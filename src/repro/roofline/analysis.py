"""Roofline analysis from compiled artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_chip
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_link_bw

``cost_analysis()`` of the SPMD-partitioned module gives per-device FLOPs /
bytes. Collective bytes are NOT in cost_analysis: we parse the post-SPMD
HLO (``compiled.as_text()``) and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(async -start forms included, -done skipped), with a size correction for
reduce-scatter (wire bytes ~ group_size x result bytes).

Hardware constants (TPU v5e-class target, per assignment):
    197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# '%all-gather.5 = bf16[2,4096]{1,0} all-gather(' / tuple results
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9\[\],{}<=\s]+?)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    count_by: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2).lower()
        kind = op.replace("-start", "")
        b = _shape_bytes(shape_str)
        if kind == "reduce-scatter":
            b *= _group_size(line)       # result is the scattered shard
        # all-gather result already includes the gathered (full) size;
        # all-reduce result bytes ~ ring wire bytes per device (x2(n-1)/n ~ 2
        # ignored -> conservative)
        bytes_by[kind] += b
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collectives: CollectiveStats
    model_flops: float               # 6*N*D (train) / 2*N*tokens (serve)
    n_chips: int
    xla_cost_analysis: dict = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — remat/redundancy waste catcher."""
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Fraction of chip peak spent on *useful* model FLOPs if the step
        ran at the roofline estimate: MODEL_FLOPS / (chips*peak*step_time)."""
        denom = self.n_chips * PEAK_FLOPS * self.step_time_s
        return self.model_flops / denom if denom else float("nan")

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
            "collective_breakdown": self.collectives.bytes_by_kind,
            "collective_counts": self.collectives.count_by_kind,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_chips": self.n_chips,
            "xla_cost_analysis_reference": self.xla_cost_analysis,
        }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*tokens (fwd-only)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per sequence + attention KV read flops
    flops = 2.0 * n * shape.global_batch
    if not cfg.attention_free:
        hd = cfg.resolved_head_dim
        n_attn_layers = sum(1 for k in cfg.layer_kinds()
                            if k in ("dense", "moe", "shared_attn"))
        flops += (4.0 * cfg.n_heads * hd * shape.seq_len
                  * shape.global_batch * n_attn_layers)
    return flops


def analyze(compiled, cfg, shape, n_chips: int,
            hlo_text: Optional[str] = None) -> Roofline:
    """Primary cost source is the HLO-text model (roofline/hlo_cost.py):
    XLA's cost_analysis() counts while-loop bodies once, which silently
    undercounts scan-over-layers models by ~n_layers (verified — see
    tests/test_roofline.py); the text model multiplies by
    known_trip_count. cost_analysis() is kept as a cross-check field."""
    from repro.roofline import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    mc = hlo_cost.module_cost(text)
    xla_cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        xla_cost = {"flops": float(ca.get("flops", 0.0)),
                    "bytes": float(ca.get("bytes accessed", 0.0))}
    except Exception:  # noqa: BLE001
        pass
    colls = CollectiveStats(
        bytes_by_kind={k: int(v) for k, v in mc.coll_by_kind.items()},
        count_by_kind={k: int(v) for k, v in mc.coll_count.items()})
    r = Roofline(
        flops_per_device=mc.flops,
        bytes_per_device=mc.bytes_fused,
        collective_bytes=float(mc.coll_bytes),
        collectives=colls,
        model_flops=model_flops(cfg, shape),
        n_chips=n_chips,
    )
    r.xla_cost_analysis = xla_cost
    r.xla_cost_analysis["bytes_all_ops_upper_bound"] = mc.bytes
    return r
