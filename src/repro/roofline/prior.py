"""Roofline cold-start priors: analytical runtime estimates for placement.

The profiler's log-linear models need measured runs to exist; a cold
cluster has none, and placement used to default every unknown template to
``duration or 1.0`` — silently collapsing the cost/speed frontier the
auto-provisioner is supposed to find. This module derives a *prior*
runtime estimate from the same roofline arithmetic as
``roofline/analysis.py``: a template registers an analytic cost
(FLOPs / HBM bytes / collective bytes as functions of the job config —
or fixed numbers parsed out of an HLO module via ``hlo_cost``), each
accelerator family registers its hardware constants, and the estimate is

    t = startup + max(flops / (peak * n), bytes / (hbm_bw * n),
                      coll_bytes / ici_bw)

with ``n`` the config's chip count on families whose compute scales with
a resource dimension. ``Profiler(prior=...)`` serves these from
``predict_for_pool`` whenever no fitted model exists, and online
``add_observation`` feedback replaces the prior with a measured per-pool
model as soon as real runtimes arrive (see docs/engine.md, "Profiler
feedback loop").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

CostFn = Union[float, Callable[[dict], float]]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """One accelerator family's roofline constants.

    ``scale_dim`` names the resource dimension whose amount multiplies
    aggregate compute/bandwidth (e.g. ``"chips"`` on a TPU pod slice);
    ``ref_chips`` is the amount the registered cost models are normalized
    to (cost models give *total* work, so ``n = config[scale_dim] /
    ref_chips`` divides it across the slice). ``startup_s`` is the
    per-job provisioning + compile tax the roofline terms sit on top of.
    """
    family: str
    peak_flops: float
    hbm_bw: float
    ici_bw: float = ICI_BW
    startup_s: float = 0.0
    scale_dim: Optional[str] = None
    ref_chips: float = 1.0

    def chips(self, config: dict) -> float:
        if self.scale_dim is None:
            return 1.0
        return max(float(config.get(self.scale_dim, self.ref_chips))
                   / self.ref_chips, 1e-9)


# The repo's target family (TPU v5e-class, constants from analysis.py).
TPU_V5E = HardwareSpec("tpu", PEAK_FLOPS, HBM_BW, ICI_BW,
                       scale_dim="chips", ref_chips=1.0)


def roofline_ceiling_s(flops: float, nbytes: float,
                       hw: HardwareSpec, coll_bytes: float = 0.0,
                       n_chips: float = 1.0) -> float:
    """Best-case seconds for a workload on ``hw``: the roofline max of
    the compute / memory / interconnect terms (no startup)."""
    n = max(n_chips, 1e-9)
    return max(flops / (hw.peak_flops * n),
               nbytes / (hw.hbm_bw * n),
               coll_bytes / hw.ici_bw if hw.ici_bw else 0.0)


@dataclasses.dataclass
class TemplateCost:
    """Analytic cost of one command template as functions of the job
    config (numeric args + resource shape — the same dict placement
    feeds ``predict_for_pool``). Constants are accepted where the cost
    does not depend on the config."""
    flops: CostFn = 0.0
    nbytes: CostFn = 0.0
    coll_bytes: CostFn = 0.0

    @staticmethod
    def _eval(fn: CostFn, config: dict) -> float:
        return float(fn(config)) if callable(fn) else float(fn)

    def evaluate(self, config: dict) -> tuple[float, float, float]:
        return (self._eval(self.flops, config),
                self._eval(self.nbytes, config),
                self._eval(self.coll_bytes, config))

    @classmethod
    def from_hlo(cls, hlo_text: str, *,
                 scale_by: Optional[str] = None) -> "TemplateCost":
        """Parse a compiled module's FLOPs / fused bytes / collective
        bytes with ``hlo_cost.module_cost`` (the while-body-aware text
        model). ``scale_by`` optionally names a config key that
        multiplies the cost (e.g. steps or tokens per job)."""
        from repro.roofline import hlo_cost
        mc = hlo_cost.module_cost(hlo_text)
        scale = ((lambda cfg: max(float(cfg.get(scale_by, 1.0)), 0.0))
                 if scale_by else (lambda cfg: 1.0))
        return cls(flops=lambda cfg: mc.flops * scale(cfg),
                   nbytes=lambda cfg: mc.bytes_fused * scale(cfg),
                   coll_bytes=lambda cfg: mc.coll_bytes * scale(cfg))


class RooflinePrior:
    """Cold-start runtime estimates per (template, accelerator family).

    ``hardware`` maps pool/family name -> :class:`HardwareSpec`;
    templates register analytic costs with :meth:`register` /
    :meth:`register_hlo`. :meth:`estimate` raises ``KeyError`` for an
    unknown template or family so callers (``Profiler.predict_for_pool``)
    can fall through to their own defaults.
    """

    def __init__(self, hardware: dict[str, HardwareSpec]):
        self.hardware = dict(hardware)
        self.templates: dict[str, TemplateCost] = {}

    def register(self, template: str, *, flops: CostFn = 0.0,
                 nbytes: CostFn = 0.0,
                 coll_bytes: CostFn = 0.0) -> "RooflinePrior":
        self.templates[template] = TemplateCost(flops, nbytes, coll_bytes)
        return self

    def register_hlo(self, template: str, hlo_text: str, *,
                     scale_by: Optional[str] = None) -> "RooflinePrior":
        self.templates[template] = TemplateCost.from_hlo(
            hlo_text, scale_by=scale_by)
        return self

    def can_estimate(self, template: str, family: str) -> bool:
        return template in self.templates and family in self.hardware

    def estimate(self, template: str, family: str, config: dict) -> float:
        """Prior runtime seconds; KeyError when template/family unknown."""
        tc = self.templates[template]
        hw = self.hardware[family]
        flops, nbytes, coll = tc.evaluate(config)
        return hw.startup_s + roofline_ceiling_s(
            flops, nbytes, hw, coll_bytes=coll, n_chips=hw.chips(config))
