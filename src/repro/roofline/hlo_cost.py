"""HLO-text cost model with correct while-loop accounting.

XLA's built-in ``cost_analysis()`` counts a while-loop body ONCE — useless
for scan-over-layers models (verified: a 10-trip scan reports 1x body
FLOPs). This module parses the post-SPMD HLO text and recursively costs the
module: while bodies are multiplied by their ``known_trip_count``
backend-config (emitted by XLA for lax.scan), fusions contribute their
inner FLOPs but only fusion-boundary bytes, and collective bytes are
attributed per call site (so collectives inside the layer scan count L
times).

Cost semantics (per device, the module is the SPMD program):
  flops : dot = 2*|result|*K, convolution = 2*|result|*window*Cin/groups,
          elementwise/reduce ~ |result| (minor)
  bytes : for each materialized (non-fused-interior) op: operand bytes +
          result bytes — the standard HloCostAnalysis HBM-traffic model
  coll  : result-shape bytes of all-gather/all-reduce/all-to-all/
          collective-permute (+start forms), reduce-scatter scaled by its
          replica-group size (wire bytes ~ the unscattered input)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^(\([^)]*\)|[\w\[\]{},.\s]+?)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_WINDOW_SIZE_RE = re.compile(r"window=\{[^}]*size=([\dx]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start",
                "all-reduce-start", "collective-permute-start"}
_NO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "iota", "after-all", "partition-id",
                 "replica-id"}
_SKIP_DONE = {"all-gather-done", "all-reduce-done",
              "collective-permute-done"}
# ops whose operand/result traffic survives TPU fusion (memory-term model)
_MATERIAL_OPS = {"dot", "convolution", "copy", "transpose",
                 "dynamic-slice", "dynamic-update-slice", "gather",
                 "scatter", "sort", "reduce-window", "rng",
                 "rng-bit-generator"} | _COLLECTIVES


def _shape_list(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype = m.group(1)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dtype, dims))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(type_str: str) -> int:
    total = 0
    for _, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_type: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]     # instr name -> result type string


def _split_operands(rest: str) -> tuple[str, str]:
    """rest starts right after the op's '('; returns (inside, after)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OP_RE.match(rhs)
        if not om:
            continue
        result_type, op = om.group(1), om.group(2)
        inside, after = _split_operands(rhs[om.end():])
        operands = _OPERAND_RE.findall(inside)
        cur.instrs.append(Instr(name, op, result_type, operands,
                                rhs[om.end() - len(op) - 1:]))
        cur.shapes[name] = result_type
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0        # every materialized op (CPU-HLO upper bound)
    bytes_fused: float = 0.0  # dot/conv/coll/copy/slice boundaries only —
                              # approximates TPU elementwise fusion
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_count: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_fused += other.bytes_fused * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v * mult


def _dot_flops(instr: Instr, comp: Computation) -> float:
    res = _numel(instr.result_type)
    k = 1
    m = _LHS_CONTRACT_RE.search(instr.line)
    if m and instr.operands:
        lhs_type = comp.shapes.get(instr.operands[0])
        if lhs_type:
            shapes = _shape_list(lhs_type)
            if shapes:
                dims = shapes[0][1]
                for idx in (int(d) for d in m.group(1).split(",") if d):
                    if idx < len(dims):
                        k *= dims[idx]
    return 2.0 * res * k


def _conv_flops(instr: Instr, comp: Computation) -> float:
    res = _numel(instr.result_type)
    window = 1
    m = _WINDOW_SIZE_RE.search(instr.line)
    if m:
        for d in m.group(1).split("x"):
            window *= int(d)
    cin = 1
    if len(instr.operands) >= 2:
        ktype = comp.shapes.get(instr.operands[1])
        if ktype:
            shapes = _shape_list(ktype)
            if shapes and len(shapes[0][1]) >= 2:
                cin = shapes[0][1][-2]   # kernel layout ...IO (approx)
    return 2.0 * res * window * cin


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _flops_only(comp: Computation, comps, memo) -> float:
    """FLOPs inside a fused computation (no bytes at fusion interior)."""
    if comp.name in memo:
        return memo[comp.name]
    total = 0.0
    for ins in comp.instrs:
        if ins.op == "dot":
            total += _dot_flops(ins, comp)
        elif ins.op == "convolution":
            total += _conv_flops(ins, comp)
        elif ins.op == "fusion" or ins.op == "call":
            m = _CALLS_RE.search(ins.line)
            tgt = m.group(1) if m else (ins.op == "call" and None)
            if ins.op == "call":
                m2 = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                tgt = m2.group(1) if m2 else tgt
            if tgt and tgt in comps:
                total += _flops_only(comps[tgt], comps, memo)
        elif ins.op not in _NO_BYTES_OPS and ins.op not in _SKIP_DONE:
            total += _numel(ins.result_type)      # elementwise-ish
    memo[comp.name] = total
    return total


def cost_computation(comp: Computation, comps: dict[str, Computation],
                     memo: dict[str, Cost],
                     flops_memo: dict[str, float]) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    c = Cost()
    for ins in comp.instrs:
        op = ins.op
        if op in _SKIP_DONE or op in _NO_BYTES_OPS:
            continue
        # bytes: operands + result for every materialized op
        b = _nbytes(ins.result_type)
        for o in ins.operands:
            t = comp.shapes.get(o)
            if t:
                b += _nbytes(t)
        if op == "while":
            trip = 1
            m = _TRIP_RE.search(ins.line)
            if m:
                trip = int(m.group(1))
            bm = _BODY_RE.search(ins.line)
            if bm and bm.group(1) in comps:
                c.add(cost_computation(comps[bm.group(1)], comps, memo,
                                       flops_memo), trip)
            cm = _COND_RE.search(ins.line)
            if cm and cm.group(1) in comps:
                c.add(cost_computation(comps[cm.group(1)], comps, memo,
                                       flops_memo), trip + 1)
            continue
        if op == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w.\-]+))",
                                 ins.line):
                names = (m.group(1) or m.group(2) or "")
                for nm in _OPERAND_RE.findall(names) or \
                        [x.strip().lstrip("%") for x in names.split(",")]:
                    if nm in comps:
                        c.add(cost_computation(comps[nm], comps, memo,
                                               flops_memo), 1.0)
            c.bytes += b
            continue
        if op == "fusion":
            m = _CALLS_RE.search(ins.line)
            if m and m.group(1) in comps:
                c.flops += _flops_only(comps[m.group(1)], comps, flops_memo)
            c.bytes += b
            continue          # fusion interiors fuse on TPU: bytes_all only
        if op == "call":
            m = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
            if m and m.group(1) in comps:
                c.add(cost_computation(comps[m.group(1)], comps, memo,
                                       flops_memo), 1.0)
            continue
        if op in _COLLECTIVES:
            kind = op.replace("-start", "")
            cb = _nbytes(ins.result_type)
            if op.endswith("-start"):
                # result tuple holds (input, output): take the larger half
                cb = cb // 2 if cb else cb
            if "_promoted" in ins.line:
                # XLA's CPU backend promotes bf16 all-reduce sums to f32
                # ("to_apply=%add..._promoted"); TPU runs them natively in
                # bf16 — count at source width
                cb //= 2
            if kind == "reduce-scatter":
                cb *= _group_size(ins.line)
            c.coll_bytes += cb
            c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0) + cb
            c.coll_count[kind] = c.coll_count.get(kind, 0) + 1
            c.bytes += b
            c.bytes_fused += b
            continue
        if op == "dot":
            c.flops += _dot_flops(ins, comp)
        elif op == "convolution":
            c.flops += _conv_flops(ins, comp)
        else:
            c.flops += _numel(ins.result_type)
        c.bytes += b
        if op in _MATERIAL_OPS:
            c.bytes_fused += b
    memo[comp.name] = c
    return c


def module_cost(hlo_text: str) -> Cost:
    comps = parse_module(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation with the most instructions
        entry = max(comps, key=lambda k: len(comps[k].instrs))
    return cost_computation(comps[entry], comps, {}, {})
