"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early
fusion (frontend stubbed). [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                  n_shared_experts=1, d_ff_shared=8192),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
