"""musicgen-large [audio] — decoder-only over EnCodec tokens, 4 codebooks
(delay pattern / EnCodec frontend stubbed: inputs are (B, S, K) code ids).
[arXiv:2306.05284; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    norm_type="layernorm",
    source="arXiv:2306.05284",
))
