"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer;
vision frontend stubbed (input_specs provides patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    rope_theta=5e5,
    cross_attn_every=5,
    vision_dim=1280,
    n_vision_tokens=1601,   # 1 tile x (40x40+1) patches
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
