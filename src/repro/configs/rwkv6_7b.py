"""rwkv6-7b [ssm] — Finch, data-dependent decay, attn-free. [arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig, RWKVConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # d_model / rwkv.head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65_536,
    rwkv=RWKVConfig(head_dim=64),
    source="arXiv:2404.05892",
))
