"""Architecture config system.

Every assigned architecture is a frozen ``ArchConfig``; reduced smoke-test
variants are derived with ``.reduced()``. Configs are registered by id and
selectable everywhere via ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

_REGISTRY: dict[str, "ArchConfig"] = {}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # every k-th layer is MoE (1 = all layers)
    moe_every: int = 1
    # independent routing groups (aligned with data shards so dispatch
    # scatter/gather stays device-local); capacity is per group
    n_dispatch_groups: int = 16
    # compute the shared expert INSIDE the EP shard_map on its model-axis
    # ff slice so its partial sums ride the EP psum (one collective
    # instead of two) — §Perf cell B
    fuse_shared: bool = False


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    lora_shift: int = 32
    lora_decay: int = 64
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qk_norm: bool = False
    parametric_norm: bool = True            # False => OLMo non-parametric LN
    norm_type: str = "rmsnorm"              # rmsnorm | layernorm
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (zamba2): one shared attention block applied every k layers
    hybrid_attn_every: int = 0
    # vlm (llama-3.2-vision): a cross-attention layer every k layers
    cross_attn_every: int = 0
    vision_dim: int = 0
    n_vision_tokens: int = 0
    # audio (musicgen): number of codebooks (input (B,S,K), K lm heads)
    n_codebooks: int = 0
    norm_eps: float = 1e-5
    source: str = ""

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if the arch can run the long_500k decode shape."""
        return self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> list[str]:
        """Per-layer block kinds; drives the group layout in transformer.py."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                kinds.append("rwkv")
            elif self.family == "hybrid":
                # every hybrid_attn_every-th layer is the shared attn block
                if self.hybrid_attn_every and (i % self.hybrid_attn_every
                                               == self.hybrid_attn_every - 1):
                    kinds.append("shared_attn")
                else:
                    kinds.append("mamba")
            elif self.family == "vlm" and self.cross_attn_every and (
                    i % self.cross_attn_every == self.cross_attn_every - 1):
                kinds.append("cross_attn")
            elif self.moe is not None and (i % self.moe.moe_every
                                           == self.moe.moe_every - 1):
                kinds.append("moe")
            else:
                kinds.append("dense")
        return kinds

    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            if self.n_codebooks:
                total += self.n_codebooks * self.vocab_size * d
            else:
                total += self.vocab_size * d
        for kind in self.layer_kinds():
            total += self._block_params(kind)
        total += d  # final norm
        return total

    def n_active_params(self) -> int:
        """Active-per-token params (differs from n_params for MoE)."""
        d = self.d_model
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += (self.n_codebooks or 1) * self.vocab_size * d
        for kind in self.layer_kinds():
            if kind == "moe":
                m = self.moe
                act = self._attn_params() + 2 * d
                act += m.top_k * 3 * d * m.d_ff_expert
                act += m.n_shared_experts * 3 * d * m.d_ff_shared
                act += d * m.n_experts  # router
                total += act
            else:
                total += self._block_params(kind)
        return total + d

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        p = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.qk_norm:
            p += 2 * hd
        return p

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "dense":
            return self._attn_params() + 3 * d * self.d_ff + 2 * d
        if kind == "moe":
            m = self.moe
            p = self._attn_params() + 2 * d + d * m.n_experts
            p += m.n_experts * 3 * d * m.d_ff_expert
            p += m.n_shared_experts * 3 * d * m.d_ff_shared
            return p
        if kind == "rwkv":
            r = self.rwkv
            hd = r.head_dim
            # time-mix: 5 projections d*d (r,k,v,g,o) + loras + channel mix
            p = 5 * d * d + 5 * (d * r.lora_shift + r.lora_shift * d) \
                + d * r.lora_decay + r.lora_decay * d + 2 * d
            p += 2 * d * self.d_ff + d * d  # channel mix (w_k, w_v, w_r)
            return p + 2 * d
        if kind == "mamba":
            mc = self.mamba
            di = mc.d_inner(d)
            nh = mc.n_heads(d)
            p = d * (2 * di + 2 * mc.n_groups * mc.d_state + nh)  # in_proj
            p += (di + 2 * mc.n_groups * mc.d_state) * mc.d_conv  # conv
            p += 3 * nh + di  # A_log, D, dt_bias, gate norm
            p += di * d + d  # out_proj + pre-norm
            return p
        if kind == "shared_attn":
            # weights shared across sites: counted once at layout build time
            return 0
        if kind == "cross_attn":
            d_src = self.vision_dim or d
            hd = self.resolved_head_dim
            p = d * self.n_heads * hd + 2 * d_src * self.n_kv_heads * hd \
                + self.n_heads * hd * d + 2 * d
            p += 3 * d * self.d_ff + d  # its own MLP
            return p
        raise ValueError(kind)

    def shared_block_params(self) -> int:
        if self.family != "hybrid":
            return 0
        return self._attn_params() + 3 * self.d_model * self.d_ff \
            + 2 * self.d_model

    # ---- reduced smoke-test variant ----------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        hd = 16
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(n_heads, self.n_kv_heads * n_heads
                          // max(self.n_heads, 1)) or 1)
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family not in
                         ("hybrid", "vlm") else 6),
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=128,
            vocab_size=256,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=64, n_dispatch_groups=1,
                d_ff_shared=64 if self.moe.n_shared_experts else 0)
        if self.mamba:
            kw["mamba"] = dataclasses.replace(
                self.mamba, d_state=16, head_dim=16, chunk=16)
        if self.rwkv:
            kw["rwkv"] = dataclasses.replace(
                self.rwkv, head_dim=16, lora_shift=8, lora_decay=8, chunk=16)
        if self.family == "hybrid":
            kw["hybrid_attn_every"] = 3
        if self.family == "vlm":
            kw["cross_attn_every"] = 3
            kw["vision_dim"] = 48
            kw["n_vision_tokens"] = 8
        return dataclasses.replace(self, **kw)


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    from repro.configs import (  # noqa: F401
        qwen3_32b, qwen3_8b, mistral_nemo_12b, olmo_1b, olmoe_1b_7b,
        llama4_scout, rwkv6_7b, llama32_vision_11b, zamba2_7b,
        musicgen_large)
