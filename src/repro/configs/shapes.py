"""Assigned input shapes and (arch x shape) applicability rules."""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable?, reason-if-not). long_500k needs sub-quadratic sequence
    handling -> SSM/hybrid only (see DESIGN.md §6)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("pure full-attention arch: 500k-token KV decode is "
                       "quadratic-prefill territory; skipped per assignment")
    return True, ""


def cells(archs: list[ArchConfig]) -> list[tuple[ArchConfig, ShapeConfig, bool, str]]:
    out = []
    for a in archs:
        for s in SHAPES.values():
            ok, why = applicable(a, s)
            out.append((a, s, ok, why))
    return out
