"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block applied
every 6th layer (weights shared across sites). [arXiv:2411.15242; unverified]"""
from repro.configs.base import ArchConfig, MambaConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32_000,
    mamba=MambaConfig(d_state=64, head_dim=64, expand=2, n_groups=1),
    hybrid_attn_every=6,
    source="arXiv:2411.15242",
))
