"""Serving steps: prefill and one-token decode (the dry-run's ``serve_step``
lowers these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models import transformer as T


def make_prefill_step(cfg: ArchConfig, *, attn_impl: str = "xla",
                      compute_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        ctx = M.make_ctx(cfg, tokens.shape[1], "prefill",
                         attn_impl=attn_impl, remat=None,
                         vision=batch.get("vision"),
                         compute_dtype=compute_dtype)
        return M.prefill(params, tokens, cfg, ctx)

    return prefill_step


def make_serve_step(cfg: ArchConfig, buffer_len: int, *,
                    compute_dtype=jnp.bfloat16):
    """One new token against a KV cache / SSM state of ``buffer_len``."""

    def serve_step(params, states, batch):
        tokens = batch["tokens"]          # (B, 1[, K])
        cache_len = batch["cache_len"]    # (B,) current filled length
        ctx = M.make_ctx(cfg, buffer_len, "decode",
                         vision=batch.get("vision"), cache_len=cache_len,
                         compute_dtype=compute_dtype)
        logits, new_states = M.decode_step(params, tokens, states,
                                           cache_len, cfg, ctx)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return logits, new_states, next_tok

    return serve_step


def greedy_generate(cfg: ArchConfig, params, prompt, max_new: int,
                    vision=None):
    """Reference autoregressive loop (tiny models / examples): prefill the
    prompt token-by-token through the decode path, then generate."""
    b = prompt.shape[0]
    buf = prompt.shape[1] + max_new
    states = T.init_decode_state(cfg, b, buf, vision=vision, params=params)
    cache_len = jnp.zeros((b,), jnp.int32)
    step = jax.jit(make_serve_step(cfg, buf))
    toks = prompt
    out = []
    cur = toks[:, :1]
    for i in range(buf - 1):
        batch = {"tokens": cur, "cache_len": cache_len}
        if vision is not None:
            batch["vision"] = vision
        logits, states, nxt = step(params, states, batch)
        cache_len = cache_len + 1
        if i + 1 < prompt.shape[1]:
            cur = toks[:, i + 1:i + 2]            # teacher-force the prompt
        else:
            cur = nxt[:, None] if nxt.ndim == 1 else nxt[:, None, :]
            out.append(cur)
    return jnp.concatenate(out, axis=1) if out else prompt[:, :0]
