from repro.sharding.mesh import make_abstract_mesh
from repro.sharding.rules import (AxisRules, constrain, set_rules,
                                  current_rules, param_specs,
                                  batch_specs, logical_to_spec)
