"""Version-compatible AbstractMesh construction.

JAX changed ``AbstractMesh``'s constructor across 0.4.x -> 0.5+:

    old (<= 0.4.x):  AbstractMesh(((name, size), ...))
    new (>= 0.5):    AbstractMesh(axis_sizes, axis_names)

Callers should never spell either signature directly; ``make_abstract_mesh``
tries the new form and falls back to the old pair form, so mesh-shape
property tests (and anything else building device-free meshes) collect and
run on every pinned JAX.
"""
from __future__ import annotations

from typing import Sequence


def make_abstract_mesh(axis_sizes: Sequence[int],
                       axis_names: Sequence[str]):
    """Build ``jax.sharding.AbstractMesh`` on any supported JAX version."""
    from jax.sharding import AbstractMesh
    sizes = tuple(int(s) for s in axis_sizes)
    names = tuple(str(n) for n in axis_names)
    if len(sizes) != len(names):
        raise ValueError(f"axis_sizes/axis_names length mismatch: "
                         f"{sizes} vs {names}")
    try:
        return AbstractMesh(sizes, names)          # new signature
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))   # old signature
