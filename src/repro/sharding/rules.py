"""Logical-axis sharding rules (GSPMD).

Model code annotates tensors with *logical* axis names; the launcher installs
an ``AxisRules`` mapping logical names -> mesh axes for the active mesh.
Outside any rules context (unit tests, single device) annotations are no-ops.

Logical axes:
  batch   : data-parallel batch           -> ("pod", "data") / ("data",)
  tp      : tensor-parallel (heads, d_ff, experts, vocab)   -> ("model",)
  kvseq   : KV-cache / long-context sequence sharding       -> ("model",)
  longseq : 500k decode KV sequence        -> ("data", "model") combined
  zero    : optimizer-state / FSDP weight sharding          -> ("data",)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class AxisRules:
    mesh: Optional[Mesh]
    table: dict[str, tuple[str, ...]]

    @classmethod
    def for_mesh(cls, mesh: Mesh) -> "AxisRules":
        axes = mesh.axis_names
        batch = tuple(a for a in ("pod", "data") if a in axes)
        model = ("model",) if "model" in axes else ()
        return cls(mesh=mesh, table={
            "batch": batch,
            "tp": model,
            "kvseq": model,
            "longseq": batch + model,
            "zero": tuple(a for a in ("data",) if a in axes),
        })


_ACTIVE: Optional[AxisRules] = None


def set_rules(rules: Optional[AxisRules]) -> None:
    global _ACTIVE
    _ACTIVE = rules


def current_rules() -> Optional[AxisRules]:
    return _ACTIVE


def logical_to_spec(logical: Sequence[Optional[str]],
                    rules: Optional[AxisRules] = None) -> P:
    rules = rules or _ACTIVE
    if rules is None:
        return P()
    out = []
    for name in logical:
        if name is None:
            out.append(None)
        else:
            mapped = rules.table.get(name, ())
            out.append(mapped if len(mapped) != 1 else mapped[0])
    return P(*out)


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axis names; no-op without rules."""
    rules = _ACTIVE
    if rules is None or rules.mesh is None:
        return x
    spec = logical_to_spec(logical, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# parameter sharding specs (path-walk over the real param tree)
# ---------------------------------------------------------------------------

_COL_TP = {"wq", "wk", "wv", "wg", "wr", "w_up", "w_gate", "cm_wk",
           "cm_wr", "z_proj", "x_proj", "conv_x", "lm_head"}
_ROW_TP = {"wo", "out_proj", "cm_wv", "w_down"}
_VEC_TP = {"conv_b_x", "gate_norm", "ln_x"}


def _leaf_spec(path: tuple[str, ...], ndim: int, cfg, tp) -> P:
    """Core PartitionSpec for one param leaf; leading stack dims padded."""
    key = path[-1]
    in_moe = "moe" in path and "shared" not in path

    if key == "embed":
        if cfg.n_codebooks:
            return P(None, None, tp)
        # tied tables serve take() AND logits: vocab-sharded keeps logits
        # tp-sharded (no giant psum); untied tables shard d_model instead
        return P(tp, None) if cfg.tie_embeddings else P(None, tp)
    if in_moe and key in ("w_gate", "w_up", "w_down"):
        core = (tp, None, None)               # experts over tp (EP)
    elif key in _COL_TP:
        core = (None, tp)
    elif key in _ROW_TP:
        core = (tp, None)
    elif key in _VEC_TP:
        core = (tp,)
    else:
        core = ()
    pad = (None,) * (ndim - len(core))
    return P(*(pad + core))


def _path_keys(path) -> tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(cfg, rules: Optional[AxisRules] = None,
                fsdp: bool = True, param_shapes=None):
    """PartitionSpec pytree exactly matching ``init_params(cfg)``.

    Specs are assigned by walking the real (eval_shape'd) param tree and
    pattern-matching leaf paths — the spec tree always matches the param
    tree structure. With ``fsdp``, one extra dimension per leaf (never the
    leading stacked-layer dim) shards over the data axis: FSDP/ZeRO-3-style
    weight sharding whose gathers GSPMD overlaps inside the layer scan.
    """
    rules = rules or _ACTIVE
    tp = None
    if rules is not None:
        mapped = rules.table.get("tp", ())
        tp = mapped[0] if len(mapped) == 1 else (mapped or None)
    if param_shapes is None:
        from repro.models import model as _M
        param_shapes = jax.eval_shape(
            functools.partial(_M.init_params, cfg), jax.random.PRNGKey(0))

    data_axes = rules.table.get("zero", ()) if rules else ()
    data = data_axes[0] if data_axes else None
    n_data = int(rules.mesh.shape[data]) if data else 1

    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    specs = []
    for path, leaf in flat:
        keys = _path_keys(path)
        spec = _leaf_spec(keys, len(leaf.shape), cfg, tp)
        # (expert weights are stored FSDP-sharded too; shard_map reshards
        # to its P("model",...) in_specs = the FSDP gather, overlappable)
        if fsdp and data and n_data > 1 and keys[-1] != "embed" \
                and len(leaf.shape) >= 2:
            parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
            for i in range(len(leaf.shape) - 1, 0, -1):
                if parts[i] is None and leaf.shape[i] % n_data == 0 \
                        and leaf.shape[i] >= n_data:
                    parts[i] = data
                    break
            spec = P(*parts)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# decode-state / batch specs
# ---------------------------------------------------------------------------

def decode_state_specs(cfg, global_batch: int,
                       rules: Optional[AxisRules] = None,
                       layout: str = "fsdp"):
    """PartitionSpec tree matching transformer.init_decode_state.

    layout="fsdp" (baseline): batch over data when divisible; kv-heads over
    model when divisible, else the sequence dim shards over model; batch-1
    long-context decode shards the sequence over data AND model.
    layout="resident" (serving-optimized, §Perf C): batch replicated —
    weights stay 2D-resident (no per-token FSDP gather) and the KV sequence
    shards over data x model.
    """
    from repro.models.transformer import build_layout
    rules = rules or _ACTIVE
    if rules is None:
        return None
    tbl = rules.table
    tp = tbl.get("tp", (None,))[0] if tbl.get("tp") else None
    batch_axes = tbl.get("batch", ())
    mesh = rules.mesh
    bsz = 1
    for a in batch_axes:
        bsz *= int(mesh.shape[a])
    b_ax = batch_axes if (batch_axes and global_batch % bsz == 0
                          and global_batch >= bsz) else None
    if layout == "resident":
        b_ax = None
    if b_ax is not None and len(b_ax) == 1:
        b_ax = b_ax[0]
    tp_size = int(mesh.shape[tp]) if tp else 1

    def attn_spec():
        # (stack..., B, S, KV, D)
        if layout == "resident" and batch_axes and tp is not None:
            return (None, tuple(batch_axes) + (tp,), None, None)
        seq_ax = None
        if b_ax is None and batch_axes:
            # batch too small to shard -> the sequence takes the data axis
            seq_ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        if cfg.n_kv_heads % tp_size == 0 and tp_size > 1:
            return (b_ax, seq_ax, tp, None)
        if seq_ax is not None and tp is not None:
            return (b_ax, tuple(batch_axes) + (tp,), None, None)
        return (b_ax, tp, None, None)       # seq over model

    def stackP(nstack, core):
        return P(*((None,) * nstack + tuple(core)))

    layout = build_layout(cfg)
    if layout["kind"] == "uniform":
        if layout["block"] == "rwkv":
            st = (stackP(1, (b_ax, tp, None, None)),      # wkv (B,H,K,V)
                  stackP(1, (b_ax, None, None)),          # tm last token
                  stackP(1, (b_ax, None, None)))          # cm last token
            return {"layers": st}
        core = attn_spec()
        return {"layers": (stackP(1, core), stackP(1, core))}

    # periodic
    if layout["inner_block"] == "mamba":
        inner = (stackP(2, (b_ax, tp, None, None)),       # ssm (B,H,N,P)
                 stackP(2, (b_ax, None, tp)))             # conv (B,W-1,C)
        trailing = (stackP(1, (b_ax, tp, None, None)),
                    stackP(1, (b_ax, None, tp)))
    else:
        core = attn_spec()
        inner = (stackP(2, core), stackP(2, core))
        trailing = (stackP(1, core), stackP(1, core))
    core = attn_spec()
    if layout["single_block"] == "cross_attn":
        single = (stackP(1, (b_ax, None, None, None)),
                  stackP(1, (b_ax, None, None, None)))
    else:
        single = (stackP(1, core), stackP(1, core))
    return {"inner": inner, "single": single, "trailing": trailing}


def batch_specs(cfg, shape_kind: str, global_batch: int,
                rules: Optional[AxisRules] = None, layout: str = "fsdp"):
    """Input-batch PartitionSpecs per shape kind (see launch/dryrun.py)."""
    rules = rules or _ACTIVE
    b = None
    if rules is not None and layout != "resident":
        axes = rules.table.get("batch", ())
        size = 1
        for a in axes:
            size *= int(rules.mesh.shape[a])
        if axes and global_batch % size == 0 and global_batch >= size:
            b = axes if len(axes) > 1 else axes[0]
    out = {"tokens": P(b, None) if not cfg.n_codebooks else P(b, None, None)}
    if shape_kind == "train":
        out["labels"] = out["tokens"]
    if shape_kind == "decode":
        out["cache_len"] = P(b)
    if cfg.family == "vlm":
        out["vision"] = P(b, None, None)
    return out
