"""Blocked online-softmax (flash) attention — TPU Pallas kernel.

TPU-native adaptation of the FlashAttention-2 schedule: the grid's innermost
dimension walks KV blocks sequentially per (batch, q-head, q-block) with the
running (m, l, acc) state living in VMEM scratch (persists across the
innermost grid dim on TPU). Causal blocks above the diagonal are skipped via
``pl.when`` — no wasted MXU work, unlike the XLA fallback's masked schedule.

GQA is handled by the k/v BlockSpec index map (query head h reads kv head
h // group) — grouped KV is never materialized.

Layout: q (B, H, S, D), k/v (B, KV, S, D). Block sizes default to 128 to
align with the MXU 128x128 systolic array; D is expected to be a multiple
of 128 on TPU (it is for all assigned archs except head_dim 64/80/112 ones,
which pad — see ops.py). A sequence length that does not divide the block
sizes is padded to the block grid with the final KV block masked (padded
query rows trimmed), so autotuner candidate shapes never crash.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, sm_scale: float,
                  n_kv_blocks: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: q block [q_start, q_start+bq) needs kv blocks with
    # k_start <= q_end
    q_end = q_start + block_q - 1
    needed = (k_start <= q_end) if causal else True

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale     # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        elif n_kv_blocks * block_k != kv_len:
            # ragged final block (seq padded to the block grid): padded
            # key positions must not contribute. Causal needs no mask —
            # padded keys sit strictly after every valid query row, and
            # padded query rows are trimmed by the caller.
            k_pos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(k_pos < kv_len, s, NEG_INF)
        m_prev = m_scr[:, 0]                               # (bq,)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])                    # (bq, bk)
        l_scr[:, 0] = l_scr[:, 0] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_cur

    last_ki = (jnp.minimum(q_end, (n_kv_blocks * block_k) - 1) // block_k) \
        if causal else (n_kv_blocks - 1)

    @pl.when(ki == last_ki)
    def _finalize():
        l = jnp.maximum(l_scr[:, 0], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         sm_scale=None, interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, KV, S, D) with H % KV == 0."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0, (h, kv)
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    # ragged sequence: pad q/k/v to the block grid (nearest multiple of
    # lcm(block_q, block_k)) and mask the final KV block in-kernel;
    # padded query rows are trimmed from the output below
    s_pad = s
    if s % block_q or s % block_k:
        step = math.lcm(block_q, block_k)
        s_pad = ((s + step - 1) // step) * step
        padw = ((0, 0), (0, 0), (0, s_pad - s), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    nq, nk = s_pad // block_q, s_pad // block_k
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sm_scale=sm_scale, n_kv_blocks=nk, kv_len=s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        scratch_shapes=[
            _scratch((block_q, 128)),     # running max  (col 0 used)
            _scratch((block_q, 128)),     # running sum  (col 0 used)
            _scratch((block_q, d)),       # fp32 accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :] if s_pad != s else out


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
