"""Jit'd public wrappers for the Pallas kernels (layout adapters + the
interpret switch used by CPU validation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba2_ssd as _ssd
from repro.kernels import rwkv6 as _wkv


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, S, H, D); k, v: (B, S, KV, D) -> (B, S, H, D)."""
    qt = jnp.swapaxes(q, 1, 2)          # (B, H, S, D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _fa.flash_attention_bhsd(qt, kt, vt, causal=causal,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return jnp.swapaxes(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, *, chunk: int = 128, interpret: bool = False):
    """r,k,v,logw: (B, S, H, K); u: (H, K) -> (B, S, H, K)."""
    tr = lambda a: jnp.swapaxes(a, 1, 2)
    o = _wkv.wkv6_bhsk(tr(r), tr(k), tr(v), tr(logw), u, chunk=chunk,
                       interpret=interpret)
    return jnp.swapaxes(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x, dt, A, B, C, D, *, chunk: int = 128,
               interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); B,C: (B,S,G,N); A,D: (H,) -> (B,S,H,P)."""
    xt = jnp.swapaxes(x, 1, 2)                  # (B,H,S,P)
    dtt = jnp.swapaxes(dt, 1, 2)                # (B,H,S)
    Bt = jnp.swapaxes(B, 1, 2)                  # (B,G,S,N)
    Ct = jnp.swapaxes(C, 1, 2)
    o = _ssd.ssd_bhsp(xt, dtt, A, Bt, Ct, D, chunk=chunk,
                      interpret=interpret)
    return jnp.swapaxes(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
                     interpret: bool = False):
    """q: (B, 1, H, D); caches (B, S, KV, D); cache_len (B,) ->
    (B, 1, H, D)."""
    q3 = q[:, 0]                                 # (B, H, D)
    kc = jnp.swapaxes(k_cache, 1, 2)             # (B, KV, S, D)
    vc = jnp.swapaxes(v_cache, 1, 2)
    o = _dec.decode_attention_bhd(q3, kc, vc, cache_len, block_k=block_k,
                                  interpret=interpret)
    return o[:, None]
