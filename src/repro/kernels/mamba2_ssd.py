"""Mamba-2 SSD chunked scan — TPU Pallas kernel.

Hardware adaptation (DESIGN.md §3): the Triton SSD kernel uses warp-level
semiring scans; the TPU version uses the block matrix form — per chunk the
intra-chunk term is (C_t · B_j decay-weighted) masked-matmul on the MXU and
the (N x P) state carries across the innermost grid dim in VMEM scratch.
Scalar-per-head decay makes the exponent algebra 1-D (cheaper than WKV6's
per-channel decay).

Layouts: x (B,H,S,P) blocked (1,1,C,P); dt (B,H,S) blocked (1,1,C);
Bmat/Cmat (B,G,S,N) blocked (1,1,C,N) with head->group index mapping;
A,D (H,). Grid (B, H, NC).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref,
                state_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    f32 = jnp.float32
    x = x_ref[0, 0].astype(f32)           # (C, P)
    dt = dt_ref[0, 0].astype(f32)         # (C,)
    a = a_ref[0].astype(f32)              # scalar <0
    bm = b_ref[0, 0].astype(f32)          # (C, N)
    cm = c_ref[0, 0].astype(f32)          # (C, N)
    dcoef = d_ref[0].astype(f32)

    la = dt * a                           # (C,) log decay per token
    cum = jnp.cumsum(la)                  # inclusive
    tot = cum[-1]
    xd = x * dt[:, None]                  # dt-weighted input

    state = state_scr[...]                # (N, P)
    # inter-chunk: y_t += C_t exp(cum_t) . state
    cdec = cm * jnp.exp(cum)[:, None]
    y = jax.lax.dot_general(cdec, state, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)
    # intra-chunk pairs j <= t (half-shifted exponents)
    cs = cm * jnp.exp(cum - 0.5 * tot)[:, None]
    bs = bm * jnp.exp(0.5 * tot - cum)[:, None]
    att = jax.lax.dot_general(cs, bs, (((1,), (1,)), ((), ())),
                              preferred_element_type=f32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(ii >= jj, att, 0.0)
    y = y + jax.lax.dot_general(att, xd, (((1,), (0,)), ((), ())),
                                preferred_element_type=f32)
    # state update: h' = exp(tot) h + sum_j exp(tot - cum_j) B_j xd_j^T
    bdec = bm * jnp.exp(tot - cum)[:, None]
    state_scr[...] = jnp.exp(tot) * state + jax.lax.dot_general(
        bdec, xd, (((0,), (0,)), ((), ())),
        preferred_element_type=f32)
    # skip connection
    y = y + x * dcoef
    o_ref[0, 0] = y.astype(o_ref.dtype)


def ssd_bhsp(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B,H,S,P); dt: (B,H,S); A,D: (H,); Bm,Cm: (B,G,S,N)."""
    b, h, s, p_ = x.shape
    g, n = Bm.shape[1], Bm.shape[3]
    reps = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    grid = (b, h, nc)
    xspec = pl.BlockSpec((1, 1, chunk, p_),
                         lambda b_, h_, ci: (b_, h_, ci, 0))
    dtspec = pl.BlockSpec((1, 1, chunk), lambda b_, h_, ci: (b_, h_, ci))
    hspec = pl.BlockSpec((1,), lambda b_, h_, ci: (h_,))
    bcspec = pl.BlockSpec((1, 1, chunk, n),
                          lambda b_, h_, ci: (b_, h_ // reps, ci, 0))
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[xspec, dtspec, hspec, bcspec, bcspec, hspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, p_), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p_), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D)
