"""Flash-decode attention (one query token vs a long KV cache) — TPU Pallas.

The GPU flash-decode splits KV across SMs and merges per-split LSE; on TPU
the innermost sequential grid dimension IS the split walk, so the running
(m, l, acc) in VMEM scratch performs the LSE merge incrementally. Invalid
cache positions (>= cache_len) are masked inside each block.

Layout: q (B, H, D); k/v cache (B, KV, S, D) blocked (1,1,block_k,D);
cache_len (B,). Grid (B, H, S // block_k).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_k: int, sm_scale: float,
                   n_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    f32 = jnp.float32
    q = q_ref[0, 0].astype(f32) * sm_scale        # (1, D)  — kept 2D
    k = k_ref[0, 0].astype(f32)                   # (bk, D)
    v = v_ref[0, 0].astype(f32)
    clen = len_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=f32)  # (1, bk)
    pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(pos < clen, s, NEG_INF)

    m_prev = m_scr[0, 0]
    m_cur = jnp.maximum(m_prev, s.max())
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                         # (1, bk)
    l_scr[0, 0] = l_scr[0, 0] * corr + p.sum()
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=f32)
    m_scr[0, 0] = m_cur

    @pl.when(ki == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[0, 0], 1e-37)).astype(o_ref.dtype)


def decode_attention_bhd(q, k_cache, v_cache, cache_len, *,
                         block_k: int = 512, sm_scale=None,
                         interpret: bool = False):
    """q: (B, H, D); caches (B, KV, S, D); cache_len (B,) -> (B, H, D)."""
    b, h, d = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    group = h // kv
    block_k = min(block_k, s)
    assert s % block_k == 0
    nk = s // block_k
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    q4 = q.reshape(b, h, 1, d)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k,
                          sm_scale=sm_scale, n_blocks=nk),
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, ki: (b_, h_ // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, ki: (b_, h_ // group, ki, 0)),
            pl.BlockSpec((1,), lambda b_, h_, ki: (b_,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda b_, h_, ki: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((1, 128), jnp.float32),
                        pltpu.VMEM((1, 128), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(q4, k_cache, v_cache, cache_len)
    return out.reshape(b, h, d)
