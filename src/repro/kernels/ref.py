"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are deliberately the most literal O(S^2)/sequential implementations —
no chunking tricks — so kernel bugs can't hide in shared structure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, sm_scale=None):
    """q: (B, S, H, D); k, v: (B, S, KV, D). fp32 math."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    group = h // kv
    if sm_scale is None:
        sm_scale = d ** -0.5
    kq = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kq) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vq).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_len, *, sm_scale=None):
    """q: (B, H, D); caches: (B, KV, S, D); cache_len: (B,)."""
    b, h, d = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    group = h // kv
    if sm_scale is None:
        sm_scale = d ** -0.5
    kq = jnp.repeat(k_cache, group, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v_cache, group, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kq) * sm_scale
    valid = jnp.arange(s)[None, None, :] < cache_len[:, None, None]
    sc = jnp.where(valid, sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, vq).astype(q.dtype)


def wkv6_ref(r, k, v, logw, u):
    """Sequential WKV6. r,k,v,logw: (B, S, H, K); u: (H, K).
    S_t = diag(w_t) S_{t-1} + k_t^T v_t;  y_t = r_t (S_{t-1} + diag(u) k v)."""
    f32 = jnp.float32
    b, s, h, dk = r.shape
    r_, k_, v_, w_ = (a.astype(f32).transpose(1, 0, 2, 3)
                      for a in (r, k, v, logw))   # (S, B, H, K)

    def step(state, xs):
        rt, kt, vt, lwt = xs
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       state + u.astype(f32)[None, :, :, None] * kv)
        state = jnp.exp(lwt)[..., None] * state + kv
        return state, y

    state0 = jnp.zeros((b, h, dk, dk), f32)
    _, ys = jax.lax.scan(step, state0, (r_, k_, v_, w_))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype)


def ssd_ref(x, dt, A, B, C, D):
    """Sequential Mamba-2 SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,);
    B,C: (B,S,G,N); D: (H,)."""
    f32 = jnp.float32
    b, s, h, p_ = x.shape
    g, n = B.shape[2], B.shape[3]
    reps = h // g
    Bh = jnp.repeat(B.astype(f32), reps, axis=2)
    Ch = jnp.repeat(C.astype(f32), reps, axis=2)
    xt = x.astype(f32).transpose(1, 0, 2, 3)
    dtt = dt.astype(f32).transpose(1, 0, 2)
    Bt = Bh.transpose(1, 0, 2, 3)
    Ct = Ch.transpose(1, 0, 2, 3)

    def step(state, xs):
        xi, dti, bi, ci = xs
        a = jnp.exp(dti * A.astype(f32)[None])           # (B, H)
        xd = xi * dti[..., None]
        state = a[..., None, None] * state + \
            jnp.einsum("bhn,bhp->bhnp", bi, xd)
        y = jnp.einsum("bhn,bhnp->bhp", ci, state)
        return state, y

    state0 = jnp.zeros((b, h, n, p_), f32)
    _, ys = jax.lax.scan(step, state0, (xt, dtt, Bt, Ct))
    y = ys.transpose(1, 0, 2, 3)
    return (y + x.astype(f32) * D.astype(f32)[None, None, :, None]
            ).astype(x.dtype)
