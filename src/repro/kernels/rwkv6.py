"""RWKV-6 (WKV6) chunked linear-recurrence — TPU Pallas kernel.

Hardware adaptation (DESIGN.md §3): the reference CUDA kernel walks the
recurrence one token per thread-block with the state in registers; that maps
terribly to TPU. Instead we use the chunk-parallel matrix form: per chunk,
the intra-chunk contribution is two MXU matmuls (decay-weighted r @ k^T,
then @ v) and the inter-chunk contribution is r @ state; the (K x V) state
is carried across the innermost sequential grid dimension in VMEM scratch.
Pairwise decays use exponent half-shifting for fp32 safety (same scheme as
the jnp path in models/rwkv.py — the two implementations cross-check).

Layout: r,k,v,logw (B, H, S, K) blocked (1,1,C,K); u (H, K); grid (B,H,NC).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scr, *,
                 chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    f32 = jnp.float32
    rc = r_ref[0, 0].astype(f32)          # (C, K)
    kc = k_ref[0, 0].astype(f32)
    vc = v_ref[0, 0].astype(f32)
    lw = lw_ref[0, 0].astype(f32)         # log decay, <= 0
    u = u_ref[0].astype(f32)              # (K,)

    cum = jnp.cumsum(lw, axis=0)
    ce = cum - lw                         # exclusive cumsum
    tot = cum[-1:]                        # (1, K)

    state = state_scr[...]                # (K, V)
    # inter-chunk
    rd = rc * jnp.exp(ce)
    y = jax.lax.dot_general(rd, state, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)
    # intra-chunk (strictly-lower pairs), half-shifted exponents
    rds = rc * jnp.exp(ce - 0.5 * tot)
    ki = kc * jnp.exp(0.5 * tot - cum)
    att = jax.lax.dot_general(rds, ki, (((1,), (1,)), ((), ())),
                              preferred_element_type=f32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(ii > jj, att, 0.0)
    y = y + jax.lax.dot_general(att, vc, (((1,), (0,)), ((), ())),
                                preferred_element_type=f32)
    # diagonal bonus term
    diag = jnp.sum(rc * kc * u[None, :], axis=1, keepdims=True)
    y = y + diag * vc
    # state update
    kdec = kc * jnp.exp(tot - cum)
    state_scr[...] = jnp.exp(tot).T * state + jax.lax.dot_general(
        kdec, vc, (((0,), (0,)), ((), ())),
        preferred_element_type=f32)
    o_ref[0, 0] = y.astype(o_ref.dtype)


def wkv6_bhsk(r, k, v, logw, u, *, chunk: int = 128,
              interpret: bool = False):
    """r,k,v,logw: (B, H, S, K); u: (H, K). Returns y (B, H, S, K)."""
    b, h, s, dk = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (b, h, nc)
    spec = pl.BlockSpec((1, 1, chunk, dk),
                        lambda b_, h_, ci: (b_, h_, ci, 0))
    u_spec = pl.BlockSpec((1, dk), lambda b_, h_, ci: (h_, 0))
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec, u_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, dk), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dk), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
