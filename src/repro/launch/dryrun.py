import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run BEFORE any other import (jax locks the device count
on first init): the dry-run — and only the dry-run — sees 512 placeholder
host devices so the production meshes can build. Smoke tests and benches
import through normal entry points and see 1 device.

Per cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. installs the sharding rules, derives param/opt/state/batch specs,
  3. ``jit(step).lower(**ShapeDtypeStructs).compile()`` — no allocation,
  4. prints memory_analysis() (fits?) + cost_analysis() (FLOPs/bytes),
  5. parses the post-SPMD HLO for collectives and emits the roofline terms
     as JSON for EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, get_arch, list_archs
from repro.configs.shapes import SHAPES, ShapeConfig, applicable
from repro.models import model as M
from repro.models import transformer as T
from repro.roofline import analysis as RA
from repro.serve.decode import make_prefill_step, make_serve_step
from repro.sharding import rules as SR
from repro.train.optimizer import OptimizerConfig, opt_state_specs
from repro.train.train_step import TrainConfig, make_opt_state, \
    make_train_step


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        tok_shape = (b, 1, cfg.n_codebooks) if cfg.n_codebooks else (b, 1)
        out = {"tokens": jax.ShapeDtypeStruct(tok_shape, i32),
               "cache_len": jax.ShapeDtypeStruct((b,), i32)}
    else:
        tok_shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks else (b, s)
        out = {"tokens": jax.ShapeDtypeStruct(tok_shape, i32)}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct(tok_shape, i32)
    if cfg.family == "vlm":
        out["vision"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.vision_dim), jnp.bfloat16)
    return out


def _named(mesh, spec_tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
               tcfg: TrainConfig, serve_layout: str = "fsdp"):
    """Returns (jitted_fn, example_args) ready for .lower(*args)."""
    rules = SR.AxisRules.for_mesh(mesh)
    SR.set_rules(rules)
    param_shapes = jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.PRNGKey(0))
    resident = serve_layout == "resident" and shape.kind == "decode"
    if resident:
        # serving-optimized: bf16 resident weights, model-axis TP only —
        # no data-axis weight sharding, so no per-token FSDP gathers
        # (§Perf C)
        param_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32
                else s.dtype), param_shapes)
    pspecs = SR.param_specs(cfg, rules, fsdp=not resident,
                            param_shapes=param_shapes)
    batch_sds = input_specs(cfg, shape)
    bspecs = SR.batch_specs(cfg, shape.kind, shape.global_batch, rules,
                            layout=serve_layout if shape.kind == "decode"
                            else "fsdp")

    if shape.kind == "train":
        if tcfg.master_weights:
            # bf16 param storage; fp32 truth in opt_state["master"]
            param_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, jnp.bfloat16 if s.dtype == jnp.float32
                    else s.dtype), param_shapes)
        ocfg = OptimizerConfig()
        step = make_train_step(cfg, tcfg, ocfg)
        opt_shapes = jax.eval_shape(
            functools.partial(make_opt_state, tcfg=tcfg), param_shapes)
        ospecs = opt_state_specs(pspecs, param_shapes, rules, zero=True)
        if tcfg.master_weights:
            ospecs["master"] = ospecs["mu"]
        if tcfg.grad_compression:
            ospecs["residuals"] = pspecs
        fn = jax.jit(step,
                     in_shardings=(_named(mesh, pspecs),
                                   _named(mesh, ospecs),
                                   _named(mesh, bspecs)),
                     out_shardings=(_named(mesh, pspecs),
                                    _named(mesh, ospecs), None),
                     donate_argnums=(0, 1))
        return fn, (param_shapes, opt_shapes, batch_sds)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, attn_impl=tcfg.attn_impl)
        out_spec = NamedSharding(mesh, P(bspecs["tokens"][0], None))
        fn = jax.jit(step,
                     in_shardings=(_named(mesh, pspecs),
                                   _named(mesh, bspecs)),
                     out_shardings=out_spec)
        return fn, (param_shapes, batch_sds)

    # decode
    buffer_len = shape.seq_len
    step = make_serve_step(cfg, buffer_len)
    vision_sds = batch_sds.get("vision")
    if vision_sds is not None:
        state_shapes = jax.eval_shape(
            lambda v, pp: T.init_decode_state(cfg, shape.global_batch,
                                              buffer_len, vision=v,
                                              params=pp),
            vision_sds, param_shapes)
    else:
        state_shapes = jax.eval_shape(
            functools.partial(T.init_decode_state, cfg,
                              shape.global_batch, buffer_len))
    sspecs = SR.decode_state_specs(cfg, shape.global_batch, rules,
                                   layout=serve_layout)
    fn = jax.jit(step,
                 in_shardings=(_named(mesh, pspecs),
                               _named(mesh, sspecs),
                               _named(mesh, bspecs)),
                 out_shardings=(None, _named(mesh, sspecs), None),
                 donate_argnums=(1,))
    return fn, (param_shapes, state_shapes, batch_sds)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, tcfg: TrainConfig = None,
             out_dir: str = "benchmarks/results/dryrun",
             serve_layout: str = "fsdp",
             verbose: bool = True) -> dict:
    from repro.launch.mesh import make_production_mesh
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    label = f"{arch} x {shape_name} x {'multi' if multi_pod else 'single'}-pod"
    if not ok:
        if verbose:
            print(f"[SKIP] {label}: {why}")
        result = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                  "status": "n/a", "reason": why}
        if out_dir:
            out = Path(out_dir)
            out.mkdir(parents=True, exist_ok=True)
            tag = (f"{arch}__{shape_name}__"
                   f"{'multi' if multi_pod else 'single'}")
            (out / f"{tag}.json").write_text(json.dumps(result, indent=1))
        return result
    tcfg = tcfg or TrainConfig()
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    fn, args = build_cell(cfg, shape, mesh, tcfg=tcfg,
                          serve_layout=serve_layout)
    # NamedShardings carry the mesh: no global mesh context needed
    lowered = fn.lower(*args)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
    except Exception as e:  # noqa: BLE001 — CPU backend may not support it
        mem = {"error": str(e)}
    hlo_text = compiled.as_text()
    roof = RA.analyze(compiled, cfg, shape, n_chips, hlo_text=hlo_text)
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "n_chips": int(n_chips),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "roofline": roof.as_dict(),
        "train_config": dataclass_dict(tcfg),
    }
    if verbose:
        print(f"[OK] {label}: chips={n_chips} "
              f"compile={t_compile:.1f}s "
              f"compute={roof.compute_s*1e3:.1f}ms "
              f"memory={roof.memory_s*1e3:.1f}ms "
              f"collective={roof.collective_s*1e3:.1f}ms "
              f"dominant={roof.dominant} "
              f"useful={roof.useful_flops_ratio:.2f} "
              f"roofline_frac={roof.roofline_fraction:.3f}")
        if mem and "error" not in mem:
            print(f"     memory_analysis: {mem}")
    if out_dir:
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
        (out / f"{tag}.json").write_text(json.dumps(result, indent=1))
    SR.set_rules(None)
    return result


def dataclass_dict(tcfg):
    import dataclasses
    return dataclasses.asdict(tcfg) if tcfg else {}


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    tcfg = TrainConfig(remat=args.remat, microbatches=args.microbatches)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, multi_pod=mp, tcfg=tcfg,
                             out_dir=args.out)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[FAIL] {arch} x {shape} x "
                          f"{'multi' if mp else 'single'}: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")


if __name__ == "__main__":
    main()
