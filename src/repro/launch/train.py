"""End-to-end training driver.

On a real pod this binds the production mesh + shardings and runs the
supervised loop; on CPU (default) it trains the reduced config so the whole
path — pipeline -> sharded step -> checkpoints -> fault supervision -> ACAI
provenance — is exercised end to end.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --full \
        --mesh 16x16           # requires a real 256-device runtime
"""
from __future__ import annotations

import argparse
import functools
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import get_arch, list_archs
from repro.core.acai import AcaiProject
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import model as M
from repro.sharding import rules as SR
from repro.train.checkpoints import CheckpointManager
from repro.train.fault import TrainSupervisor
from repro.train.optimizer import OptimizerConfig, opt_state_specs
from repro.train.train_step import (TrainConfig, make_opt_state,
                                    make_train_step)


def build_sharded_train(cfg, tcfg, ocfg, mesh):
    """Production assembly: specs + jit with shardings (used on pods; the
    dry-run lowers exactly this)."""
    rules = SR.AxisRules.for_mesh(mesh)
    SR.set_rules(rules)
    param_shapes = jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.PRNGKey(0))
    pspecs = SR.param_specs(cfg, rules, fsdp=True,
                            param_shapes=param_shapes)
    ospecs = opt_state_specs(pspecs, param_shapes, rules)
    step = make_train_step(cfg, tcfg, ocfg)
    named = lambda t: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), t,
        is_leaf=lambda x: type(x).__name__ == "PartitionSpec")
    return jax.jit(step, in_shardings=(named(pspecs), named(ospecs), None),
                   out_shardings=(named(pspecs), named(ospecs), None),
                   donate_argnums=(0, 1)), pspecs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real accelerator mesh)")
    ap.add_argument("--mesh", default=None, help="e.g. 16x16")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--workdir", default="/tmp/acai-train")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    tcfg = TrainConfig(remat=args.remat)
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=5,
                           total_steps=args.steps, weight_decay=0.0)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh(shape, ("data", "model")[:len(shape)])
        step, _ = build_sharded_train(cfg, tcfg, ocfg, mesh)
    else:
        step = jax.jit(make_train_step(cfg, tcfg, ocfg))

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_opt_state(params, tcfg)
    pipe = TokenPipeline(DataConfig(
        vocab_size=min(cfg.vocab_size, 64), seq_len=args.seq_len,
        global_batch=args.global_batch, markov_temp=2.5), cfg)

    project = AcaiProject("train", Path(args.workdir))
    pipe.register(project, f"{args.arch}-data", creator="trainer")
    ckpt = CheckpointManager(project, f"{args.arch}-run")
    sup = TrainSupervisor(ckpt, save_every=args.save_every)

    def batch_fn(i):
        return jax.tree.map(jnp.asarray, pipe.batch_at(i))

    state, report = sup.run(step, {"params": params, "opt": opt,
                                   "step": 0}, args.steps, batch_fn)
    print(f"done: {report.steps_run} steps, {report.checkpoints} ckpts, "
          f"latest={ckpt.latest_step()}")


if __name__ == "__main__":
    main()
