"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16x16 = 256 chips per pod ("data", "model"); multi-pod
adds a leading "pod" axis (2 x 16 x 16 = 512 chips). The dry-run forces 512
host devices via XLA_FLAGS (see launch/dryrun.py lines 1–2).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (reduced test meshes, provisioner search points)."""
    return jax.make_mesh(shape, axes)


def mesh_for_chips(chips: int, model_axis: int = 16, *,
                   pod_size: int = 256):
    """Auto-provisioner search points: chips -> (pod?, data, model) mesh.
    Chips beyond one pod add a 'pod' axis (inter-pod = DP)."""
    if chips <= pod_size:
        model = min(model_axis, chips)
        data = chips // model
        return make_mesh((data, model), ("data", "model"))
    pods = chips // pod_size
    model = model_axis
    data = pod_size // model
    return make_mesh((pods, data, model), ("pod", "data", "model"))
