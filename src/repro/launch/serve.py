"""Batched serving driver: continuous-batching loop over the one-token
serve step (reduced configs on CPU; the same program the decode dry-run
cells lower for the production meshes).

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --requests 6
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, list_archs
from repro.models import model as M
from repro.models import transformer as T
from repro.serve.decode import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    if cfg.family == "vlm" or cfg.n_codebooks:
        raise SystemExit("demo driver supports token-only archs")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    buf = 32
    states = T.init_decode_state(cfg, args.slots, buf)
    cache_len = jnp.zeros((args.slots,), jnp.int32)
    step = jax.jit(make_serve_step(cfg, buf))

    # continuous batching: slots hold independent requests; finished slots
    # are refilled from the queue without stalling the others
    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab_size, rng.integers(3, 8)).tolist()
             for _ in range(args.requests)]
    slot_req = [-1] * args.slots
    slot_prompt: list[list[int]] = [[] for _ in range(args.slots)]
    produced = {i: [] for i in range(args.requests)}
    cur = np.zeros((args.slots, 1), np.int32)
    next_req = 0
    done = 0

    def refill(s):
        nonlocal next_req
        if next_req < len(queue):
            slot_req[s] = next_req
            slot_prompt[s] = list(queue[next_req])
            cur[s, 0] = slot_prompt[s].pop(0)
            next_req += 1
            return True
        slot_req[s] = -1
        return False

    for s in range(args.slots):
        refill(s)
    cache_len = jnp.zeros((args.slots,), jnp.int32)

    ticks = 0
    while done < len(queue) and ticks < 500:
        ticks += 1
        batch = {"tokens": jnp.asarray(cur), "cache_len": cache_len}
        _, states, nxt = step(params, states, batch)
        cache_len = cache_len + 1
        nxt = np.asarray(nxt)
        for s in range(args.slots):
            r = slot_req[s]
            if r < 0:
                continue
            if slot_prompt[s]:                      # still prefilling
                cur[s, 0] = slot_prompt[s].pop(0)
                continue
            produced[r].append(int(nxt[s]))
            cur[s, 0] = int(nxt[s])
            if len(produced[r]) >= args.max_new:
                done += 1
                # reset this slot's cache and grab the next request
                cache_len = cache_len.at[s].set(0)
                refill(s)
    for r, toks in produced.items():
        print(f"request {r}: prompt={queue[r]} -> {toks}")
    print(f"served {done}/{len(queue)} requests in {ticks} decode ticks "
          f"({args.slots} slots)")


if __name__ == "__main__":
    main()
