"""ACAI CLI (§3.4): every SDK service gets a command.

    python -m repro.core.cli --root /tmp/acai --token <tok> <command> ...

Commands: upload, download, ls, create-file-set, submit, status, wait,
logs, jobs, cluster, find, trace. ``cluster`` renders the per-pool view
(capacity/utilization/placement counts per accelerator pool) and
``submit --pool`` pins a job to one pool. State persists under --root
(tokens in tokens.json for this local deployment). ``submit`` runs a
``module:callable`` through the futures SDK and prints the job id.
Job state persists to the metadata store and log text to the data lake
(``/.logs/<job-id>.log``), and each project engine journals its full
state under ``<root>/<project>/state`` (the durable control plane): a
fresh invocation *recovers* the registry, so ``status``/``wait``/
``logs <job-id>`` are first-class across processes — jobs an
interrupted invocation left non-terminal re-queue and complete on
recovery instead of stranding. ``--after`` accepts parents from past
invocations too — a FINISHED parent is a met dependency, a failed one
refuses the submit."""
from __future__ import annotations

import argparse
import importlib
import json
import sys
from pathlib import Path

from repro.core.acai import AcaiPlatform
from repro.core.engine.handle import JobHandle
from repro.core.engine.registry import JobSpec


def _load_platform(root: Path) -> AcaiPlatform:
    plat = AcaiPlatform(root, durable=True)
    tok_file = root / "tokens.json"
    if tok_file.exists():
        saved = json.loads(tok_file.read_text())
        plat._admin_token = saved["admin"]
        from repro.core.acai import User
        for tok, u in saved["users"].items():
            plat._users[tok] = User(**u)
        for name in saved["projects"]:
            if name not in plat._projects:
                from repro.core.acai import AcaiEngine, AcaiProject
                plat._projects[name] = AcaiProject(name, root / name)
                # durable engine over the project's journaled state:
                # jobs from past invocations recover into the registry,
                # making status/wait/logs first-class cross-process
                plat._engines[name] = AcaiEngine(
                    datalake=plat._projects[name],
                    workroot=str(root / name / "jobs"),
                    durable=root / name / "state")
    return plat


def _save_platform(plat: AcaiPlatform, root: Path) -> None:
    import dataclasses
    (root / "tokens.json").write_text(json.dumps({
        "admin": plat._admin_token,
        "users": {t: dataclasses.asdict(u)
                  for t, u in plat._users.items()},
        "projects": sorted(plat._projects),
    }))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="acai")
    ap.add_argument("--root", default="/tmp/acai-cli")
    ap.add_argument("--token", default=None)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("init", help="create a project; prints admin token")
    sp.add_argument("project")

    sp = sub.add_parser("upload")
    sp.add_argument("path")
    sp.add_argument("file")

    sp = sub.add_parser("download")
    sp.add_argument("ref")

    sub.add_parser("ls")

    sp = sub.add_parser("create-file-set")
    sp.add_argument("name")
    sp.add_argument("specs", nargs="+")

    sp = sub.add_parser("submit", help="submit a job; prints id + state")
    sp.add_argument("name")
    sp.add_argument("--fn", required=True,
                    help="module:callable executed as the job program")
    sp.add_argument("--input-fileset", default=None)
    sp.add_argument("--output-fileset", default=None)
    sp.add_argument("--after", default="",
                    help="comma-separated parent job ids (DAG gating)")
    sp.add_argument("--arg", action="append", default=[],
                    metavar="K=V", help="job arg (JSON values accepted)")
    sp.add_argument("--vcpu", type=float, default=1)
    sp.add_argument("--mem-mb", type=float, default=512)
    sp.add_argument("--pool", default=None,
                    help="pin to one accelerator pool (requires a pools "
                         "deployment; see the `cluster` command)")
    sp.add_argument("--resource", action="append", default=[],
                    metavar="DIM=AMOUNT",
                    help="resource shape overriding --vcpu/--mem-mb "
                         "(repeatable; e.g. --resource chips=8 for a "
                         "TPU pool)")
    sp.add_argument("--no-wait", action="store_true",
                    help="print the handle immediately, don't resolve it")

    for c, h in (("status", "job state"), ("logs", "job log text"),
                 ("wait", "block until the job is terminal")):
        sp = sub.add_parser(c, help=h)
        sp.add_argument("job_id")
        if c == "wait":
            sp.add_argument("--timeout", type=float, default=None)

    sp = sub.add_parser("jobs")
    sp.add_argument("--status", default=None)
    sp.add_argument("--sort-by", default="job_id")

    sub.add_parser("cluster",
                   help="per-pool capacity/utilization/placement + "
                        "queue-wait metrics")

    sp = sub.add_parser("find")
    sp.add_argument("conditions", nargs="+",
                    help="key=value or key>value / key<value")

    sp = sub.add_parser("trace")
    sp.add_argument("fileset_ref", nargs="?")
    sp.add_argument("--forward", action="store_true")

    args = ap.parse_args(argv)
    root = Path(args.root)
    root.mkdir(parents=True, exist_ok=True)
    plat = _load_platform(root)

    if args.cmd == "init":
        tok = plat.create_project(plat.admin_token, args.project)
        _save_platform(plat, root)
        print(tok)
        return 0

    if not args.token:
        print("--token required", file=sys.stderr)
        return 2
    proj = plat.project(args.token)
    user = plat.authenticate(args.token)

    if args.cmd == "upload":
        ref = proj.upload(args.path, Path(args.file).read_bytes(),
                          creator=user.name)
        print(ref)
    elif args.cmd == "download":
        sys.stdout.buffer.write(proj.storage.download(args.ref))
    elif args.cmd == "ls":
        for p in proj.storage.list_files():
            print(f"{p}  versions={proj.storage.versions(p)}")
        for s in proj.filesets.list_sets():
            print(f"@{s}  versions="
                  f"{[v.version for v in proj.filesets._sets[s]]}")
    elif args.cmd == "create-file-set":
        print(proj.create_file_set(args.name, args.specs,
                                   creator=user.name))
    elif args.cmd == "submit":
        mod, _, fn_name = args.fn.partition(":")
        fn = getattr(importlib.import_module(mod), fn_name)
        job_args = {}
        for kv in args.arg:
            k, _, v = kv.partition("=")
            try:
                v = json.loads(v)
            except json.JSONDecodeError:
                pass
            job_args[k] = v
        # the registry is per-process (each invocation submits one job),
        # so --after is a pre-submit gate over persisted terminal state;
        # in-process scheduler gating needs the ROADMAP's persistent
        # registry
        for pid in [j for j in args.after.split(",") if j]:
            past = proj.metadata.get(pid).get("state")
            if past == "FINISHED":
                continue
            if past is None:
                print(f"unknown parent job {pid}", file=sys.stderr)
            else:
                print(f"refusing submit: parent {pid} ended {past}",
                      file=sys.stderr)
            return 1
        if args.pool and plat.engine(args.token).scheduler.placement \
                is None:
            # silently dropping the pin would run the job anywhere
            print(f"--pool {args.pool} requires a pools deployment; "
                  f"this engine has no placement layer", file=sys.stderr)
            return 2
        resources = {"vcpu": args.vcpu, "mem_mb": args.mem_mb}
        if args.resource:
            resources = {}
            for kv in args.resource:
                k, sep, v = kv.partition("=")
                try:
                    if not (k and sep):
                        raise ValueError
                    resources[k] = float(v)
                except ValueError:
                    print(f"--resource expects DIM=AMOUNT with a numeric "
                          f"amount, got {kv!r}", file=sys.stderr)
                    return 2
        handle = plat.submit_job(args.token, JobSpec(
            name=args.name, project="", user="", fn=fn,
            input_fileset=args.input_fileset,
            output_fileset=args.output_fileset,
            args=job_args, pool=args.pool, resources=resources))
        state = handle.status() if args.no_wait else handle.wait()
        print(f"{handle.job_id} {state.value}")
    elif args.cmd in ("status", "wait", "logs"):
        # cancel is SDK-only (JobHandle.cancel): the registry is
        # per-process, so by the time a second invocation could cancel,
        # the job is already terminal
        eng = plat.engine(args.token)
        in_registry = True
        try:
            job = eng.registry.get(args.job_id)
        except KeyError:
            in_registry = False
        if args.cmd == "logs":
            log = job.outputs.get("log") if in_registry else None
            if log is None:
                # the agent persists log text to the data lake
                try:
                    log = proj.storage.download(
                        f"/.logs/{args.job_id}.log").decode()
                except Exception:
                    log = None
            if log is None:
                if not in_registry and not proj.metadata.get(args.job_id):
                    print(f"unknown job {args.job_id}", file=sys.stderr)
                    return 1
                log = ""
            sys.stdout.write(log)
        elif in_registry:
            h = JobHandle(job, eng)
            state = h.wait(args.timeout) if args.cmd == "wait" \
                else h.status()
            line = state.value
            if args.cmd == "status":
                # answer "why" without a second lookup: retry count and
                # last failure reason ride the status line
                if job.retries:
                    line += f" retries={job.retries}"
                if job.error:
                    why = str(job.error).strip().splitlines()[-1][:120]
                    line += f" error={why}"
            print(line)
        else:
            # past invocation: the registry is per-process, read metadata
            doc = proj.metadata.get(args.job_id)
            if not doc:
                print(f"unknown job {args.job_id}", file=sys.stderr)
                return 1
            state = doc.get("state")
            if state is None:
                # registered but no terminal state persisted: submitted by
                # an interrupted or still-running invocation
                if args.cmd == "wait":
                    print(f"{args.job_id} has no terminal state recorded "
                          f"(owning process interrupted or still running)",
                          file=sys.stderr)
                    return 1
                state = "SUBMITTED"
            line = state
            if args.cmd == "status":
                if doc.get("retries"):
                    line += f" retries={doc['retries']}"
                if doc.get("error"):
                    line += f" error={doc['error']}"
            print(line)
    elif args.cmd == "jobs":
        from repro.core.engine.dashboard import job_history
        eng = plat.engine(args.token)
        print(job_history(eng.registry, proj.metadata,
                          status=args.status, sort_by=args.sort_by))
    elif args.cmd == "cluster":
        from repro.core.engine.dashboard import scheduler_page
        eng = plat.engine(args.token)
        print(scheduler_page(eng.scheduler, eng.monitor))
    elif args.cmd == "find":
        conds = {}
        for c in args.conditions:
            if ">" in c:
                k, v = c.split(">", 1)
                conds[k] = (">", float(v))
            elif "<" in c:
                k, v = c.split("<", 1)
                conds[k] = ("<", float(v))
            else:
                k, v = c.split("=", 1)
                try:
                    v = float(v)
                except ValueError:
                    pass
                conds[k] = v
        for aid in proj.metadata.find(**conds):
            print(aid, json.dumps({k: v for k, v in
                                   proj.metadata.get(aid).items()
                                   if v is not None}))
    elif args.cmd == "trace":
        from repro.core.engine.dashboard import provenance_page
        print(provenance_page(
            proj.provenance, args.fileset_ref,
            direction="forward" if args.forward else "backward"))
    _save_platform(plat, root)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
