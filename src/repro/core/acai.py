"""ACAI facade: credential server + project workspaces + SDK surface.

Mirrors the paper's public surface (§3.1, §3.4, §4.1): a global admin
creates projects; each project has an admin user who creates member users;
every request carries a user token which the credential server resolves to
(user, project) before dispatch. Per-project state (storage, filesets,
metadata, provenance) is isolated; the execution engine is shared.
"""
from __future__ import annotations

import dataclasses
import secrets
import warnings
from pathlib import Path
from typing import Callable, Optional

from repro.core.datalake.fileset import FileSetManager
from repro.core.datalake.metadata import MetadataStore
from repro.core.datalake.provenance import ProvenanceGraph
from repro.core.datalake.storage import Storage
from repro.core.engine.cluster import Cluster
from repro.core.engine.events import EventBus
from repro.core.engine.placement import Placement
from repro.core.engine.handle import JobHandle, wait_all
from repro.core.engine.launcher import (LocalRunner, ThreadPoolRunner,
                                        VirtualRunner)
from repro.core.engine.monitor import JobMonitor
from repro.core.engine.pipeline import Pipeline
from repro.core.engine.registry import JobRegistry, JobSpec
from repro.core.engine.scheduler import Scheduler
from repro.core.provision.autoprovision import AutoProvisioner
from repro.core.provision.pricing import CPU_PRICING, Pricing
from repro.core.provision.profiler import Profiler


class AuthError(RuntimeError):
    pass


@dataclasses.dataclass
class User:
    name: str
    project: str
    token: str
    is_admin: bool = False


class AcaiProject:
    """Isolated workspace: data lake + metadata + provenance."""

    def __init__(self, name: str, root):
        self.name = name
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        self.storage = Storage(root)
        self.metadata = MetadataStore(root)
        self.provenance = ProvenanceGraph(root)
        self.filesets = FileSetManager(self.storage, self.provenance)

    # SDK conveniences -------------------------------------------------
    def upload(self, path: str, data: bytes, creator: str = "") -> str:
        fv = self.storage.upload(path, data, creator)
        self.metadata.register(f"{path}@{fv.version}", kind="file",
                               creator=creator)
        return f"{path}@{fv.version}"

    def create_file_set(self, name: str, specs: list[str],
                        creator: str = "") -> str:
        fsv = self.filesets.create(name, specs, creator)
        self.metadata.register(fsv.ref, kind="fileset", creator=creator)
        return fsv.ref


class AcaiEngine:
    """Execution engine assembly: registry + scheduler + launcher + monitor.

    ``pricing`` is either one ``Pricing`` (homogeneous deployment, at most
    one capacity cluster) or a catalog ``{family: Pricing}`` — then
    ``cluster_nodes`` (an int for every family, or ``{family: nodes}``)
    builds one ``Cluster`` pool per family and a ``Placement`` layer
    chooses a pool per job (profiler-fed via :meth:`use_profiler`).
    """

    def __init__(self, *, datalake: Optional[AcaiProject] = None,
                 pricing: Pricing | dict[str, Pricing] = CPU_PRICING,
                 quota_k: int = 2,
                 virtual: bool = False,
                 oracle: Optional[Callable] = None,
                 workroot: str = "/tmp/acai-jobs",
                 runner: Optional[str] = None, max_workers: int = 4,
                 cluster: Optional[Cluster] = None,
                 cluster_nodes: Optional[int | dict[str, int]] = None,
                 placement: Optional[Placement] = None,
                 placement_objective: str = "cost",
                 policy: str = "fair", backfill: bool = True,
                 usage_halflife: Optional[float] = None,
                 preemption: bool = False,
                 starvation_threshold: float = 300.0,
                 quarantine_threshold: int = 3,
                 user_failure_budget: Optional[int] = None,
                 checkpoint_interval: Optional[float] = None,
                 durable: Optional[str | Path] = None,
                 snapshot_every: int = 1000,
                 recover: bool = True):
        # durable control plane: ``durable=<dir>`` turns on the
        # write-ahead journal + snapshot store (the paper's Redis-backed
        # engine state). Every submit/transition/preempt/resize records
        # through it, the event stream persists, and building an engine
        # over a non-empty state dir recovers: terminal jobs adopt as-is,
        # non-terminal ones re-queue as new epochs with their checkpoint
        # progress intact (``self.recovery`` holds the report).
        store = journal = None
        had_state = False
        if durable is not None:
            from repro.core.engine.durable import FileStore, Journal
            store = FileStore(durable)
            journal = Journal(store, snapshot_every=snapshot_every)
            had_state = journal.has_state()
        self.store = store
        self.journal = journal
        self.recovery = None
        self.bus = EventBus(store=store)
        self.datalake = datalake
        self.registry = JobRegistry(
            metadata=datalake.metadata if datalake else None,
            journal=journal)
        runner = runner or ("virtual" if virtual else "local")
        if runner == "virtual":
            self.launcher = VirtualRunner(
                self.registry, self.bus, oracle=oracle, pricing=pricing,
                checkpoint_interval=checkpoint_interval)
        elif runner == "thread":
            self.launcher = ThreadPoolRunner(self.registry, self.bus,
                                             datalake=datalake,
                                             pricing=pricing,
                                             workroot=workroot,
                                             max_workers=max_workers)
        elif runner == "local":
            self.launcher = LocalRunner(self.registry, self.bus,
                                        datalake=datalake, pricing=pricing,
                                        workroot=workroot)
        elif runner == "subprocess":
            from repro.core.engine.durable.runner import SubprocessRunner
            self.launcher = SubprocessRunner(self.registry, self.bus,
                                             datalake=datalake,
                                             pricing=pricing,
                                             workdir=workroot)
        else:
            raise ValueError(f"unknown runner {runner!r}")
        catalog = pricing if isinstance(pricing, dict) else None
        if catalog and placement is None and cluster_nodes is None:
            # without pools there is no placement and billing would fall
            # back to an arbitrary catalog entry — refuse loudly
            raise ValueError(
                "a pricing catalog needs cluster_nodes (int or "
                "{family: nodes}) or an explicit placement= to build "
                "its pools; pass a single Pricing for a pool-less engine")
        if placement is None and catalog and cluster_nodes is not None:
            nodes = cluster_nodes if isinstance(cluster_nodes, dict) \
                else {fam: cluster_nodes for fam in catalog}
            pools = {fam: Cluster.from_pricing(p, nodes=nodes[fam],
                                               name=fam)
                     for fam, p in catalog.items() if nodes.get(fam)}
            placement = Placement(pools, pricing=catalog,
                                  objective=placement_objective)
        if cluster is None and placement is None \
                and cluster_nodes is not None and not catalog:
            cluster = Cluster.from_pricing(pricing, nodes=cluster_nodes)
        self.scheduler = Scheduler(self.registry, self.launcher, self.bus,
                                   quota_k=quota_k, cluster=cluster,
                                   placement=placement,
                                   policy=policy, backfill=backfill,
                                   usage_halflife=usage_halflife,
                                   preemption=preemption,
                                   starvation_threshold=starvation_threshold,
                                   quarantine_threshold=quarantine_threshold,
                                   user_failure_budget=user_failure_budget)
        self.cluster = cluster
        self.monitor = JobMonitor(self.bus, registry=self.registry)
        self.pricing = pricing
        if journal is not None:
            from repro.core.engine.durable import (attach_terminal_recorder,
                                                   snapshot_state)
            from repro.core.engine.durable.recovery import recover as \
                _recover
            self.launcher.journal = journal
            self.scheduler.journal = journal
            journal.snapshot_source = lambda: snapshot_state(self)
            # subscribed after the scheduler + monitor: by the time a
            # terminal event reaches the recorder, the runner's finalize
            # has committed outputs/billing, so the ``final`` journal
            # record carries authoritative values
            attach_terminal_recorder(self.bus, journal, self.registry)
            if recover and had_state:
                self.recovery = _recover(self)

    @property
    def pools(self) -> dict[str, Cluster]:
        return self.scheduler.pools

    def use_profiler(self, profiler, *, feedback: bool = False) -> None:
        """Feed a profiler's runtime predictions into pool placement
        (no-op without a placement layer). ``feedback=True`` also closes
        the loop: every FINISHED job's measured runtime is folded back
        into the profiler's per-pool model (``"<tmpl>@<pool>"``) via
        ``add_observation``, so cold-start priors and mispredictions
        self-correct online. Off by default — scheduling decisions are
        bit-identical to a feedback-less engine until opted in."""
        if self.scheduler.placement is not None:
            self.scheduler.placement.use_profiler(profiler)
        if feedback:
            profiler.attach_feedback(self.bus, self.registry)

    def submit(self, spec: JobSpec, *, pipeline: str = "") -> JobHandle:
        """Submit a job; returns a JobHandle future. Declared dependencies
        (``spec.depends_on``) are recorded as provenance edges before the
        job runs and gate its launch in the scheduler."""
        parents = []
        for pid in dict.fromkeys(spec.depends_on or ()):
            try:
                parents.append(self.registry.get(pid))
            except KeyError:
                # validated before the job is created: a bad dependency
                # must not leave a zombie QUEUED job behind
                raise ValueError(f"job {spec.name!r} depends on unknown "
                                 f"job {pid!r}") from None
        if self.scheduler.placement is not None:
            # like bad dependencies, a pool name that doesn't exist is a
            # caller typo — reject before the job is created rather than
            # burning a job id on a guaranteed-infeasible submit
            known = self.scheduler.placement.pools
            bad = [p for p in {spec.pool, *(spec.pool_resources or ())}
                   if p is not None and p not in known]
            if bad:
                raise ValueError(
                    f"job {spec.name!r} names unknown pool(s) "
                    f"{sorted(bad)!r}; available: {sorted(known)!r}")
        job = self.registry.submit(spec)
        if self.datalake is not None:
            for parent in parents:
                self.datalake.provenance.add_dependency_edge(
                    src_job=parent.job_id, dst_job=job.job_id,
                    pipeline=pipeline,
                    src_fileset=parent.spec.output_fileset,
                    dst_fileset=spec.input_fileset)
        self.scheduler.submit(job)
        return JobHandle(job, self)

    def pipeline(self, name: str = "pipeline") -> Pipeline:
        """A DAG builder whose stages submit to this engine."""
        return Pipeline(self, name=name)

    def wait_all(self, handles: Optional[list[JobHandle]] = None,
                 timeout: Optional[float] = None):
        """Resolve the given handles (or drain every pending job)."""
        if handles is not None:
            return wait_all(handles, timeout)
        if hasattr(self.launcher, "pending"):
            self.scheduler.run_to_completion()
        return None

    def run_all(self) -> None:
        """Deprecated: drain the engine. Prefer keeping the JobHandles
        from submit() and calling ``wait_all(handles)`` / ``h.result()``."""
        warnings.warn("AcaiEngine.run_all() is deprecated; use the "
                      "JobHandle futures returned by submit() "
                      "(wait_all(handles), handle.result())",
                      DeprecationWarning, stacklevel=2)
        self.wait_all()


class _UserEngine:
    """Engine view bound to a user token: specs submitted through it are
    stamped with the token's (project, user) exactly like ``submit_job``.
    Everything else (registry, scheduler, monitor, ...) proxies to the
    project's engine — the profiler's fleets run as the requesting user
    without hand-rolled submit shims."""

    def __init__(self, platform: "AcaiPlatform", token: str):
        self._platform = platform
        self._token = token
        self._engine = platform.engine(token)

    def submit(self, spec: JobSpec, **kw) -> JobHandle:
        return self._platform.submit_job(self._token, spec, **kw)

    def __getattr__(self, name):
        return getattr(self._engine, name)


class AcaiPlatform:
    """Credential server + project/user management (§3.1, §4.1)."""

    def __init__(self, root: str | Path, *,
                 pricing: Pricing | dict[str, Pricing] = CPU_PRICING,
                 virtual: bool = False, oracle=None, quota_k: int = 2,
                 runner: Optional[str] = None, max_workers: int = 4,
                 cluster_nodes: Optional[int | dict[str, int]] = None,
                 policy: str = "fair", backfill: bool = True,
                 usage_halflife: Optional[float] = None,
                 durable: bool = False):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._users: dict[str, User] = {}      # token -> user
        self._projects: dict[str, AcaiProject] = {}
        self._engines: dict[str, AcaiEngine] = {}
        self._admin_token = secrets.token_hex(8)
        self._pricing = pricing
        self._virtual = virtual
        self._oracle = oracle
        self._quota_k = quota_k
        self._runner = runner
        self._max_workers = max_workers
        self._cluster_nodes = cluster_nodes
        self._policy = policy
        self._backfill = backfill
        self._usage_halflife = usage_halflife
        # durable=True journals each project engine's state under
        # <root>/<project>/state, so a fresh process over the same root
        # (the CLI) recovers jobs instead of starting empty
        self._durable = durable

    # -- credential server ----------------------------------------------
    @property
    def admin_token(self) -> str:
        return self._admin_token

    def authenticate(self, token: str) -> User:
        user = self._users.get(token)
        if user is None:
            raise AuthError("invalid token")
        return user

    def create_project(self, admin_token: str, name: str) -> str:
        """Global admin creates a project + its admin user; returns the
        project-admin token."""
        if admin_token != self._admin_token:
            raise AuthError("only the global administrator creates projects")
        if name in self._projects:
            raise ValueError(f"project {name} exists")
        self._projects[name] = AcaiProject(name, self.root / name)
        self._engines[name] = AcaiEngine(
            datalake=self._projects[name], pricing=self._pricing,
            virtual=self._virtual, oracle=self._oracle,
            quota_k=self._quota_k, runner=self._runner,
            max_workers=self._max_workers,
            cluster_nodes=self._cluster_nodes,
            policy=self._policy, backfill=self._backfill,
            usage_halflife=self._usage_halflife,
            workroot=str(self.root / name / "jobs"),
            durable=(self.root / name / "state") if self._durable
            else None)
        return self.create_user(None, name, f"{name}-admin", _admin=True)

    def create_user(self, admin_token: Optional[str], project: str,
                    username: str, _admin: bool = False) -> str:
        if not _admin:
            admin = self.authenticate(admin_token)
            if not (admin.is_admin and admin.project == project):
                raise AuthError("only the project administrator creates users")
        token = secrets.token_hex(8)
        self._users[token] = User(username, project, token, is_admin=_admin)
        return token

    # -- authenticated SDK dispatch ---------------------------------------
    def project(self, token: str) -> AcaiProject:
        return self._projects[self.authenticate(token).project]

    def engine(self, token: str) -> AcaiEngine:
        return self._engines[self.authenticate(token).project]

    def submit_job(self, token: str, spec: JobSpec, *,
                   pipeline: str = "") -> JobHandle:
        user = self.authenticate(token)
        spec.project = user.project
        spec.user = user.name
        return self._engines[user.project].submit(spec, pipeline=pipeline)

    def pipeline(self, token: str, name: str = "pipeline") -> Pipeline:
        """A DAG builder bound to the caller: stage specs are stamped with
        the token's (project, user) at submit, like ``submit_job``."""
        eng = self.engine(token)
        return Pipeline(eng, name=name,
                        submit=lambda spec: self.submit_job(
                            token, spec, pipeline=name))

    def make_profiler(self, token: str, quorum: float = 0.95,
                      priority: int = 0) -> Profiler:
        prof = Profiler(_UserEngine(self, token), quorum=quorum,
                        priority=priority)
        # profiler-fed placement: predictions flow into the project's pool
        # scoring as soon as models are fit (no-op on single-pool engines)
        self.engine(token).use_profiler(prof)
        return prof

    def make_autoprovisioner(self, token: str,
                             profiler: Profiler) -> AutoProvisioner:
        return AutoProvisioner(profiler, self._pricing)
