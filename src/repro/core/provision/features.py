"""Per-family feature spaces for TPU-job profiling templates (DESIGN.md §6).

The paper's command-template "hints" become architecture-aware resource
dimensions: every family profiles (steps, chips, hbm_gb); MoE families add
the expert-parallel width, long-context serving adds the KV sharding width.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.core.provision.profiler import CommandTemplate


def template_for(cfg: ArchConfig, shape_name: str,
                 steps_hints=(50, 100, 200),
                 chips_hints=(8, 32, 128),
                 hbm_hints=(4, 8, 16)) -> CommandTemplate:
    hints = {"steps": list(steps_hints)}
    resources = {"chips": list(chips_hints), "hbm_gb": list(hbm_hints)}
    if cfg.moe is not None:
        # EP width must divide the expert count
        resources["ep_width"] = [w for w in (2, 4, 8, 16)
                                 if cfg.moe.n_experts % w == 0]
    if shape_name == "long_500k" and cfg.subquadratic:
        resources["kv_shard"] = [16, 64, 256]
    return CommandTemplate(name=f"{cfg.name}-{shape_name}", hints=hints,
                           resource_hints=resources)
