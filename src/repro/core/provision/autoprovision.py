"""Auto-provisioner (ACAI §3.3.2, §4.2.4): constrained grid search over the
discrete resource space using the profiler's predictions.

Two tasks, exactly as the paper:
  optimize runtime  s.t. predicted cost    <= max_cost
  optimize cost     s.t. predicted runtime <= max_runtime

With a pricing *catalog* (``{pool_name: Pricing}``, one per accelerator
family) the search spans every pool's grid: each candidate is a
(pool, resources) pair, runtimes come from the pool's model
(``"<template>@<pool>"`` when profiled, the family-agnostic template
otherwise), and the decision records which pool won — the provisioning
half of the placement layer's cost/speed frontier.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

from repro.core.provision.pricing import Pricing
from repro.core.provision.profiler import Profiler


@dataclasses.dataclass
class ProvisionDecision:
    resources: dict[str, float]
    predicted_runtime: float
    predicted_cost: float
    # full search table for Fig.16-style visualization / audits
    table: list[dict[str, Any]]
    objective: str
    pool: str = "default"           # the accelerator family that won

    @property
    def feasible(self) -> bool:
        return bool(self.resources)


class AutoProvisioner:
    def __init__(self, profiler: Profiler,
                 pricing: Union[Pricing, dict[str, Pricing]]):
        self.profiler = profiler
        self.pricing = pricing      # as given (legacy callers read it)
        self.catalog: dict[str, Pricing] = \
            pricing if isinstance(pricing, dict) else {"default": pricing}

    def _template_for(self, template_name: str, pool: str) -> str:
        """The pool's own profiled model when one exists, else the
        family-agnostic template."""
        if pool != "default":
            cand = Profiler.pool_template(template_name, pool)
            if getattr(self.profiler, "has_model", lambda n: False)(cand):
                return cand
        return template_name

    def _search(self, template_name: str, values: dict[str, float],
                *, max_cost: Optional[float], max_runtime: Optional[float],
                objective: str) -> ProvisionDecision:
        table = []
        best = None
        for pool, pricing in self.catalog.items():
            tname = self._template_for(template_name, pool)
            for resources in pricing.grid():
                cfg = dict(values)
                cfg.update(resources)
                t = self.profiler.predict(tname, cfg)
                c = pricing.job_cost(resources, t)
                ok = ((max_cost is None or c <= max_cost)
                      and (max_runtime is None or t <= max_runtime))
                table.append({**resources, "pool": pool, "runtime": t,
                              "cost": c, "feasible": ok})
                if not ok:
                    continue
                key = t if objective == "runtime" else c
                if best is None or key < best[0]:
                    best = (key, pool, resources, t, c)
        if best is None:
            return ProvisionDecision({}, float("nan"), float("nan"),
                                     table, objective)
        _, pool, resources, t, c = best
        return ProvisionDecision(dict(resources), t, c, table, objective,
                                 pool=pool)

    def optimize_runtime(self, template_name: str,
                         values: dict[str, float],
                         max_cost: float) -> ProvisionDecision:
        return self._search(template_name, values, max_cost=max_cost,
                            max_runtime=None, objective="runtime")

    def optimize_cost(self, template_name: str, values: dict[str, float],
                      max_runtime: float) -> ProvisionDecision:
        return self._search(template_name, values, max_cost=None,
                            max_runtime=max_runtime, objective="cost")

    # -- beyond-paper: active refinement ---------------------------------
    def refined_search(self, template_name: str, values: dict[str, float],
                       *, measure_fn, objective: str = "runtime",
                       max_cost: Optional[float] = None,
                       max_runtime: Optional[float] = None,
                       rounds: int = 3, tol: float = 0.10) -> tuple[
                           ProvisionDecision, list[dict]]:
        """Search -> measure the winning config with ONE real profiling run
        -> if the prediction was off by > tol, add the observation, refit,
        re-search. Fixes the paper's extrapolation failure (its §5.1 Fig.15
        non-linearity: the model is trusted far outside the profiled hull
        — on pods that's the collective wall) at the cost of <= ``rounds``
        extra profiling jobs. Returns (decision, refinement_history)."""
        history = []
        dec = self._search(template_name, values, max_cost=max_cost,
                           max_runtime=max_runtime, objective=objective)
        for _ in range(rounds):
            if not dec.feasible:
                break
            cfg = dict(values)
            cfg.update(dec.resources)
            true_t = measure_fn(cfg)
            err = abs(dec.predicted_runtime - true_t) / max(true_t, 1e-9)
            history.append({"resources": dict(dec.resources),
                            "predicted_runtime": dec.predicted_runtime,
                            "measured_runtime": true_t, "rel_err": err})
            if err <= tol:
                break
            self.profiler.add_observation(template_name, cfg, true_t)
            dec = self._search(template_name, values, max_cost=max_cost,
                               max_runtime=max_runtime, objective=objective)
        return dec, history
