"""Pallas kernel block-size autotuner (ROADMAP item 2, perf_hillclimb idiom).

The four seed kernels (flash attention, decode attention, mamba2 SSD,
RWKV6) all expose block/chunk sizes chosen for the MXU's 128x128 systolic
array. The best size depends on the accelerator family and the problem
shape (VMEM working set vs grid-step overhead), so this module runs a
deterministic hillclimb over each kernel's candidate ladder, seeded from
the MXU-aligned defaults, and persists the winners in a tuning cache
(``BENCH_kernels.json``: best config + achieved fraction of the roofline
ceiling per (kernel, shape, family)).

Determinism: candidate measurements are memoized, neighbors are visited
in sorted parameter order, and a move requires beating the incumbent by
``HYSTERESIS`` — given the same measurements the search walks the same
path. Tests inject a synthetic ``measure`` function to pin the walk
exactly; CI runs the interpret-mode path (hermetic, no TPU) where
timings rank grid overhead rather than MXU behavior but every candidate
is still validated numerically against ``kernels/ref.py``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Callable, Optional

from repro.roofline.prior import HardwareSpec, roofline_ceiling_s

HYSTERESIS = 0.03        # a neighbor must win by >=3% to displace the
                         # incumbent — timing-noise damper + determinism
MAX_STEPS = 8            # hillclimb iterations (ladders are short)
BYTES_F32 = 4


# -- kernel registry -----------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One tunable kernel: candidate ladders, input builder, reference.

    ``build(shape, seed)`` returns ``(args, ref_out)``;
    ``call(cfg, interpret, *args)`` runs the Pallas kernel;
    ``cost(shape)`` returns analytic (flops, hbm_bytes) for the roofline
    ceiling; ``divides_seq`` names params that must divide the sequence
    length (kernels whose grids cannot pad)."""
    name: str
    ladders: dict[str, tuple[int, ...]]
    default: dict[str, int]
    build: Callable[[dict, int], tuple]
    call: Callable[..., object]
    cost: Callable[[dict], tuple[float, float]]
    divides_seq: tuple[str, ...] = ()
    tol: float = 2e-2


def _keys(seed: int, n: int):
    import jax
    return jax.random.split(jax.random.PRNGKey(seed), n)


def _build_flash(shape: dict, seed: int):
    import jax
    from repro.kernels import ref
    b, s, h, kv, d = (shape[k] for k in ("b", "s", "h", "kv", "d"))
    ks = _keys(seed, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    return (q, k, v), ref.attention_ref(q, k, v)


def _call_flash(cfg, interpret, q, k, v):
    from repro.kernels import ops
    return ops.flash_attention(q, k, v, block_q=cfg["block_q"],
                               block_k=cfg["block_k"], interpret=interpret)


def _cost_flash(shape: dict) -> tuple[float, float]:
    b, s, h, kv, d = (shape[k] for k in ("b", "s", "h", "kv", "d"))
    flops = 4.0 * b * h * s * s * d * 0.5          # causal: half the pairs
    nbytes = BYTES_F32 * b * s * d * (2 * h + 2 * kv)   # q+o, k+v
    return flops, nbytes


def _build_decode(shape: dict, seed: int):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    b, s, h, kv, d = (shape[k] for k in ("b", "s", "h", "kv", "d"))
    ks = _keys(seed, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, s, kv, d))
    vc = jax.random.normal(ks[2], (b, s, kv, d))
    clen = jnp.asarray([(s * 3) // 4 - 37 * i for i in range(b)], jnp.int32)
    want = ref.decode_attention_ref(
        jnp.swapaxes(q, 1, 2)[:, :, 0], jnp.swapaxes(kc, 1, 2),
        jnp.swapaxes(vc, 1, 2), clen)[:, None]
    return (q, kc, vc, clen), want


def _call_decode(cfg, interpret, q, kc, vc, clen):
    from repro.kernels import ops
    return ops.decode_attention(q, kc, vc, clen, block_k=cfg["block_k"],
                                interpret=interpret)


def _cost_decode(shape: dict) -> tuple[float, float]:
    b, s, h, kv, d = (shape[k] for k in ("b", "s", "h", "kv", "d"))
    flops = 4.0 * b * h * s * d
    nbytes = BYTES_F32 * b * s * d * 2 * kv        # the KV cache dominates
    return flops, nbytes


def _build_ssd(shape: dict, seed: int):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    b, s, h, p, n = (shape[k] for k in ("b", "s", "h", "p", "n"))
    ks = _keys(seed, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, 1, n)) * 0.5
    D = jnp.ones((h,))
    return (x, dt, A, B, C, D), ref.ssd_ref(x, dt, A, B, C, D)


def _call_ssd(cfg, interpret, *args):
    from repro.kernels import ops
    return ops.mamba2_ssd(*args, chunk=cfg["chunk"], interpret=interpret)


def _cost_ssd(shape: dict) -> tuple[float, float]:
    b, s, h, p, n = (shape[k] for k in ("b", "s", "h", "p", "n"))
    chunk = 128
    flops = 2.0 * b * h * s * (chunk * (n + p) + 2 * n * p)
    nbytes = BYTES_F32 * b * s * (h * 2 * p + 2 * n + h)
    return flops, nbytes


def _build_wkv6(shape: dict, seed: int):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    b, s, h, k = (shape[kk] for kk in ("b", "s", "h", "k"))
    ks = _keys(seed, 5)
    r = jax.random.normal(ks[0], (b, s, h, k)) * 0.5
    kk_ = jax.random.normal(ks[1], (b, s, h, k)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, k)) * 0.5
    logw = -jnp.exp(jax.random.uniform(ks[3], (b, s, h, k),
                                       minval=-7.0, maxval=-0.7))
    u = jax.random.normal(ks[4], (h, k)) * 0.3
    return (r, kk_, v, logw, u), ref.wkv6_ref(r, kk_, v, logw, u)


def _call_wkv6(cfg, interpret, *args):
    from repro.kernels import ops
    return ops.wkv6(*args, chunk=cfg["chunk"], interpret=interpret)


def _cost_wkv6(shape: dict) -> tuple[float, float]:
    b, s, h, k = (shape[kk] for kk in ("b", "s", "h", "k"))
    chunk = 128
    flops = 2.0 * b * h * s * (2 * chunk * k + 2 * k * k)
    nbytes = BYTES_F32 * b * s * h * k * 5
    return flops, nbytes


KERNELS: dict[str, KernelSpec] = {
    "flash_attention": KernelSpec(
        "flash_attention",
        ladders={"block_q": (32, 64, 128, 256),
                 "block_k": (32, 64, 128, 256)},
        default={"block_q": 128, "block_k": 128},
        build=_build_flash, call=_call_flash, cost=_cost_flash),
    "decode_attention": KernelSpec(
        "decode_attention",
        ladders={"block_k": (128, 256, 512, 1024)},
        default={"block_k": 512},
        build=_build_decode, call=_call_decode, cost=_cost_decode,
        divides_seq=("block_k",)),
    "mamba2_ssd": KernelSpec(
        "mamba2_ssd",
        ladders={"chunk": (32, 64, 128, 256)},
        default={"chunk": 128},
        build=_build_ssd, call=_call_ssd, cost=_cost_ssd,
        divides_seq=("chunk",)),
    "rwkv6": KernelSpec(
        "rwkv6",
        ladders={"chunk": (32, 64, 128, 256)},
        default={"chunk": 128},
        build=_build_wkv6, call=_call_wkv6, cost=_cost_wkv6,
        divides_seq=("chunk",)),
}


def legal(spec: KernelSpec, shape: dict, cfg: dict) -> bool:
    """A candidate is legal when every param is on its ladder, fits the
    sequence, and (for pad-less kernels) divides it."""
    s = shape["s"]
    for p, v in cfg.items():
        if v not in spec.ladders[p] or v > s:
            return False
        if p in spec.divides_seq and s % v:
            return False
    return True


def seed_config(spec: KernelSpec, shape: dict) -> dict:
    """The MXU-aligned default, stepped down each ladder until legal for
    this shape (e.g. chunk 128 -> 64 for a 192-long sequence)."""
    cfg = dict(spec.default)
    for p in cfg:
        ladder = spec.ladders[p]
        i = ladder.index(cfg[p])
        while i >= 0 and not legal(spec, shape, {**cfg, p: ladder[i]}):
            i -= 1
        if i < 0:
            raise ValueError(
                f"{spec.name}: no legal {p} for shape {shape}")
        cfg[p] = ladder[i]
    return cfg


# -- deterministic hillclimb --------------------------------------------
def hillclimb(spec: KernelSpec, shape: dict,
              measure: Callable[[dict], float], *,
              start: Optional[dict] = None,
              max_steps: int = MAX_STEPS) -> tuple[dict, float, int]:
    """Greedy coordinate descent from the seeded default: per step, time
    every +-1 ladder neighbor (sorted param order, memoized) and move to
    the best one iff it beats the incumbent by ``HYSTERESIS``. Returns
    (best_config, best_seconds, candidates_measured)."""
    memo: dict[tuple, float] = {}

    def key(cfg):
        return tuple(sorted(cfg.items()))

    def timed(cfg):
        k = key(cfg)
        if k not in memo:
            memo[k] = measure(cfg)
        return memo[k]

    cur = dict(start) if start else seed_config(spec, shape)
    cur_t = timed(cur)
    for _ in range(max_steps):
        best_cfg, best_t = cur, cur_t
        for p in sorted(spec.ladders):
            ladder = spec.ladders[p]
            i = ladder.index(cur[p])
            for j in (i - 1, i + 1):
                if not 0 <= j < len(ladder):
                    continue
                cand = {**cur, p: ladder[j]}
                if not legal(spec, shape, cand):
                    continue
                t = timed(cand)
                if t < best_t * (1.0 - HYSTERESIS):
                    best_cfg, best_t = cand, t
        if best_cfg == cur:
            break
        cur, cur_t = best_cfg, best_t
    return cur, cur_t, len(memo)


# -- measurement ---------------------------------------------------------
def _interpret_measure(spec: KernelSpec, args, *, interpret: bool,
                       reps: int = 3) -> Callable[[dict], float]:
    """Median-of-reps wall time per call (after a warm/compile call)."""
    import jax

    def measure(cfg: dict) -> float:
        jax.block_until_ready(spec.call(cfg, interpret, *args))
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(spec.call(cfg, interpret, *args))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2]
    return measure


def max_abs_err(spec: KernelSpec, args, ref_out, cfg: dict,
                interpret: bool) -> float:
    import jax.numpy as jnp
    out = spec.call(cfg, interpret, *args)
    return float(jnp.abs(out - ref_out).max())


def default_family() -> str:
    """The accelerator family tuning runs against; ``interpret`` when no
    real TPU backend is attached (CI / CPU hosts)."""
    try:
        import jax
        if jax.devices()[0].platform == "tpu":
            return "tpu"
    except Exception:  # noqa: BLE001 — jax absent/broken: still hermetic
        pass
    return "interpret"


# interpret-mode "hardware": CPU-interpreter constants so the recorded
# roofline fraction is well-defined (tiny — it measures the interpreter,
# not silicon) without pretending CI timings are TPU timings.
INTERPRET_HW = HardwareSpec("interpret", peak_flops=50e9, hbm_bw=20e9,
                            ici_bw=1.0)
FAMILY_HW: dict[str, HardwareSpec] = {"interpret": INTERPRET_HW}


def _family_hw(family: str) -> HardwareSpec:
    if family in FAMILY_HW:
        return FAMILY_HW[family]
    from repro.roofline.prior import TPU_V5E
    return TPU_V5E if family.startswith("tpu") else INTERPRET_HW


# -- the tuning cache ----------------------------------------------------
def shape_key(shape: dict) -> str:
    return ",".join(f"{k}={shape[k]}" for k in sorted(shape))


def cache_key(kernel: str, shape: dict, family: str) -> str:
    return f"{kernel}|{shape_key(shape)}|{family}"


class TuningCache:
    """Persisted (kernel, shape, family) -> tuning entry map.

    The JSON layout is the committed ``BENCH_kernels.json``: a dict of
    ``kernel|shape|family`` keys, each holding the winning config, the
    timings that won it, the achieved fraction of the roofline ceiling,
    and the max error vs the reference kernel."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: dict[str, dict] = {}
        if path:
            self.load(path)

    def load(self, path: str) -> "TuningCache":
        self.path = path
        try:
            with open(path) as f:
                blob = json.load(f)
            self.entries = dict(blob.get("entries", blob))
        except (OSError, json.JSONDecodeError):
            self.entries = {}
        return self

    def save(self, path: Optional[str] = None) -> None:
        path = path or self.path
        assert path, "TuningCache.save: no path"
        with open(path, "w") as f:
            json.dump({"entries": dict(sorted(self.entries.items()))},
                      f, indent=1, sort_keys=True)

    def put(self, entry: dict) -> None:
        self.entries[cache_key(entry["kernel"], entry["shape"],
                               entry["family"])] = entry

    def get(self, kernel: str, shape: dict,
            family: str) -> Optional[dict]:
        return self.entries.get(cache_key(kernel, shape, family))

    def best_config(self, kernel: str, shape: dict, family: str,
                    default: Optional[dict] = None) -> Optional[dict]:
        """The tuned config for an exact (kernel, shape, family) hit,
        else ``default`` (callers pass the kernel's MXU default)."""
        e = self.get(kernel, shape, family)
        return dict(e["config"]) if e else default


# -- the tuner entry point ----------------------------------------------
def autotune(kernel: str, shape: dict, *,
             family: Optional[str] = None, interpret: bool = True,
             seed: int = 0, reps: int = 3,
             measure: Optional[Callable[[dict], float]] = None,
             cache: Optional[TuningCache] = None) -> dict:
    """Tune one (kernel, shape) for ``family`` and return (and cache)
    the tuning entry. ``measure`` overrides the timing function (tests
    inject deterministic synthetic costs)."""
    spec = KERNELS[kernel]
    family = family or default_family()
    args, ref_out = spec.build(shape, seed)
    if measure is None:
        measure = _interpret_measure(spec, args, interpret=interpret,
                                     reps=reps)
    default = seed_config(spec, shape)
    # one memoized timing per config, shared between the default
    # measurement and the hillclimb: the same config must never carry
    # two (noisy) timings, or speedup_vs_default could dip below 1.0
    # for the config the climb never left
    memo: dict[tuple, float] = {}

    def timed(cfg: dict) -> float:
        k = tuple(sorted(cfg.items()))
        if k not in memo:
            memo[k] = measure(cfg)
        return memo[k]

    default_t = timed(default)
    best, best_t, n_meas = hillclimb(spec, shape, timed, start=default)
    err = max_abs_err(spec, args, ref_out, best, interpret)
    hw = _family_hw(family)
    flops, nbytes = spec.cost(shape)
    ceiling = roofline_ceiling_s(flops, nbytes, hw)
    entry = {
        "kernel": kernel, "shape": dict(shape), "family": family,
        "config": best, "default_config": default,
        "us": best_t * 1e6, "default_us": default_t * 1e6,
        "speedup_vs_default": default_t / max(best_t, 1e-12),
        "candidates_measured": n_meas,
        "roofline_ceiling_us": ceiling * 1e6,
        "roofline_fraction": ceiling / max(best_t, 1e-12),
        "max_err": err, "tol": spec.tol,
        "mode": "interpret" if interpret else "compiled",
    }
    assert err <= spec.tol, \
        f"{kernel}{shape}: tuned config {best} diverges from ref " \
        f"(err {err:.3e} > {spec.tol})"
    if not math.isfinite(best_t):
        raise RuntimeError(f"{kernel}: non-finite timing")
    if cache is not None:
        cache.put(entry)
    return entry


# shapes the bench/CI smoke tunes — small enough for interpret mode,
# ragged/odd-head-dim cases included on purpose (they exercise the
# flash padding path the tuner depends on)
SMOKE_SHAPES: dict[str, list[dict]] = {
    "flash_attention": [
        {"b": 1, "s": 256, "h": 4, "kv": 2, "d": 64},
        {"b": 1, "s": 192, "h": 2, "kv": 2, "d": 80},
    ],
    "decode_attention": [{"b": 2, "s": 1024, "h": 4, "kv": 2, "d": 64}],
    "mamba2_ssd": [{"b": 1, "s": 256, "h": 4, "p": 64, "n": 32}],
    "rwkv6": [{"b": 1, "s": 256, "h": 2, "k": 64}],
}


def autotune_all(*, family: Optional[str] = None, interpret: bool = True,
                 seed: int = 0, reps: int = 3,
                 shapes: Optional[dict[str, list[dict]]] = None,
                 cache: Optional[TuningCache] = None) -> list[dict]:
    shapes = shapes or SMOKE_SHAPES
    out = []
    for kernel, shape_list in shapes.items():
        for shape in shape_list:
            out.append(autotune(kernel, shape, family=family,
                                interpret=interpret, seed=seed,
                                reps=reps, cache=cache))
    return out
