"""Cloud pricing model (ACAI §4.3, Fig. 11).

The paper bills each resource dimension separately with a unit price that
RISES LINEARLY with the amount provisioned: 2/3 of the GCP baseline at the
minimum allocation up to 4/3 at the maximum (discourages vertical scaling).

Two concrete pricings ship:
  CPU_PRICING — the paper's original space: 0.5–8 vCPU (step .5),
                512–8192 MB (step 256); GCP N1 us-east1 baselines.
  TPU_PRICING — the TPU-pod adaptation: chips 8–512 (powers of two) and
                per-chip HBM GB; v5e-class on-demand baseline.

A heterogeneous deployment holds one catalog entry per accelerator
family (``default_catalog()``): the engine builds one capacity pool per
family and the placement layer scores jobs across them, so each family's
node shapes and unit prices stay independent.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any


@dataclasses.dataclass(frozen=True)
class ResourceDim:
    name: str
    minimum: float
    maximum: float
    base_unit_price: float          # $ per unit-hour at the GCP baseline
    values: tuple[float, ...]       # discrete allocatable amounts

    def unit_price(self, amount: float) -> float:
        """2/3 .. 4/3 of baseline, linear in the provisioned amount."""
        frac = (amount - self.minimum) / max(self.maximum - self.minimum,
                                             1e-12)
        return self.base_unit_price * (2.0 / 3.0 + (2.0 / 3.0) * frac)


def _steps(lo: float, hi: float, step: float) -> tuple[float, ...]:
    out, x = [], lo
    while x <= hi + 1e-9:
        out.append(round(x, 6))
        x += step
    return tuple(out)


class Pricing:
    def __init__(self, dims: list[ResourceDim], family: str = "default"):
        self.dims = {d.name: d for d in dims}
        self.family = family            # accelerator family (pool name)

    def job_cost(self, resources: dict[str, Any], runtime_s: float) -> float:
        """Total_cost = sum_r unit_cost(r) * amount(r) * hours (paper §5.1.2)."""
        hours = runtime_s / 3600.0
        total = 0.0
        for name, dim in self.dims.items():
            amt = float(resources.get(name, dim.minimum))
            total += dim.unit_price(amt) * amt * hours
        return total

    def hourly_rate(self, resources: dict[str, Any]) -> float:
        return self.job_cost(resources, 3600.0)

    def grid(self) -> list[dict[str, float]]:
        names = list(self.dims)
        combos = itertools.product(*(self.dims[n].values for n in names))
        return [dict(zip(names, c)) for c in combos]


# the paper's original space (GCP N1 us-east1 baselines, $/unit-hr)
CPU_PRICING = Pricing([
    ResourceDim("vcpu", 0.5, 8.0, 0.033174, _steps(0.5, 8.0, 0.5)),
    ResourceDim("mem_mb", 512, 8192, 0.004446 / 1024.0,
                _steps(512, 8192, 256)),
], family="cpu")

class ChipScaledPricing(Pricing):
    """TPU pricing: secondary dims (per-chip HBM reservation) scale with the
    chip count — cost = hours * (mu_chip(c)*c + mu_hbm(h)*h*c)."""

    def job_cost(self, resources: dict[str, Any], runtime_s: float) -> float:
        hours = runtime_s / 3600.0
        chips = float(resources.get("chips", self.dims["chips"].minimum))
        total = self.dims["chips"].unit_price(chips) * chips
        for name, dim in self.dims.items():
            if name == "chips":
                continue
            amt = float(resources.get(name, dim.minimum))
            total += dim.unit_price(amt) * amt * chips
        return total * hours


# TPU-pod adaptation: chips replace vCPUs, reserved per-chip HBM replaces MB
TPU_PRICING = ChipScaledPricing([
    ResourceDim("chips", 8, 512, 1.20,
                (8, 16, 32, 64, 128, 256, 512)),
    ResourceDim("hbm_gb", 2, 16, 0.02, _steps(2, 16, 2)),
], family="tpu")


def spot_pricing(pricing: Pricing, discount: float = 0.6,
                 family: str | None = None) -> Pricing:
    """A spot/preemptible catalog entry derived from an on-demand one:
    the same resource dimensions at ``(1 - discount)`` x the unit price
    (GCP spot VMs run 60–91 % below on-demand). The concrete pricing
    subclass is preserved, so chip-scaled TPU pricing stays chip-scaled.
    Pair it with a ``Cluster(spot=True, reclaim_rate=...)`` pool: the
    placement layer prices the reclamation risk into the discount."""
    if not 0.0 < discount < 1.0:
        raise ValueError(f"discount must be in (0, 1), got {discount}")
    dims = [dataclasses.replace(d,
                                base_unit_price=d.base_unit_price
                                * (1.0 - discount))
            for d in pricing.dims.values()]
    return type(pricing)(dims, family or f"{pricing.family}-spot")


def default_catalog() -> dict[str, "Pricing"]:
    """One pricing per accelerator family — the pool catalog the engine
    turns into a heterogeneous deployment (``pricing=default_catalog()``,
    one ``Cluster`` per entry, placement choosing among them)."""
    return {"cpu": CPU_PRICING, "tpu": TPU_PRICING}
