"""Elastic pool provisioning (ACAI §3.3.2's loop applied to capacity).

The paper's headline (1.7x speed-up, 39 % cost cut) comes from a
provisioning loop that keeps just enough of the right hardware running.
``ElasticController`` is that loop for the engine's capacity pools: it
watches each pool's utilization pressure and queued demand and grows or
shrinks the pool in whole-node steps between ``min_nodes`` and
``max_nodes``, through ``Scheduler.resize_pool`` — so a shrink below live
reservations drains through the same checkpoint-aware preemption path a
spot reclamation uses, and a grow immediately re-dispatches the backlog.
Resizable gangs (``GangSpec.min_pods > 0``) soften those drains: the
scheduler first shrinks running gangs to ``k`` pods (freeing capacity
with no requeue and no lost work) and only preempts whole jobs for
whatever overage remains.

The controller is deliberately clock-agnostic: ``step(now)`` is called by
whoever owns time (the benchmark's virtual-clock loop, a wall-clock
daemon thread, a test). Decisions are recorded with timestamps, which
makes the *provisioned* cost of a run computable (node-hours per pool x
the node's hourly rate) — the number an elastic deployment actually
optimizes, as opposed to the per-job billing that ignores idle capacity.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class PoolPolicy:
    """Scaling knobs for one pool.

    ``node_shape`` is the capacity one node contributes per dimension
    (pool capacity = nodes x shape). ``grow_at``/``shrink_at`` are
    max-dimension utilization thresholds; growth additionally requires
    queued demand for the pool (high utilization with an empty queue is
    a pool doing its job, not pressure), and shrink requires none. An
    over-committed pool (utilization ``inf`` after an external shrink or
    reclaim) counts as pressure. ``cooldown_s`` spaces scale operations
    so one burst cannot thrash the pool.
    """
    node_shape: dict[str, float]
    min_nodes: int = 1
    max_nodes: int = 8
    grow_at: float = 0.85
    shrink_at: float = 0.25
    step_nodes: int = 1
    cooldown_s: float = 120.0


@dataclasses.dataclass
class ScaleDecision:
    at: float
    pool: str
    action: str                 # "grow" | "shrink"
    nodes_before: int
    nodes_after: int
    reason: str


class ElasticController:
    """Grows/shrinks scheduler pools from utilization pressure."""

    def __init__(self, scheduler, policies: dict[str, PoolPolicy]):
        self.scheduler = scheduler
        self.policies = dict(policies)
        self.decisions: list[ScaleDecision] = []
        self._nodes: dict[str, int] = {}
        self._last_op: dict[str, float] = {}
        self._t0 = scheduler._now()
        for pool, pol in self.policies.items():
            cl = scheduler.pools[pool]
            # infer the current node count from capacity / node shape
            # (the max across dims tolerates a partially-shaped pool)
            counts = [cl.capacity.get(d, 0.0) / amt
                      for d, amt in pol.node_shape.items() if amt > 0]
            self._nodes[pool] = max(1, int(round(max(counts, default=1))))

    def nodes(self, pool: str) -> int:
        return self._nodes[pool]

    def _pressure(self, pool: str) -> float:
        """Max-dimension utilization; ``inf`` (over-commit) is pressure."""
        util = self.scheduler.pools[pool].utilization()
        return max(util.values(), default=0.0)

    def step(self, now: Optional[float] = None) -> list[ScaleDecision]:
        """One control round over every managed pool; returns the scale
        decisions taken (possibly empty)."""
        out: list[ScaleDecision] = []
        now = self.scheduler._now() if now is None else now
        for pool, pol in self.policies.items():
            if now - self._last_op.get(pool, float("-inf")) < pol.cooldown_s:
                continue
            util = self._pressure(pool)
            queued = self.scheduler.queued_demand(pool)
            n = self._nodes[pool]
            if util >= pol.grow_at and queued > 0 and n < pol.max_nodes:
                new = min(pol.max_nodes, n + pol.step_nodes)
                action, reason = "grow", f"util={util:.2f} queued={queued}"
            elif util <= pol.shrink_at and queued == 0 and n > pol.min_nodes:
                new = max(pol.min_nodes, n - pol.step_nodes)
                action, reason = "shrink", f"util={util:.2f} idle"
            else:
                continue
            cap = {d: amt * new for d, amt in pol.node_shape.items()}
            self.scheduler.resize_pool(pool, cap)
            self._nodes[pool] = new
            self._last_op[pool] = now
            dec = ScaleDecision(now, pool, action, n, new, reason)
            self.decisions.append(dec)
            out.append(dec)
        return out

    # -- provisioned-cost accounting ------------------------------------
    def node_hours(self, until: float) -> dict[str, float]:
        """Integral of the node count over time per managed pool, from
        controller construction to ``until`` — what the deployment
        actually paid for, idle or not."""
        out: dict[str, float] = {}
        for pool in self.policies:
            t = self._t0
            # reconstruct the initial count from the decision log (the
            # first decision's nodes_before), falling back to current
            decs = [d for d in self.decisions if d.pool == pool]
            n = decs[0].nodes_before if decs else self._nodes[pool]
            total = 0.0
            for d in decs:
                total += n * max(0.0, d.at - t)
                t, n = d.at, d.nodes_after
            total += n * max(0.0, until - t)
            out[pool] = total / 3600.0
        return out

    def provisioned_cost(self, until: float,
                         node_rate: dict[str, float]) -> float:
        """Dollars of provisioned capacity: node-hours x each pool's
        per-node hourly rate (``node_rate[pool]``)."""
        hours = self.node_hours(until)
        return sum(h * node_rate.get(p, 0.0) for p, h in hours.items())
