"""Profiler: learning to predict runtime (ACAI §4.2.2–§4.2.3).

The user supplies a command template with hints (sets of values per
argument); the profiler launches |cpus||mems|∏|opts_i| profiling jobs
through the execution engine, waits for a 95 % quorum (straggler policy),
and fits the paper's log-linear model

    log y = log alpha + sum_i beta_i log x_i

by least squares over the explored grid. ``predict`` is the serving
endpoint the auto-provisioner queries.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Optional

import numpy as np


@dataclasses.dataclass
class CommandTemplate:
    """'python train.py --epoch {1,2,5} ...' + resource exploration sets."""
    name: str
    hints: dict[str, list[float]]             # arg -> candidate values
    resource_hints: dict[str, list[float]]    # resource dim -> explored set

    def grid(self) -> list[dict[str, float]]:
        names = list(self.hints) + list(self.resource_hints)
        spaces = [self.hints[n] for n in self.hints] + \
                 [self.resource_hints[n] for n in self.resource_hints]
        return [dict(zip(names, combo))
                for combo in itertools.product(*spaces)]

    @property
    def feature_names(self) -> list[str]:
        return list(self.hints) + list(self.resource_hints)


class LogLinearModel:
    """y = alpha * prod_i x_i^beta_i, fit in log space (paper §4.2.3)."""

    def __init__(self, feature_names: list[str]):
        self.feature_names = feature_names
        self.coef: Optional[np.ndarray] = None    # [log alpha, betas...]

    def _design(self, configs: list[dict[str, float]]) -> np.ndarray:
        X = np.ones((len(configs), 1 + len(self.feature_names)))
        for i, c in enumerate(configs):
            for j, n in enumerate(self.feature_names):
                X[i, 1 + j] = math.log(max(float(c[n]), 1e-12))
        return X

    def fit(self, configs: list[dict[str, float]],
            runtimes: list[float]) -> "LogLinearModel":
        X = self._design(configs)
        y = np.log(np.maximum(np.asarray(runtimes, float), 1e-12))
        self.coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        return self

    def predict(self, config: dict[str, float]) -> float:
        X = self._design([config])
        return float(np.exp(X @ self.coef)[0])

    def predict_many(self, configs: list[dict[str, float]]) -> np.ndarray:
        return np.exp(self._design(configs) @ self.coef)

    # -- evaluation metrics (paper Table 1) -----------------------------
    @staticmethod
    def errors(pred: np.ndarray, true: np.ndarray) -> dict[str, float]:
        pred, true = np.asarray(pred, float), np.asarray(true, float)
        l1 = float(np.abs(pred - true).mean())
        l2 = float(((pred - true) ** 2).mean())
        var = float(((true - true.mean()) ** 2).mean())
        return {"l1": l1, "l2": l2,
                "variance_explained": 1.0 - l2 / max(var, 1e-12)}


class Profiler:
    """Drives profiling fleets through the engine and serves predictions."""

    def __init__(self, engine, quorum: float = 0.95, priority: int = 0):
        # engine: repro.core.acai.AcaiEngine (registry+scheduler facade)
        # priority: scheduling priority stamped on profiling jobs — the
        # fleets are small and short, ideal backfill candidates, so
        # platforms typically submit them below training priority.
        self.engine = engine
        self.quorum = quorum
        self.priority = priority
        self.models: dict[str, LogLinearModel] = {}
        self.training_sets: dict[str, tuple[list[dict], list[float]]] = {}

    def profile(self, template: CommandTemplate,
                job_factory: Callable[[dict[str, float]], "Any"],
                ) -> LogLinearModel:
        """job_factory(config) -> JobSpec for one profiling run."""
        grid = template.grid()
        specs = [job_factory(cfg) for cfg in grid]
        for spec in specs:
            if not spec.priority:
                spec.priority = self.priority
        jobs = [self.engine.submit(spec) for spec in specs]
        res = self.engine.scheduler.run_until_quorum(
            [j.job_id for j in jobs], frac=self.quorum)
        configs, runtimes = [], []
        for cfg, job in zip(grid, jobs):
            j = self.engine.registry.get(job.job_id)
            if j.state.value == "FINISHED" and j.runtime is not None:
                configs.append(cfg)
                runtimes.append(j.runtime)
        model = LogLinearModel(template.feature_names).fit(configs, runtimes)
        self.models[template.name] = model
        self.training_sets[template.name] = (configs, runtimes)
        return model

    def fit_offline(self, template: CommandTemplate,
                    configs: list[dict[str, float]],
                    runtimes: list[float]) -> LogLinearModel:
        """Fit directly from measured (config, runtime) pairs — used by the
        CPU-measured reproduction bench and by compile-based oracles."""
        model = LogLinearModel(template.feature_names).fit(configs, runtimes)
        self.models[template.name] = model
        self.training_sets[template.name] = (configs, runtimes)
        return model

    def add_observation(self, template_name: str, config: dict[str, float],
                        runtime: float) -> None:
        """Active refinement: fold one new measured run into the model."""
        configs, runtimes = self.training_sets[template_name]
        configs.append(dict(config))
        runtimes.append(float(runtime))
        self.models[template_name] = LogLinearModel(
            self.models[template_name].feature_names).fit(configs, runtimes)

    # the "endpoint for querying the runtime of a command template"
    def predict(self, template_name: str, config: dict[str, float]) -> float:
        return self.models[template_name].predict(config)

    def has_model(self, template_name: str) -> bool:
        return template_name in self.models

    # -- heterogeneous pools ---------------------------------------------
    # Per-family runtime models are plain templates named
    # "<template>@<pool>" (fit them with profile()/fit_offline() on that
    # pool's resource dims); placement and the auto-provisioner fall back
    # to the family-agnostic model when a pool was never profiled.
    @staticmethod
    def pool_template(template_name: str, pool: str) -> str:
        return f"{template_name}@{pool}"

    def predict_for_pool(self, template_name: str, pool: str,
                         config: dict[str, float]) -> float:
        name = self.pool_template(template_name, pool)
        if name not in self.models:
            name = template_name
        return self.models[name].predict(config)
