"""Profiler: learning to predict runtime (ACAI §4.2.2–§4.2.3).

The user supplies a command template with hints (sets of values per
argument); the profiler launches |cpus||mems|∏|opts_i| profiling jobs
through the execution engine, waits for a 95 % quorum (straggler policy),
and fits the paper's log-linear model

    log y = log alpha + sum_i beta_i log x_i

by least squares over the explored grid. ``predict`` is the serving
endpoint the auto-provisioner queries.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Optional

import numpy as np


@dataclasses.dataclass
class CommandTemplate:
    """'python train.py --epoch {1,2,5} ...' + resource exploration sets."""
    name: str
    hints: dict[str, list[float]]             # arg -> candidate values
    resource_hints: dict[str, list[float]]    # resource dim -> explored set

    def grid(self) -> list[dict[str, float]]:
        names = list(self.hints) + list(self.resource_hints)
        spaces = [self.hints[n] for n in self.hints] + \
                 [self.resource_hints[n] for n in self.resource_hints]
        return [dict(zip(names, combo))
                for combo in itertools.product(*spaces)]

    @property
    def feature_names(self) -> list[str]:
        return list(self.hints) + list(self.resource_hints)


class LogLinearModel:
    """y = alpha * prod_i x_i^beta_i, fit in log space (paper §4.2.3).

    With ``clamp=True`` predictions are clamped to the explored grid:
    feature values outside the fitted hull are clipped to it (in log
    space) and the output is bounded to ``[y_min / slack, y_max * slack]``
    of the training runtimes. A log-linear model extrapolates as a power
    law, so a config far off-grid produces unbounded runtimes — fine for
    the auto-provisioner's refine loop (which *measures* the winning
    config and corrects, and whose exact-extrapolation behavior is
    pinned), poison for placement scores served blind. The profiler's
    placement-serving endpoint (``predict_for_pool``) therefore clamps;
    raw ``predict`` keeps the seed's exact extrapolation by default.
    """

    EXTRAPOLATION_SLACK = 8.0     # output bound: [y_min/8, y_max*8]

    def __init__(self, feature_names: list[str], clamp: bool = False):
        self.feature_names = feature_names
        self.clamp = clamp
        self.coef: Optional[np.ndarray] = None    # [log alpha, betas...]
        self._f_lo: Optional[np.ndarray] = None   # per-feature log bounds
        self._f_hi: Optional[np.ndarray] = None
        self._y_lo: float = 0.0                   # runtime bounds (seconds)
        self._y_hi: float = float("inf")

    def _design(self, configs: list[dict[str, float]]) -> np.ndarray:
        X = np.ones((len(configs), 1 + len(self.feature_names)))
        for i, c in enumerate(configs):
            for j, n in enumerate(self.feature_names):
                X[i, 1 + j] = math.log(max(float(c[n]), 1e-12))
        return X

    def fit(self, configs: list[dict[str, float]],
            runtimes: list[float],
            weights: Optional[list[float]] = None) -> "LogLinearModel":
        """Least squares in log space; ``weights`` (optional, one per
        observation) makes it weighted least squares — the online
        feedback path uses recency weights so stale measurements fade."""
        X = self._design(configs)
        y = np.log(np.maximum(np.asarray(runtimes, float), 1e-12))
        if len(configs) > 1:
            self._f_lo = X[:, 1:].min(axis=0)
            self._f_hi = X[:, 1:].max(axis=0)
        slack = self.EXTRAPOLATION_SLACK
        self._y_lo = float(min(runtimes)) / slack
        self._y_hi = float(max(runtimes)) * slack
        if weights is not None:
            w = np.sqrt(np.maximum(np.asarray(weights, float), 1e-12))
            X = X * w[:, None]
            y = y * w
        self.coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        return self

    def in_hull(self, config: dict[str, float],
                slack: float = 2.0) -> bool:
        """Whether ``config`` sits within the explored feature hull
        (each feature inside ``[lo / slack, hi * slack]``). A model fit
        from fewer than two configs has no hull and never contains
        anything — one point is not support. Callers use this to decide
        when a fitted model's (clamped) extrapolation is still more
        trustworthy than an analytic prior."""
        if self._f_lo is None:
            return False
        x = self._design([config])[0, 1:]
        pad = math.log(max(slack, 1.0))
        return bool(np.all(x >= self._f_lo - pad)
                    and np.all(x <= self._f_hi + pad))

    def _predict_design(self, configs: list[dict[str, float]],
                        clamp: bool) -> np.ndarray:
        X = self._design(configs)
        if clamp and self._f_lo is not None:
            X[:, 1:] = np.clip(X[:, 1:], self._f_lo, self._f_hi)
        return X

    def predict(self, config: dict[str, float],
                clamp: Optional[bool] = None) -> float:
        if self.coef is None:
            raise RuntimeError(
                f"LogLinearModel({self.feature_names}): predict before fit")
        clamp = self.clamp if clamp is None else clamp
        y = float(np.exp(self._predict_design([config], clamp)
                         @ self.coef)[0])
        if clamp:
            y = min(max(y, self._y_lo), self._y_hi)
        return y

    def predict_many(self, configs: list[dict[str, float]],
                     clamp: Optional[bool] = None) -> np.ndarray:
        if self.coef is None:
            raise RuntimeError(
                f"LogLinearModel({self.feature_names}): predict before fit")
        clamp = self.clamp if clamp is None else clamp
        y = np.exp(self._predict_design(configs, clamp) @ self.coef)
        if clamp:
            y = np.clip(y, self._y_lo, self._y_hi)
        return y

    # -- evaluation metrics (paper Table 1) -----------------------------
    @staticmethod
    def errors(pred: np.ndarray, true: np.ndarray) -> dict[str, float]:
        pred, true = np.asarray(pred, float), np.asarray(true, float)
        l1 = float(np.abs(pred - true).mean())
        l2 = float(((pred - true) ** 2).mean())
        var = float(((true - true.mean()) ** 2).mean())
        return {"l1": l1, "l2": l2,
                "variance_explained": 1.0 - l2 / max(var, 1e-12)}


class Profiler:
    """Drives profiling fleets through the engine and serves predictions.

    ``prior`` (a ``repro.roofline.prior.RooflinePrior``) supplies
    analytical cold-start estimates: ``predict_for_pool`` serves the
    prior whenever no fitted model exists for the template, so placement
    on a cold cluster scores real physics instead of ``1.0``-second
    defaults. ``recency_halflife`` (observation count) makes online
    refits recency-weighted: an observation ``k`` runs old carries
    weight ``0.5 ** (k / halflife)``, so drifting pools re-learn instead
    of averaging stale history forever. ``window`` caps each template's
    retained observations (oldest dropped) to bound refit cost.
    """

    def __init__(self, engine, quorum: float = 0.95, priority: int = 0,
                 prior=None, recency_halflife: Optional[float] = None,
                 window: int = 512):
        # engine: repro.core.acai.AcaiEngine (registry+scheduler facade)
        # priority: scheduling priority stamped on profiling jobs — the
        # fleets are small and short, ideal backfill candidates, so
        # platforms typically submit them below training priority.
        self.engine = engine
        self.quorum = quorum
        self.priority = priority
        self.prior = prior
        self.recency_halflife = recency_halflife
        self.window = window
        self.models: dict[str, LogLinearModel] = {}
        self.training_sets: dict[str, tuple[list[dict], list[float]]] = {}
        # where the last predict_for_pool answer came from:
        # "pool-model" | "model" | "prior" (placement surfaces this
        # in its fallback stats)
        self.last_source: Optional[str] = None

    def profile(self, template: CommandTemplate,
                job_factory: Callable[[dict[str, float]], "Any"],
                ) -> LogLinearModel:
        """job_factory(config) -> JobSpec for one profiling run."""
        grid = template.grid()
        specs = [job_factory(cfg) for cfg in grid]
        for spec in specs:
            if not spec.priority:
                spec.priority = self.priority
        jobs = [self.engine.submit(spec) for spec in specs]
        res = self.engine.scheduler.run_until_quorum(
            [j.job_id for j in jobs], frac=self.quorum)
        configs, runtimes = [], []
        for cfg, job in zip(grid, jobs):
            j = self.engine.registry.get(job.job_id)
            if j.state.value == "FINISHED" and j.runtime is not None:
                configs.append(cfg)
                runtimes.append(j.runtime)
        model = LogLinearModel(template.feature_names).fit(configs, runtimes)
        self.models[template.name] = model
        self.training_sets[template.name] = (configs, runtimes)
        return model

    def fit_offline(self, template: CommandTemplate,
                    configs: list[dict[str, float]],
                    runtimes: list[float]) -> LogLinearModel:
        """Fit directly from measured (config, runtime) pairs — used by the
        CPU-measured reproduction bench and by compile-based oracles."""
        model = LogLinearModel(template.feature_names).fit(configs, runtimes)
        self.models[template.name] = model
        self.training_sets[template.name] = (configs, runtimes)
        return model

    def add_observation(self, template_name: str, config: dict[str, float],
                        runtime: float) -> None:
        """Active refinement: fold one new measured run into the model.

        A template never seen before bootstraps a fresh training set
        (features = the observation's numeric keys) — this is how the
        launcher feedback loop grows per-pool models on a cold cluster.
        The refit is recency-weighted when ``recency_halflife`` is set
        and the retained history is capped at ``window`` observations.
        """
        if template_name not in self.training_sets:
            self.training_sets[template_name] = ([], [])
        configs, runtimes = self.training_sets[template_name]
        configs.append(dict(config))
        runtimes.append(float(runtime))
        if self.window and len(configs) > self.window:
            del configs[:len(configs) - self.window]
            del runtimes[:len(runtimes) - self.window]
        if template_name in self.models:
            features = self.models[template_name].feature_names
        else:
            features = sorted(k for k, v in config.items()
                              if isinstance(v, (int, float)))
        weights = None
        if self.recency_halflife:
            n = len(runtimes)
            weights = [0.5 ** ((n - 1 - i) / self.recency_halflife)
                       for i in range(n)]
        self.models[template_name] = LogLinearModel(features).fit(
            configs, runtimes, weights)

    # the "endpoint for querying the runtime of a command template"
    def predict(self, template_name: str, config: dict[str, float]) -> float:
        return self.models[template_name].predict(config)

    def has_model(self, template_name: str) -> bool:
        return template_name in self.models

    # -- heterogeneous pools ---------------------------------------------
    # Per-family runtime models are plain templates named
    # "<template>@<pool>" (fit them with profile()/fit_offline() on that
    # pool's resource dims); placement and the auto-provisioner fall back
    # to the family-agnostic model when a pool was never profiled.
    @staticmethod
    def pool_template(template_name: str, pool: str) -> str:
        return f"{template_name}@{pool}"

    def resolve_source(self, template_name: str, pool: str,
                       config: Optional[dict] = None) -> Optional[str]:
        """Which estimator ``predict_for_pool`` would serve from:
        ``"pool-model"`` (fitted ``<tmpl>@<pool>``), ``"model"``
        (family-agnostic fit), ``"prior"`` (roofline cold-start), or
        None (no estimate — placement falls back to declared duration).
        A fitted model beats the prior *inside its measured support*:
        with ``config`` given, a model whose explored hull does not
        contain the config defers to the prior (when one can estimate) —
        a model fit on 30-second profiling runs has nothing trustworthy
        to say about an hour-long training job, while the roofline
        arithmetic extrapolates by construction."""
        prior_ok = self.prior is not None and \
            self.prior.can_estimate(template_name, pool)

        def trusted(name: str) -> bool:
            if config is None or not prior_ok:
                return True
            return self.models[name].in_hull(config)
        pool_name = self.pool_template(template_name, pool)
        if pool_name in self.models and trusted(pool_name):
            return "pool-model"
        if template_name in self.models and trusted(template_name):
            return "model"
        if prior_ok:
            return "prior"
        # an out-of-hull model with no prior still serves (clamped):
        # a bounded estimate beats the silent 1.0-second default
        if pool_name in self.models:
            return "pool-model"
        if template_name in self.models:
            return "model"
        return None

    def predict_for_pool(self, template_name: str, pool: str,
                         config: dict[str, float]) -> float:
        """Per-pool prediction with fitted-model > prior precedence
        (within the model's explored hull — see ``resolve_source``);
        raises (KeyError) when neither exists, which placement's
        predictor wrapper treats as 'no prediction'."""
        src = self.resolve_source(template_name, pool, config)
        self.last_source = src
        if src == "pool-model":
            return self.models[self.pool_template(
                template_name, pool)].predict(config, clamp=True)
        if src == "model":
            return self.models[template_name].predict(config, clamp=True)
        if src == "prior":
            return self.prior.estimate(template_name, pool, config)
        raise KeyError(template_name)

    # -- online feedback (the launcher -> profiler leg of the loop) ------
    def observe(self, job) -> bool:
        """Fold one finished job's measured runtime into the per-pool
        model keyed ``"<template>@<pool>"``. The observation config is
        the job's numeric args + its pinned resource shape — exactly the
        config placement predicts with, so the refit corrects the very
        estimate that placed the job. Returns False (no-op) for jobs
        with no template/pool/runtime."""
        spec = job.spec
        pool = getattr(job, "pool", None)
        if not getattr(spec, "template", None) or not pool \
                or job.runtime is None:
            return False
        cfg = {k: float(v) for k, v in (spec.args or {}).items()
               if isinstance(v, (int, float))}
        cfg.update(spec.resources or {})
        self.add_observation(self.pool_template(spec.template, pool),
                             cfg, job.runtime)
        return True

    def attach_feedback(self, bus, registry) -> None:
        """Subscribe to the launcher's terminal events: every FINISHED
        job's actual runtime feeds :meth:`observe`. Strictly opt-in —
        nothing in the engine behaves differently until a caller
        attaches the loop (golden decision traces stay bit-identical
        with it detached)."""
        from repro.core.engine.events import TOPIC_CONTAINER_STATUS

        def _on_status(msg: dict) -> None:
            if msg.get("status") != "FINISHED":
                return
            try:
                job = registry.get(msg["job_id"])
            except KeyError:
                return
            try:
                self.observe(job)
            except Exception:  # noqa: BLE001 — feedback must never kill
                pass           # the launcher's publish path
        bus.subscribe(TOPIC_CONTAINER_STATUS, _on_status)
