"""Job registry (ACAI §4.2): repository of submitted jobs + metadata."""
from __future__ import annotations

import dataclasses
import re
import threading
import time
from typing import Any, Callable, Optional

from repro.core.engine.lifecycle import (TERMINAL_STATES, IllegalTransition,
                                         JobState, check_transition)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry budget for a job that ends FAILED (ACAI robustness layer).

    A retryable failure requeues the job as a new ``Job.epoch`` (the same
    rebirth machinery preemption uses) after an exponential backoff hold
    of ``min(backoff_cap, backoff_base * 2**retries)`` seconds.
    ``retry_on="transient"`` retries only failures the runner classified
    transient (``TransientJobError``, node loss, worker death);
    ``"any"`` also retries ordinary exceptions — those count toward the
    scheduler's crash-loop quarantine threshold, so a deterministic bug
    ends QUARANTINED instead of burning the whole budget.
    """
    max_retries: int = 3
    backoff_base: float = 1.0
    backoff_cap: float = 60.0
    retry_on: str = "transient"                # "transient" | "any"

    def backoff(self, retries: int) -> float:
        """Hold before retry number ``retries + 1`` (0-based exponent)."""
        return min(self.backoff_cap, self.backoff_base * (2.0 ** retries))


@dataclasses.dataclass(frozen=True)
class GangSpec:
    """A co-scheduled group of identical pods (sharded multi-host training).

    ``n_pods`` pods launch atomically on one pool — all or none; the
    scheduler admits/backfills/shadows the gang as a single unit and a
    preemption of any pod preempts the whole gang with one epoch bump.
    ``per_pod_resources`` defaults to the spec's ``resources`` (the spec's
    resources then describe ONE pod, and the gang is charged
    ``n_pods x per_pod``). ``topology`` is a placement hint: ``"close"``
    asks for all pods on one interconnect island — pools that cannot host
    the gang close are penalized by the transfer-cost model, not rejected.
    ``min_pods`` > 0 marks the gang resizable: under capacity pressure
    (spot reclaim, elastic shrink) the engine may shrink it to any
    k >= min_pods instead of preempting it outright.
    """
    n_pods: int
    per_pod_resources: Optional[dict] = None
    topology: str = "any"                      # "any" | "close"
    min_pods: int = 0                          # 0 => not resizable

    def pod_resources(self, spec: "JobSpec") -> dict:
        res = self.per_pod_resources
        return dict(res if res is not None else spec.resources)


@dataclasses.dataclass
class JobSpec:
    """Encapsulation of an ML program (ACAI §3: the Job abstraction)."""
    name: str
    project: str
    user: str
    # the program: a python callable fn(workdir: Path, job: Job) -> dict
    # (the paper runs argv in a container; the runner interface is pluggable)
    fn: Optional[Callable] = None
    argv: Optional[list[str]] = None
    input_fileset: Optional[str] = None
    output_fileset: Optional[str] = None     # name for the output file set
    resources: dict[str, Any] = dataclasses.field(default_factory=dict)
    args: dict[str, Any] = dataclasses.field(default_factory=dict)
    # virtual-duration hook for simulated runs (profiling experiments)
    duration: Optional[float] = None
    # scheduling priority (added to the queue's priority; higher first)
    priority: int = 0
    # declared dataflow: job ids that must FINISH before this job launches.
    # The scheduler holds the job until every parent is FINISHED and
    # cascades UPSTREAM_FAILED if any parent ends FAILED/KILLED.
    depends_on: list[str] = dataclasses.field(default_factory=list)
    # heterogeneous pools: pin to one pool by name; declare per-pool
    # resource alternatives (an explicit menu placement chooses from —
    # when set, the job is eligible only on the listed pools); name the
    # profiled command template whose model predicts this job's runtime
    # so placement can score pools on the cost/speed frontier.
    pool: Optional[str] = None
    pool_resources: dict[str, dict[str, Any]] = \
        dataclasses.field(default_factory=dict)
    template: Optional[str] = None
    # gang scheduling: co-launch n_pods pods as one atomic unit (None =
    # ordinary single-reservation job; see GangSpec)
    gang: Optional[GangSpec] = None
    # declared size of this job's input fileset in bytes — the placement
    # layer's transfer-cost model prices moving these bytes between
    # accelerator families when a child lands off its parent's pool
    input_bytes: float = 0.0
    # fault tolerance (None = fail-fast, the pre-retry behaviour):
    # requeue budget for FAILED incarnations, per-incarnation runtime
    # limit (a timed-out incarnation fails *transient* — straggler
    # semantics — so the retry budget can try it elsewhere), and an
    # end-to-end deadline in seconds after submit (the job is killed at
    # the deadline, and rejected at admission when its declared duration
    # already proves the deadline infeasible on every pool)
    retry: Optional[RetryPolicy] = None
    timeout_s: Optional[float] = None
    deadline: Optional[float] = None

    @property
    def n_pods(self) -> int:
        return self.gang.n_pods if self.gang is not None else 1


@dataclasses.dataclass
class Job:
    job_id: str
    spec: JobSpec
    state: JobState = JobState.SUBMITTED
    submitted_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    runtime: Optional[float] = None          # measured (or virtual) seconds
    cost: Optional[float] = None             # accumulated across segments
    pool: Optional[str] = None               # the pool placement launched on
    error: Optional[str] = None
    outputs: dict[str, Any] = dataclasses.field(default_factory=dict)
    # checkpoint-aware preemption: epoch counts incarnations (bumped on
    # every preempt-requeue so terminal events from a superseded run are
    # recognizably stale); preempt_flag is the cooperative checkpoint
    # signal threaded runners hand the job fn (a threading.Event — the fn
    # polls it and raises JobPreempted to yield at a checkpoint)
    epoch: int = 0
    preemptions: int = 0
    preempt_flag: Any = dataclasses.field(default=None, repr=False,  # acailint: runtime-only
                                          compare=False)
    # live gang width: set at launch (spec.gang.n_pods) and lowered by an
    # elastic shrink-to-k resize; None for ordinary single-pod jobs. The
    # training stack's gang_resize_hook watches it to re-mesh in place.
    gang_pods: Optional[int] = None
    # fault-tolerance bookkeeping: retries counts FAILED->QUEUED rebirths
    # (bounded by spec.retry.max_retries), failures counts *consecutive*
    # non-transient failures (a transient failure breaks the streak) —
    # the scheduler quarantines at its crash-loop threshold
    retries: int = 0
    failures: int = 0
    # retry-decision latch: raised (under the registry lock, in the same
    # commit as the FAILED transition) when the spec carries a retry
    # policy, lowered once the scheduler decides retry-or-not. Waiters
    # must not treat FAILED as terminal while it is up — the job may be
    # reborn as a new epoch a moment later. In-memory only: never
    # journaled, defaults down on recovery.
    retry_pending: bool = dataclasses.field(default=False, repr=False,  # acailint: runtime-only
                                            compare=False)

    @property
    def queue_key(self) -> tuple[str, str]:
        return (self.spec.project, self.spec.user)


class JobRegistry:
    def __init__(self, metadata=None, journal=None):
        self._jobs: dict[str, Job] = {}  # guarded-by: _lock
        self._ctr = 0  # guarded-by: _lock
        self.metadata = metadata
        # optional write-ahead journal (durable control plane): every
        # state-changing commit records through it while still holding
        # the registry lock, so journal order matches commit order
        self.journal = journal
        # journaling happens inside this lock (order == commit order),
        # but bus publishes, metadata-store writes and runner launches
        # must not — they nest foreign locks/IO under the registry lock
        self._lock = threading.RLock()  # acailint: lock(forbid: publish, metadata, launch)
        if metadata is not None:
            # resume the id counter past persisted jobs so a restarted
            # engine (e.g. a new CLI invocation over the same root) never
            # reuses an earlier job's id and overwrites its metadata
            for aid in metadata.find(kind="job"):
                m = re.fullmatch(r"job-(\d+)", aid)
                if m:
                    self._ctr = max(self._ctr, int(m.group(1)))

    def submit(self, spec: JobSpec) -> Job:
        with self._lock:
            self._ctr += 1
            job = Job(job_id=f"job-{self._ctr}", spec=spec)
            self._jobs[job.job_id] = job
            if self.journal is not None:
                self.journal.job_submitted(job)
        if self.metadata is not None:
            self.metadata.register(job.job_id, kind="job",
                                   creator=spec.user, model=spec.name,
                                   project=spec.project)
        return job

    def get(self, job_id: str) -> Job:
        with self._lock:
            return self._jobs[job_id]

    def all_jobs(self) -> list[Job]:
        with self._lock:
            return list(self._jobs.values())

    def adopt(self, job: Job) -> None:
        """Install a job rebuilt from the durable store (crash recovery):
        no transition checks, no metadata registration — the job is
        already history, not a new submission. The id counter advances
        past it so post-recovery submits never reuse its id. The install
        is journaled like any other durable mutation; recovery wraps the
        rebuild in ``journal.paused()``, so replay never double-records,
        while an adoption outside recovery survives the next crash."""
        with self._lock:
            self._jobs[job.job_id] = job
            m = re.fullmatch(r"job-(\d+)", job.job_id)
            if m:
                self._ctr = max(self._ctr, int(m.group(1)))
            if self.journal is not None:
                self.journal.job_submitted(job)
                self.journal.job_state(job)

    def force_state(self, job_id: str, new: JobState) -> Job:
        """Privileged reassignment: install ``new`` without consulting
        the transition table. Reserved for reattachment paths (e.g. the
        scheduler adopting an already-RUNNING job after recovery) where
        the job's true state is externally known rather than derived by
        an edge. Journaled like any transition so the durable story
        stays complete."""
        with self._lock:
            job = self._jobs[job_id]
            job.state = new
            if new == JobState.RUNNING and job.started_at is None:
                job.started_at = time.time()
            if self.journal is not None:
                self.journal.job_state(job)
            return job

    def set_state(self, job_id: str, new: JobState,
                  error: Optional[str] = None,
                  expect_epoch: Optional[int] = None) -> Optional[Job]:
        """Transition the job; with ``expect_epoch`` the write commits
        only while ``job.epoch`` still matches (returns None otherwise) —
        the check and the write share the registry lock, so a superseded
        worker can never terminal-ize an incarnation that was preempted
        (and epoch-bumped) after its last unlocked epoch read."""
        with self._lock:
            job = self._jobs[job_id]
            if expect_epoch is not None and job.epoch != expect_epoch:
                return None
            check_transition(job.state, new)
            job.state = new
            # raise/lower the retry-decision latch atomically with the
            # transition: a waiter that samples the registry between this
            # commit and the scheduler's retry decision must not resolve
            # a FAILED job that is about to be reborn
            job.retry_pending = (new == JobState.FAILED
                                 and job.spec.retry is not None)
            if new == JobState.RUNNING:
                job.started_at = time.time()
            if new in TERMINAL_STATES:
                job.finished_at = time.time()
                job.error = error
            if self.journal is not None:
                self.journal.job_state(job)
            return job

    def mark_preempted(self, job_id: str) -> Job:
        """Atomically ``RUNNING -> PREEMPTED`` + epoch bump (+ preemption
        count) under the registry lock, so the epoch a concurrent
        worker's ``set_state(expect_epoch=...)`` compares against can
        never be mid-bump."""
        with self._lock:
            job = self._jobs[job_id]
            check_transition(job.state, JobState.PREEMPTED)
            job.state = JobState.PREEMPTED
            job.epoch += 1
            job.preemptions += 1
            if self.journal is not None:
                self.journal.job_preempted(job)
            return job

    def note_failure(self, job_id: str, transient: bool) -> int:
        """Record one failed incarnation under the registry lock and
        return the job's *consecutive non-transient* failure count — the
        crash-loop signal the scheduler quarantines on. A transient
        failure breaks the streak (the job is flaky, not crash-looping).
        """
        with self._lock:
            job = self._jobs[job_id]
            job.failures = 0 if transient else job.failures + 1
            return job.failures

    def mark_retrying(self, job_id: str) -> Job:
        """Atomically rebirth a FAILED job into QUEUED for a retry:
        epoch bump + retry count under the registry lock, mirroring
        ``mark_preempted``. Like crash recovery's requeue this is an
        epoch rebirth, not a transition-table edge — FAILED stays
        terminal in ``_TRANSITIONS``; only this privileged op (driven by
        an explicit ``JobSpec.retry`` budget) may resurrect it. The last
        failure's ``error`` is kept as the job's last-failure reason."""
        with self._lock:
            job = self._jobs[job_id]
            if job.state != JobState.FAILED:
                raise IllegalTransition(
                    f"retry of {job_id} in state {job.state.value}")
            job.state = JobState.QUEUED
            job.retry_pending = False
            job.finished_at = None
            job.epoch += 1
            job.retries += 1
            if self.journal is not None:
                self.journal.job_retried(job)
            return job

    def persist_state(self, job_id: str) -> None:
        """Persist the job's state to the metadata store. The runner's
        finalize does this for jobs it completes; the scheduler calls it
        for terminals that never reach a runner (UPSTREAM_FAILED, queued
        kills, infeasible submits), so cross-process status readers see
        every outcome. Failure reason (first line) and retry count ride
        along so a cross-process ``acai status`` can answer "why"."""
        if self.metadata is not None:
            job = self.get(job_id)
            extra: dict[str, Any] = {}
            if job.error:
                extra["error"] = str(job.error).strip().splitlines()[-1][:200]
            if job.retries:
                extra["retries"] = job.retries
            self.metadata.put(job_id, state=job.state.value, **extra)
