"""Job registry (ACAI §4.2): repository of submitted jobs + metadata."""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

from repro.core.engine.lifecycle import JobState, check_transition


@dataclasses.dataclass
class JobSpec:
    """Encapsulation of an ML program (ACAI §3: the Job abstraction)."""
    name: str
    project: str
    user: str
    # the program: a python callable fn(workdir: Path, job: Job) -> dict
    # (the paper runs argv in a container; the runner interface is pluggable)
    fn: Optional[Callable] = None
    argv: Optional[list[str]] = None
    input_fileset: Optional[str] = None
    output_fileset: Optional[str] = None     # name for the output file set
    resources: dict[str, Any] = dataclasses.field(default_factory=dict)
    args: dict[str, Any] = dataclasses.field(default_factory=dict)
    # virtual-duration hook for simulated runs (profiling experiments)
    duration: Optional[float] = None
    # scheduling priority (added to the queue's priority; higher first)
    priority: int = 0


@dataclasses.dataclass
class Job:
    job_id: str
    spec: JobSpec
    state: JobState = JobState.SUBMITTED
    submitted_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    runtime: Optional[float] = None          # measured (or virtual) seconds
    cost: Optional[float] = None
    error: Optional[str] = None
    outputs: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def queue_key(self) -> tuple[str, str]:
        return (self.spec.project, self.spec.user)


class JobRegistry:
    def __init__(self, metadata=None):
        self._jobs: dict[str, Job] = {}
        self._ctr = 0
        self.metadata = metadata
        self._lock = threading.RLock()

    def submit(self, spec: JobSpec) -> Job:
        with self._lock:
            self._ctr += 1
            job = Job(job_id=f"job-{self._ctr}", spec=spec)
            self._jobs[job.job_id] = job
        if self.metadata is not None:
            self.metadata.register(job.job_id, kind="job",
                                   creator=spec.user, model=spec.name,
                                   project=spec.project)
        return job

    def get(self, job_id: str) -> Job:
        return self._jobs[job_id]

    def all_jobs(self) -> list[Job]:
        return list(self._jobs.values())

    def set_state(self, job_id: str, new: JobState,
                  error: Optional[str] = None) -> Job:
        with self._lock:
            job = self._jobs[job_id]
            check_transition(job.state, new)
            job.state = new
            if new == JobState.RUNNING:
                job.started_at = time.time()
            if new in (JobState.FINISHED, JobState.FAILED, JobState.KILLED):
                job.finished_at = time.time()
                job.error = error
            return job
