"""Pluggable state-store transports for the durable control plane.

The ACAI paper backs its execution engine with Redis: the job queue, the
registry and the event stream all live in a store that outlives the
engine process. This module is that seam, shrunk to the two Redis
primitives the engine actually needs:

* **streams** — append-only sequences of JSON records
  (``XADD``/``XRANGE``): the write-ahead journal and the event log.
* **keys** — whole-document reads/writes (``SET``/``GET``): snapshots.

``MemoryStore`` keeps everything in process (tests, and engines that opt
out of durability pay nothing). ``FileStore`` is the default durable
backend: each stream is a ``<name>.jsonl`` file appended line-at-a-time
and flushed per record, each key a ``<name>.json`` written atomically via
tmp + rename. A real Redis/SQL transport implements the same five
methods and nothing above this layer changes.

Crash semantics of ``FileStore``: a ``kill -9`` can tear at most the
final journal line (the OS page cache still lands buffered writes of a
dead process on disk; only power loss needs ``fsync=True``). Readers
therefore skip a trailing unparseable line instead of failing — the
torn record was never acknowledged, so dropping it is correct.
"""
from __future__ import annotations

import io
import json
import os
import threading
from pathlib import Path
from typing import Any, Optional


class StateStore:
    """Transport interface: streams of JSON records + JSON key documents."""

    def append(self, stream: str, record: dict) -> None:
        raise NotImplementedError

    def read(self, stream: str) -> list[dict]:
        raise NotImplementedError

    def truncate(self, stream: str) -> None:
        """Drop every record in the stream (journal compaction)."""
        raise NotImplementedError

    def put(self, key: str, obj: Any) -> None:
        raise NotImplementedError

    def get(self, key: str) -> Optional[Any]:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError


class MemoryStore(StateStore):
    """In-process backend: durability machinery without the disk (tests,
    and the cheapest way to exercise journal/recovery logic)."""

    def __init__(self):
        self._streams: dict[str, list[dict]] = {}
        self._keys: dict[str, Any] = {}
        self._lock = threading.Lock()

    def append(self, stream: str, record: dict) -> None:
        # round-trip through JSON so Memory and File backends accept (and
        # reject) exactly the same records — tests on Memory stay honest
        line = json.dumps(record, default=str)
        with self._lock:
            self._streams.setdefault(stream, []).append(json.loads(line))

    def read(self, stream: str) -> list[dict]:
        with self._lock:
            return list(self._streams.get(stream, ()))

    def truncate(self, stream: str) -> None:
        with self._lock:
            self._streams[stream] = []

    def put(self, key: str, obj: Any) -> None:
        line = json.dumps(obj, default=str)
        with self._lock:
            self._keys[key] = json.loads(line)

    def get(self, key: str) -> Optional[Any]:
        with self._lock:
            return self._keys.get(key)

    def delete(self, key: str) -> None:
        with self._lock:
            self._keys.pop(key, None)


class FileStore(StateStore):
    """Directory-backed durable store (the default Redis stand-in).

    ``fsync=True`` additionally fsyncs every append/put — survives power
    loss, not just process death — at a large per-record cost; the
    default relies on the page cache outliving a SIGKILL.
    """

    def __init__(self, root: str | Path, *, fsync: bool = False):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self._handles: dict[str, io.TextIOWrapper] = {}
        self._lock = threading.Lock()

    def _stream_path(self, stream: str) -> Path:
        return self.root / f"{stream}.jsonl"

    def _key_path(self, key: str) -> Path:
        return self.root / f"{key}.json"

    def append(self, stream: str, record: dict) -> None:
        line = json.dumps(record, default=str)
        with self._lock:
            fh = self._handles.get(stream)
            if fh is None or fh.closed:
                fh = self._stream_path(stream).open("a", encoding="utf-8")
                self._handles[stream] = fh
            fh.write(line + "\n")
            fh.flush()
            if self.fsync:
                os.fsync(fh.fileno())

    def read(self, stream: str) -> list[dict]:
        path = self._stream_path(stream)
        if not path.exists():
            return []
        out: list[dict] = []
        with self._lock:
            lines = path.read_text(encoding="utf-8").splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break       # torn tail from a crash mid-append: the
                                # record was never acknowledged — drop it
                raise
        return out

    def truncate(self, stream: str) -> None:
        with self._lock:
            fh = self._handles.pop(stream, None)
            if fh is not None and not fh.closed:
                fh.close()
            path = self._stream_path(stream)
            tmp = path.with_suffix(".jsonl.tmp")
            tmp.write_text("", encoding="utf-8")
            os.replace(tmp, path)

    def put(self, key: str, obj: Any) -> None:
        path = self._key_path(key)
        tmp = path.with_suffix(".json.tmp")
        data = json.dumps(obj, default=str)
        with self._lock:
            tmp.write_text(data, encoding="utf-8")
            if self.fsync:
                fd = os.open(tmp, os.O_RDONLY)
                try:
                    os.fsync(fd)
                finally:
                    os.close(fd)
            # atomic: a crash leaves either the old snapshot or the new
            # one, never a half-written file
            os.replace(tmp, path)

    def get(self, key: str) -> Optional[Any]:
        path = self._key_path(key)
        if not path.exists():
            return None
        try:
            return json.loads(path.read_text(encoding="utf-8"))
        except json.JSONDecodeError:
            return None     # interrupted before the first snapshot's
                            # rename landed: recover from the journal alone

    def delete(self, key: str) -> None:
        path = self._key_path(key)
        if path.exists():
            path.unlink()

    def close(self) -> None:
        with self._lock:
            for fh in self._handles.values():
                if not fh.closed:
                    fh.close()
            self._handles.clear()
