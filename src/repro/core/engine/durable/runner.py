"""Process-boundary runner: jobs run in a detached worker process.

``SubprocessRunner`` speaks the engine's standard ``launch`` /
``pending()`` / ``step()`` drain protocol, but the jobs themselves
execute in a separate worker process (``durable.worker``) connected over
a Unix-domain socket. The worker is spawned in its own session, so it
**survives an engine crash**: after a restart, :func:`recovery.recover`
calls :meth:`adopt`, which reconnects, replays the worker's buffered
results (completed while the engine was down — applied once, never
re-run) and re-attaches still-running jobs at their original epoch.

Job functions must be importable ``module:qualname`` callables — a
closure cannot cross the process boundary, and a launch without an
importable fn FAILs loudly instead of pretending to run.

Terminal application is epoch-guarded end to end: the worker stamps
every result with the epoch it was launched under, and ``_apply`` writes
through ``registry.set_state(expect_epoch=...)`` — a result from a
superseded incarnation (preempted/re-queued while the worker ran) is
dropped, never double-settled.
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import time
from pathlib import Path
from typing import Optional

from repro.core.engine.durable.codec import encode_fn, json_safe
from repro.core.engine.events import EventBus, TOPIC_CONTAINER_STATUS
from repro.core.engine.launcher import (Runner, _bill_segment,
                                        resolve_pricing)
from repro.core.engine.lifecycle import (TERMINAL_STATES, IllegalTransition,
                                         JobState)
from repro.core.engine.registry import Job, JobRegistry


class SubprocessRunner(Runner):
    threaded = False        # progress is made by step(), like the
    # virtual clock: handle.wait drives the drain loop

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 workdir: str | Path = "/tmp/acai-jobs",
                 pricing=None, datalake=None,
                 spawn_timeout: float = 20.0):
        self.registry = registry
        self.bus = bus
        self.pricing = pricing
        self.datalake = datalake
        self.dir = Path(workdir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.spawn_timeout = spawn_timeout
        self._inflight: dict[str, int] = {}     # job_id -> launch epoch
        self._sock: Optional[socket.socket] = None
        self._rfile = None

    # -- worker lifecycle ------------------------------------------------
    def _worker_pid(self) -> Optional[int]:
        info = self.dir / "worker.json"
        if not info.exists():
            return None
        try:
            pid = int(json.loads(info.read_text())["pid"])
            os.kill(pid, 0)         # alive?
        except (ValueError, KeyError, OSError, json.JSONDecodeError):
            return None
        try:
            # a worker we spawned and never reaped stays a zombie that
            # still answers kill(pid, 0); it can't serve the socket
            with open(f"/proc/{pid}/stat") as fh:
                if fh.read().rpartition(")")[2].split()[0] == "Z":
                    return None
        except OSError:
            pass        # no procfs: fall back to the signal probe
        return pid

    def _spawn_worker(self) -> None:
        # the worker must import repro from a bare interpreter: prepend
        # our src root (pytest's pythonpath config edits sys.path, not
        # the environment a child would inherit)
        src = str(Path(__file__).resolve().parents[4])
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        log = (self.dir / "worker.log").open("ab")
        subprocess.Popen(
            [sys.executable, "-m", "repro.core.engine.durable.worker",
             "--dir", str(self.dir)],
            stdout=log, stderr=subprocess.STDOUT,
            start_new_session=True,     # detach: survives engine death
            env=env)

    def _connect(self, *, spawn: bool = True) -> bool:
        if self._sock is not None:
            return True
        if self._worker_pid() is None:
            if not spawn:
                return False
            (self.dir / "worker.json").unlink(missing_ok=True)
            self._spawn_worker()
        sock_path = self.dir / "sock"
        deadline = time.monotonic() + self.spawn_timeout
        while time.monotonic() < deadline:
            if sock_path.exists() and self._worker_pid() is not None:
                try:
                    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                    s.connect(str(sock_path))
                    self._sock = s
                    self._rfile = s.makefile("r")
                    return True
                except OSError:
                    pass
            elif not spawn and self._worker_pid() is None:
                return False    # probing only: the worker is simply gone
            time.sleep(0.05)
        if not spawn:
            return False
        raise RuntimeError(f"worker at {self.dir} did not come up within "
                           f"{self.spawn_timeout}s")

    def _send(self, msg: dict) -> None:
        payload = (json.dumps(msg, default=str) + "\n").encode()
        self._connect()
        try:
            self._sock.sendall(payload)
        except OSError:
            # a cached connection can be stale (the worker it reached
            # exited since): reconnect — respawning if needed — and
            # retry once before giving up
            self._disconnect()
            self._connect()
            try:
                self._sock.sendall(payload)
            except OSError:
                self._disconnect()
                raise

    def _disconnect(self) -> None:
        if self._rfile is not None:
            try:
                self._rfile.close()
            except OSError:
                pass
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        self._sock = None
        self._rfile = None

    # -- Runner protocol -------------------------------------------------
    def launch(self, job: Job) -> None:
        epoch = job.epoch
        try:
            self.registry.set_state(job.job_id, JobState.RUNNING)
        except IllegalTransition:
            # killed between dispatch and pickup: surface the terminal
            self.registry.persist_state(job.job_id)
            self.bus.publish(TOPIC_CONTAINER_STATUS,
                             {"job_id": job.job_id, "epoch": epoch,
                              "status": self.registry.get(
                                  job.job_id).state.value})
            return
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job.job_id, "status": "provisioned"})
        fn_ref = encode_fn(job.spec.fn)
        if fn_ref is None:
            err = (f"{job.job_id}: SubprocessRunner needs an importable "
                   f"module-level fn (got "
                   f"{getattr(job.spec.fn, '__qualname__', None)!r}); "
                   f"lambdas/closures cannot cross the process boundary")
            self._fail_local(job, epoch, err)
            return
        self._send({"op": "launch", "job": job.job_id, "epoch": epoch,
                    "fn": fn_ref, "name": job.spec.name,
                    "args": json_safe(job.spec.args),
                    "resources": json_safe(job.spec.resources),
                    "workdir": str(self.dir / "jobs" / job.job_id)})
        self._inflight[job.job_id] = epoch

    def _fail_local(self, job: Job, epoch: int, err: str, *,
                    transient: bool = False) -> None:
        if self.registry.set_state(job.job_id, JobState.FAILED, error=err,
                                   expect_epoch=epoch) is None:
            return
        job.outputs["log"] = err
        self.registry.persist_state(job.job_id)
        if self.datalake is not None:
            # no worker log exists for an engine-side failure: persist
            # the reason as the job log so `acai logs` can answer "why"
            self.datalake.storage.upload(f"/.logs/{job.job_id}.log",
                                         err.encode(),
                                         creator=job.spec.user)
        msg = {"job_id": job.job_id, "epoch": epoch, "status": "FAILED"}
        if transient:
            msg["transient"] = True
        self.bus.publish(TOPIC_CONTAINER_STATUS, msg)

    def pending(self) -> int:
        return len(self._inflight)

    def step(self, timeout: float = 120.0) -> Optional[str]:
        """Block for the next worker push and apply it; returns the
        settled job id (None on an idle/ignored message)."""
        if not self._inflight:
            return None
        self._connect()
        self._sock.settimeout(timeout)
        try:
            line = self._rfile.readline()
        except socket.timeout:
            raise TimeoutError(f"no worker event within {timeout}s "
                               f"({len(self._inflight)} in flight)") \
                from None
        finally:
            self._sock.settimeout(None)
        if not line:
            # worker died underneath us: fail what it was running (its
            # buffered results were already consumed at adopt/connect)
            self._disconnect()
            lost = list(self._inflight.items())
            self._inflight.clear()
            for jid, epoch in lost:
                try:
                    job = self.registry.get(jid)
                except KeyError:
                    continue
                # the worker died, not the job: a transient failure, so
                # a retry budget can relaunch on a fresh worker
                self._fail_local(job, epoch,
                                 f"{jid}: worker process died mid-run",
                                 transient=True)
            return None
        msg = json.loads(line)
        if msg.get("op") != "terminal":
            return None
        try:
            job = self.registry.get(msg.get("job", ""))
        except KeyError:
            return None
        return msg["job"] if self.apply_result(job, msg) else None

    # -- result application (shared with recovery) -----------------------
    def apply_result(self, job: Job, msg: dict, *,
                     publish: bool = True) -> bool:
        """Epoch-guarded, idempotent terminal apply. Returns False when
        the result is stale (superseded epoch) or a duplicate (job
        already terminal) — exactly-once settle under at-least-once
        delivery from the worker's replayed buffer."""
        jid = job.job_id
        epoch = msg.get("epoch")
        epoch = int(epoch) if epoch is not None else None
        if job.state in TERMINAL_STATES:
            self._inflight.pop(jid, None)
            return False
        try:
            state = JobState(msg.get("status", "FAILED"))
        except ValueError:
            state = JobState.FAILED
        try:
            committed = self.registry.set_state(jid, state,
                                                error=msg.get("error"),
                                                expect_epoch=epoch)
        except IllegalTransition:
            committed = None    # e.g. re-queued (QUEUED) under a new
            # epoch while this stale result was in the buffer
        if committed is None:
            if self._inflight.get(jid) == epoch:
                self._inflight.pop(jid, None)
            return False
        job.runtime = msg.get("runtime")
        job.outputs.update(dict(msg.get("outputs") or {}))
        log = msg.get("log", "")
        if state == JobState.FAILED and msg.get("error"):
            # the worker's traceback belongs in the job log: stdout alone
            # rarely explains a failure, and the data-lake log is what
            # `acai logs <job>` reads cross-process
            log = (log + "\n" if log else "") + str(msg["error"])
        job.outputs["log"] = log
        if job.runtime:
            _bill_segment(resolve_pricing(self.pricing, job), job,
                          job.runtime)
        if self.datalake is not None:
            extra = {}
            if job.error:
                extra["error"] = \
                    str(job.error).strip().splitlines()[-1][:200]
            if job.retries:
                extra["retries"] = job.retries
            self.datalake.metadata.put(jid, runtime=job.runtime,
                                       cost=job.cost, state=state.value,
                                       **extra)
            self.datalake.storage.upload(f"/.logs/{jid}.log",
                                         job.outputs["log"].encode(),
                                         creator=job.spec.user)
        self._inflight.pop(jid, None)
        if publish:
            out = {"job_id": jid, "status": state.value}
            if epoch is not None:
                out["epoch"] = epoch
            if msg.get("transient") and state == JobState.FAILED:
                out["transient"] = True
            if msg.get("error"):
                out["error"] = str(msg["error"])
            self.bus.publish(TOPIC_CONTAINER_STATUS, out)
        return True

    # -- restart adoption ------------------------------------------------
    def adopt(self) -> tuple[dict[str, int], list[dict]]:
        """Reconnect to a surviving worker; returns ``(in-flight
        {job_id: epoch}, buffered result records)``. The in-flight set is
        re-registered so ``pending()/step()`` keep draining it; with no
        surviving worker both are empty (the recovery path re-queues)."""
        if self._worker_pid() is None or not self._connect(spawn=False):
            # the worker died too: nothing is in flight, but results it
            # persisted before dying still settle without a re-run
            return {}, self._read_result_file()
        results: list[dict] = []
        inflight: dict[str, int] = {}
        adopted = False
        try:
            self._send({"op": "adopt"})
            deadline = time.monotonic() + self.spawn_timeout
            self._sock.settimeout(max(0.1, self.spawn_timeout))
            try:
                while time.monotonic() < deadline:
                    line = self._rfile.readline()
                    if not line:
                        break
                    msg = json.loads(line)
                    if msg.get("op") == "terminal":
                        results.append(msg)  # completion racing the adopt
                        continue
                    if msg.get("op") == "adopted":
                        inflight = {r["job"]: int(r.get("epoch", 0))
                                    for r in msg.get("inflight", ())}
                        results.extend(msg.get("results", ()))
                        adopted = True
                        break
            finally:
                if self._sock is not None:
                    self._sock.settimeout(None)
        except (socket.timeout, OSError):
            pass
        if not adopted:
            # the worker died out from under the handshake (e.g. it was
            # mid-shutdown and still answered the liveness probe, or a
            # not-yet-reaped zombie): drop the stale connection and fall
            # back to its durable result buffer, exactly as for an
            # already-dead worker
            self._disconnect()
            return {}, self._read_result_file()
        self._inflight.update(inflight)
        return inflight, results

    def _read_result_file(self) -> list[dict]:
        path = self.dir / "results.jsonl"
        if not path.exists():
            return []
        out = []
        lines = path.read_text().splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break       # torn tail from the worker's own death
                raise
        return out

    def shutdown(self) -> None:
        """Stop the worker (best-effort) and drop the connection."""
        try:
            if self._worker_pid() is not None:
                self._send({"op": "shutdown"})
        except (OSError, RuntimeError):
            pass
        self._disconnect()
