"""Write-ahead journal: every state-changing engine event, durably.

The journal is an append-only stream of JSON records in a
:class:`~repro.core.engine.durable.store.StateStore`, compacted
periodically into a whole-state snapshot key. Event types:

``submit``    a job entered the registry (full encoded spec)
``state``     a registry state transition (state, epoch, pool, error,
              runtime/cost as known at that instant)
``preempt``   an epoch bump (``mark_preempted``): the prior incarnation
              is superseded from this record on
``retry``     an epoch rebirth (``mark_retrying``): a FAILED incarnation
              re-queued under its retry budget, with the retry/failure
              counters that must survive a restart
``progress``  checkpointed progress banked by a preemption (fraction of
              the job done — a relaunch resumes from here)
``final``     terminal enrichment recorded after the runner finished
              settling (authoritative outputs/runtime/cost — the
              ``state`` event fires before the runner commits them)
``resize``    a pool's capacity changed (elastic resize / spot reclaim)

Every record carries a monotone sequence number ``n`` assigned by the
journal (never reset by compaction), so replay after a crash *between*
snapshot write and journal truncation skips the already-snapshotted
prefix instead of double-applying it. Apply semantics are idempotent by
construction — records carry absolute states and epochs, and recovery
drops stale-epoch and duplicate-terminal records — so at-least-once
journal delivery yields exactly-once state.

The registry, launcher and scheduler call the typed ``job_*``/``pool_*``
hooks through a duck-typed optional attribute; with no journal attached
every hook site is a single ``is None`` test.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Callable, Optional

from repro.core.engine.durable.codec import encode_spec, json_safe
from repro.core.engine.durable.store import StateStore
from repro.core.engine.events import TOPIC_CONTAINER_STATUS
from repro.core.engine.lifecycle import (TERMINAL_STATES,
                                         TERMINAL_STATUS_VALUES)

JOURNAL_STREAM = "journal"
SNAPSHOT_KEY = "snapshot"


class Journal:
    def __init__(self, store: StateStore, *, snapshot_every: int = 1000):
        self.store = store
        self.snapshot_every = snapshot_every
        # the engine wires this to a callable building the full-state
        # snapshot document (registry + runner progress + pool capacities)
        self.snapshot_source: Optional[Callable[[], dict]] = None
        self._lock = threading.RLock()
        self._next = 1          # next sequence number to assign
        self._since_snap = 0
        self._paused = 0
        self._loaded = False

    # -- low-level record/replay ----------------------------------------
    def record(self, rec: dict) -> None:
        with self._lock:
            if self._paused:
                return
            rec = dict(rec)
            rec["n"] = self._next
            self._next += 1
            self.store.append(JOURNAL_STREAM, rec)
            self._since_snap += 1
            if (self.snapshot_every and self.snapshot_source is not None
                    and self._since_snap >= self.snapshot_every):
                self.snapshot()

    def load(self) -> tuple[Optional[dict], list[dict]]:
        """(snapshot document or None, journal events after it) — and
        prime the sequence counter past everything seen, so records
        appended after recovery never collide with replayed ones."""
        with self._lock:
            snap = self.store.get(SNAPSHOT_KEY)
            watermark = int(snap.get("seq", 0)) if snap else 0
            events = [e for e in self.store.read(JOURNAL_STREAM)
                      if int(e.get("n", 0)) > watermark]
            top = max([watermark] + [int(e.get("n", 0)) for e in events])
            self._next = max(self._next, top + 1)
            self._loaded = True
            return snap, events

    def has_state(self) -> bool:
        """True when the store holds anything to recover from."""
        return (self.store.get(SNAPSHOT_KEY) is not None
                or bool(self.store.read(JOURNAL_STREAM)))

    def snapshot(self) -> None:
        """Compact: write the full-state snapshot, then truncate the
        journal. Crash-ordered — the snapshot (with its ``seq``
        watermark) lands atomically first, so a crash before the truncate
        merely replays records the watermark filter already skips."""
        with self._lock:
            if self.snapshot_source is None:
                return
            doc = self.snapshot_source()
            doc["seq"] = self._next - 1
            self.store.put(SNAPSHOT_KEY, doc)
            self.store.truncate(JOURNAL_STREAM)
            self._since_snap = 0

    @contextmanager
    def paused(self):
        """Suppress recording (recovery rebuilds live state from the
        journal — re-journaling the rebuild would double every event)."""
        with self._lock:
            self._paused += 1
        try:
            yield
        finally:
            with self._lock:
                self._paused -= 1

    # -- typed hooks (called by registry/launcher/scheduler) ------------
    def job_submitted(self, job) -> None:
        self.record({"t": "submit", "job": job.job_id,
                     "at": job.submitted_at, "spec": encode_spec(job.spec)})

    def job_state(self, job) -> None:
        rec = {"t": "state", "job": job.job_id, "state": job.state.value,
               "epoch": job.epoch, "pool": job.pool}
        if job.error is not None:
            rec["error"] = str(job.error)
        if job.state in TERMINAL_STATES:
            rec["finished_at"] = job.finished_at
            rec["runtime"] = job.runtime
            rec["cost"] = job.cost
        self.record(rec)

    def job_preempted(self, job) -> None:
        self.record({"t": "preempt", "job": job.job_id, "epoch": job.epoch,
                     "preemptions": job.preemptions})

    def job_retried(self, job) -> None:
        """Epoch rebirth of a FAILED job under its retry budget: the
        prior incarnation's terminal records are superseded from here,
        and the retry/failure counters survive a restart (a recovered
        engine must not grant a crash-looper a fresh budget)."""
        self.record({"t": "retry", "job": job.job_id, "epoch": job.epoch,
                     "retries": job.retries, "failures": job.failures,
                     "error": job.error})

    def job_progress(self, job_id: str, done_frac: float) -> None:
        self.record({"t": "progress", "job": job_id,
                     "done_frac": float(done_frac)})

    def pool_resized(self, pool: str, capacity: dict) -> None:
        self.record({"t": "resize", "pool": pool,
                     "capacity": json_safe(capacity)})

    def job_final(self, job) -> None:
        """Terminal enrichment: runner settles outputs/cost *after* the
        epoch-guarded terminal state write, so the authoritative values
        are journaled from the bus event that closes the settle."""
        self.record({"t": "final", "job": job.job_id,
                     "state": job.state.value, "epoch": job.epoch,
                     "runtime": job.runtime, "cost": job.cost,
                     "error": job.error,
                     "outputs": json_safe(job.outputs)})


def terminal_recorder(journal: Journal, registry) -> Callable[[dict], None]:
    """Bus handler journaling a ``final`` record per terminal
    container_status. Subscribe it *after* the scheduler (handlers run in
    subscription order): by then the runner's finalize has committed
    outputs and billing, so the record carries final values."""
    def _on_status(msg: dict) -> None:
        if msg.get("status", "") not in TERMINAL_STATUS_VALUES:
            return
        try:
            job = registry.get(msg["job_id"])
        except KeyError:
            return
        if job.state not in TERMINAL_STATES:
            return      # stale event for a superseded (re-queued) epoch
        journal.job_final(job)
    return _on_status


def attach_terminal_recorder(bus, journal: Journal, registry) -> None:
    bus.subscribe(TOPIC_CONTAINER_STATUS, terminal_recorder(journal,
                                                            registry))
