"""Crash drill: a deterministic virtual fleet for kill -9 recovery runs.

The ROADMAP exit criterion for the durable control plane: *kill -9 the
engine mid-fleet, restart, and the golden trace still completes with no
lost or duplicated jobs*. This module is that drill, shared by the bench
scenario (``bench_scheduler.py --smoke``) and the integration tests:

* :func:`run_fresh` builds a durable virtual engine, submits a seeded
  fleet (mixed durations/priorities/resource shapes, dependency chains
  for held jobs, near-capacity jobs plus a mid-run elastic shrink so
  preemptions/epochs are exercised) and drives it to completion,
  heart-beating progress to ``<dir>/progress`` so a parent process can
  choose its kill moment.
* :func:`resume` rebuilds the engine from the same state directory
  (recovery runs in the constructor), drains the re-queued fleet, and
  reports final states plus duplicate-terminal counts.

Run as a module for the subprocess-victim side::

    python -m repro.core.engine.durable.drill --dir <d> --n-jobs 800

The process submits (or recovers) and drives the fleet, then writes
``<d>/final.json`` — SIGKILL it anywhere in between.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import random
import sys
from pathlib import Path

from repro.core.acai import AcaiEngine
from repro.core.engine.events import TOPIC_CONTAINER_STATUS
from repro.core.engine.lifecycle import TERMINAL_STATUS_VALUES
from repro.core.engine.registry import JobSpec
from repro.core.provision.pricing import CPU_PRICING

NODES = 4                   # vcpu capacity 32, mem 32 GiB
BIG_VCPU = 24               # near-capacity: starves behind small jobs
SHRUNK_VCPU = 26.0          # mid-run shrink: > BIG_VCPU so nothing goes
FULL_VCPU = 32.0            # infeasible, but running work must drain


def build_engine(state_dir: str | Path) -> AcaiEngine:
    """The drill's engine: durable virtual runner with preemption +
    checkpointing on. Building over an existing state dir recovers."""
    return AcaiEngine(
        virtual=True, pricing=CPU_PRICING, cluster_nodes=NODES,
        quota_k=8, policy="fair", backfill=True,
        preemption=True, starvation_threshold=20.0,
        checkpoint_interval=30.0,
        durable=state_dir, snapshot_every=1500)


def make_fleet(n_jobs: int, seed: int) -> list[JobSpec]:
    rng = random.Random(seed)
    specs = []
    for i in range(n_jobs):
        if i % 31 == 17:
            # near-capacity high-priority job: starves, then preempts
            res = {"vcpu": float(BIG_VCPU), "mem_mb": 2048.0}
            prio, dur = 5, rng.uniform(20.0, 60.0)
        else:
            res = {"vcpu": float(rng.choice([1, 2, 4])), "mem_mb": 512.0}
            prio = rng.choice([0, 0, 0, 1, 2])
            dur = rng.uniform(5.0, 120.0)
        deps = [f"job-{i}"] if (i % 7 == 3 and i > 0) else []
        specs.append(JobSpec(
            name=f"drill-{i}", project="drill", user="u",
            duration=round(dur, 3), priority=prio, resources=res,
            depends_on=deps, args={"checkpoint_interval": 30.0}))
    return specs


def _drive(engine: AcaiEngine, n_jobs: int,
           heartbeat: Path | None = None) -> None:
    """Drain the virtual clock, applying the drill's deterministic
    elastic events (shrink at 10% completions, restore at 20%) and
    heart-beating completion counts for an external killer."""
    launcher = engine.scheduler.launcher
    pool = next(iter(engine.scheduler.pools))
    shrunk = restored = False
    while launcher.pending() > 0:
        launcher.step()
        done = engine.scheduler.stats["completed"]
        if not shrunk and done >= n_jobs // 10:
            engine.scheduler.resize_pool(pool, {"vcpu": SHRUNK_VCPU})
            shrunk = True
        elif shrunk and not restored and done >= n_jobs // 5:
            engine.scheduler.resize_pool(pool, {"vcpu": FULL_VCPU})
            restored = True
        if heartbeat is not None and done % 25 == 0:
            heartbeat.write_text(str(done))
    if heartbeat is not None:
        heartbeat.write_text(str(engine.scheduler.stats["completed"]))


def final_states(engine: AcaiEngine) -> dict[str, str]:
    return {j.job_id: j.state.value for j in engine.registry.all_jobs()}


def run_fresh(dirpath: str | Path, n_jobs: int = 800,
              seed: int = 7) -> dict[str, str]:
    """Submit the seeded fleet into a fresh durable engine and drive it
    to completion; returns the final {job_id: state} map."""
    d = Path(dirpath)
    d.mkdir(parents=True, exist_ok=True)
    engine = build_engine(d / "state")
    for spec in make_fleet(n_jobs, seed):
        engine.submit(spec)
    _drive(engine, n_jobs, heartbeat=d / "progress")
    final = final_states(engine)
    (d / "final.json").write_text(json.dumps(final, sort_keys=True))
    return final


def resume(dirpath: str | Path, n_jobs: int, seed: int = 7) -> dict:
    """Recover the engine from ``<dir>/state`` and drain what the crash
    left behind. Returns final states, the recovery report, duplicate
    terminal-event counts, and the release-underflow total (any
    double-settle would move it off zero)."""
    d = Path(dirpath)
    engine = build_engine(d / "state")
    if not engine.registry.all_jobs():      # killed before any submit
        for spec in make_fleet(n_jobs, seed):
            engine.submit(spec)
    terminal_seen: dict[str, int] = {}

    def _count(msg: dict) -> None:
        if msg.get("status", "") in TERMINAL_STATUS_VALUES:
            jid = msg["job_id"]
            terminal_seen[jid] = terminal_seen.get(jid, 0) + 1

    engine.bus.subscribe(TOPIC_CONTAINER_STATUS, _count)
    _drive(engine, n_jobs, heartbeat=d / "progress")
    final = final_states(engine)
    (d / "final.json").write_text(json.dumps(final, sort_keys=True))
    report = getattr(engine, "recovery", None)
    underflow = sum(cl.stats.get("release_underflow", 0)
                    for cl in engine.scheduler.pools.values())
    return {
        "final": final,
        "report": dataclasses.asdict(report) if report else None,
        "duplicate_terminals": {j: c for j, c in terminal_seen.items()
                                if c > 1},
        "release_underflow": underflow,
        "completed_after_recovery": engine.scheduler.stats["completed"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="acai-crash-drill")
    ap.add_argument("--dir", required=True)
    ap.add_argument("--n-jobs", type=int, default=800)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    d = Path(args.dir)
    state = d / "state"
    if state.exists() and any(state.iterdir()):
        out = resume(d, args.n_jobs, args.seed)
        print(json.dumps({"resumed": True,
                          "report": out["report"],
                          "duplicates": len(out["duplicate_terminals"])}))
    else:
        run_fresh(d, args.n_jobs, args.seed)
        print(json.dumps({"resumed": False}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
