"""Standalone job worker: the far side of the process boundary.

    python -m repro.core.engine.durable.worker --dir <worker-dir>

The worker owns a Unix-domain socket (``<dir>/sock``) speaking
newline-delimited JSON and advertises itself in ``<dir>/worker.json``.
It is spawned detached (own session) by :class:`SubprocessRunner`, so it
**outlives the engine**: jobs keep running through an engine crash, and
a restarted engine reconnects and re-adopts them.

Request ops (engine -> worker)::

    {"op": "launch", "job", "epoch", "fn", "name", "args", "workdir"}
    {"op": "adopt"}                 # -> in-flight set + buffered results
    {"op": "ping"}                  # -> {"op": "pong", ...}
    {"op": "shutdown"}

Push ops (worker -> engine)::

    {"op": "terminal", "job", "epoch", "status", "outputs", "error",
     "runtime", "log"}

Every completion is appended to ``<dir>/results.jsonl`` *before* it is
pushed — the file is the durable truth. If no engine is connected when a
job finishes, the result simply waits there; ``adopt`` replays the whole
buffer and the engine's epoch-guarded apply drops what it already knows
(at-least-once delivery, exactly-once settle). Duplicate ``launch`` for
a (job, epoch) already running or already completed is idempotent: the
worker ignores the re-run and re-pushes the buffered result instead.
"""
from __future__ import annotations

import argparse
import io
import json
import os
import socket
import sys
import threading
import time
import traceback
from pathlib import Path
from types import SimpleNamespace


class _Worker:
    def __init__(self, root: Path):
        self.root = root
        self.root.mkdir(parents=True, exist_ok=True)
        self.results_path = root / "results.jsonl"
        self._lock = threading.Lock()
        self._running: dict[str, int] = {}      # job_id -> epoch
        self._done: dict[str, dict] = {}        # job_id -> result record
        self._conn: socket.socket | None = None
        self._stop = threading.Event()
        for rec in self._read_results():
            self._done[rec["job"]] = rec

    # -- durable result buffer ------------------------------------------
    def _read_results(self) -> list[dict]:
        if not self.results_path.exists():
            return []
        out = []
        lines = self.results_path.read_text().splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break       # torn tail: the job will re-run
                raise
        return out

    def _record_result(self, rec: dict) -> None:
        with self._lock:
            self._done[rec["job"]] = rec
            with self.results_path.open("a") as fh:
                fh.write(json.dumps(rec, default=str) + "\n")
                fh.flush()

    # -- push channel ----------------------------------------------------
    def _send(self, msg: dict) -> None:
        with self._lock:
            conn = self._conn
        if conn is None:
            return
        try:
            conn.sendall((json.dumps(msg, default=str) + "\n").encode())
        except OSError:
            pass        # engine gone; results.jsonl keeps the truth

    # -- job execution ---------------------------------------------------
    def _run_job(self, req: dict) -> None:
        jid, epoch = req["job"], int(req.get("epoch", 0))
        workdir = Path(req.get("workdir") or (self.root / "jobs" / jid))
        (workdir / "out").mkdir(parents=True, exist_ok=True)
        log_buf = io.StringIO()
        rec = {"op": "terminal", "job": jid, "epoch": epoch,
               "status": "FINISHED", "outputs": {}, "error": None,
               "runtime": None, "log": ""}
        t0 = time.perf_counter()
        try:
            from repro.core.engine.durable.codec import decode_fn, json_safe
            fn = decode_fn(req.get("fn"))
            if fn is None:
                raise RuntimeError("launch carried no fn reference")
            shim = SimpleNamespace(
                job_id=jid, epoch=epoch, preempt_flag=None,
                spec=SimpleNamespace(name=req.get("name", jid),
                                     args=dict(req.get("args") or {}),
                                     resources=dict(req.get("resources")
                                                    or {})))
            from contextlib import redirect_stdout
            with redirect_stdout(log_buf):
                result = fn(workdir, shim)
            rec["outputs"] = json_safe(result) \
                if isinstance(result, dict) else {}
        except Exception as e:  # noqa: BLE001 — user code failure => FAILED
            rec["status"] = "FAILED"
            rec["error"] = traceback.format_exc()
            # job-classified retryable failures (TransientJobError, by
            # name — the worker must not import the engine stack just to
            # isinstance-check) ride the record so the engine's retry
            # policy can distinguish flaky from fatal across the boundary
            if any(t.__name__ == "TransientJobError"
                   for t in type(e).__mro__):
                rec["transient"] = True
        rec["runtime"] = time.perf_counter() - t0
        rec["log"] = log_buf.getvalue()
        with self._lock:
            self._running.pop(jid, None)
        self._record_result(rec)
        self._send(rec)

    # -- request handling ------------------------------------------------
    def _handle(self, req: dict) -> dict | None:
        op = req.get("op")
        if op == "launch":
            jid = req["job"]
            with self._lock:
                running = jid in self._running
                done = self._done.get(jid)
            if running:
                return None         # duplicate launch: already in flight
            if done is not None and \
                    int(done.get("epoch", 0)) >= int(req.get("epoch", 0)):
                self._send(done)    # already completed: replay the result
                return None
            with self._lock:
                self._running[jid] = int(req.get("epoch", 0))
            threading.Thread(target=self._run_job, args=(req,),
                             daemon=False).start()
            return None
        if op == "adopt":
            with self._lock:
                inflight = [{"job": j, "epoch": e}
                            for j, e in self._running.items()]
                results = list(self._done.values())
            return {"op": "adopted", "inflight": inflight,
                    "results": results}
        if op == "ping":
            with self._lock:
                n = len(self._running)
            return {"op": "pong", "pid": os.getpid(), "inflight": n}
        if op == "shutdown":
            self._stop.set()
            return {"op": "bye"}
        return {"op": "error", "error": f"unknown op {op!r}"}

    def _serve_conn(self, conn: socket.socket) -> None:
        with self._lock:
            old, self._conn = self._conn, conn
        if old is not None:
            try:
                old.close()
            except OSError:
                pass
        rfile = conn.makefile("r")
        try:
            for line in rfile:
                if not line.strip():
                    continue
                try:
                    reply = self._handle(json.loads(line))
                except Exception:   # noqa: BLE001
                    reply = {"op": "error", "error": traceback.format_exc()}
                if reply is not None:
                    try:
                        conn.sendall((json.dumps(reply, default=str)
                                      + "\n").encode())
                    except OSError:
                        break
                if self._stop.is_set():
                    break
        finally:
            with self._lock:
                if self._conn is conn:
                    self._conn = None
            try:
                conn.close()
            except OSError:
                pass

    def serve(self) -> None:
        sock_path = self.root / "sock"
        if sock_path.exists():
            sock_path.unlink()
        srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        srv.bind(str(sock_path))
        srv.listen(2)
        srv.settimeout(0.5)
        info = self.root / "worker.json"
        tmp = info.with_suffix(".json.tmp")
        tmp.write_text(json.dumps({"pid": os.getpid(),
                                   "sock": str(sock_path)}))
        os.replace(tmp, info)
        while not self._stop.is_set():
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()
        # wait for in-flight jobs so their results land in the buffer
        while True:
            with self._lock:
                if not self._running:
                    break
            time.sleep(0.05)
        srv.close()
        # retire the advert: a graceful exit must not leave a stale
        # pid/socket for the next engine's liveness probe to trip over —
        # but only if it is still *ours* (a replacement worker may have
        # re-advertised while we drained)
        try:
            mine = json.loads(info.read_text())["pid"] == os.getpid()
        except (OSError, ValueError, KeyError, json.JSONDecodeError):
            mine = False
        if mine:
            info.unlink(missing_ok=True)
            sock_path.unlink(missing_ok=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="acai-worker")
    ap.add_argument("--dir", required=True)
    args = ap.parse_args(argv)
    _Worker(Path(args.dir)).serve()
    return 0


if __name__ == "__main__":
    sys.exit(main())
