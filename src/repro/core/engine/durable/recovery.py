"""Crash recovery: replay snapshot + journal into a live engine.

``recover(engine)`` rebuilds the registry, pool capacities and runner
checkpoint progress from the durable store, then re-enters every
non-terminal job through the ordinary ``Scheduler.submit`` path as a
*new epoch* — the PR-5 epoch guards make the crashed incarnation's
stragglers (a zombie worker's late terminal event, a replayed journal
record) recognizably stale, so nothing can double-settle.

Recovery invariants:

1. **No lost jobs** — every journaled ``submit`` yields a registry entry;
   non-terminal ones re-queue (in original submit order, so ``depends_on``
   resolves against already-rebuilt parents) and run to a terminal state.
2. **No duplicated terminal events** — terminal jobs are adopted as-is
   and never re-run; a replayed/duplicate terminal record for a job that
   is already terminal (or for a superseded epoch) is dropped in
   :func:`fold`, and live stragglers are dropped by the epoch guards.
3. **Progress survives** — a preempted job's checkpointed fraction
   (journaled ``progress`` records) is restored into the runner before
   the requeue, so the relaunch resumes from the checkpoint, exactly as
   a live preemption would.
4. **Workers outlive the engine** — when the launcher is a
   :class:`SubprocessRunner`, its worker process is re-adopted: results
   it buffered while the engine was down apply as terminals (no re-run),
   and jobs still in flight re-attach at their original epoch instead of
   re-queueing.

Recording is paused for the duration (rebuilding from the journal must
not re-journal the rebuild); a fresh compacted snapshot is written at
the end, so a second crash recovers from clean state.
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Optional

from repro.core.engine.durable.codec import decode_job, encode_job, \
    json_safe
from repro.core.engine.lifecycle import TERMINAL_STATES, JobState

_TERMINAL_VALUES = frozenset(s.value for s in TERMINAL_STATES)


@dataclasses.dataclass
class RecoveryReport:
    jobs_total: int = 0
    terminal: int = 0           # adopted as-is, never re-run
    requeued: int = 0           # non-terminal: re-entered as new epochs
    adopted: int = 0            # still in flight on a surviving worker
    worker_results: int = 0     # completed while the engine was down
    resumed: int = 0            # requeues restored from a checkpoint
    events_replayed: int = 0
    wall_s: float = 0.0


# -- snapshot construction ----------------------------------------------
def snapshot_state(engine) -> dict:
    """Full-state snapshot document: every job, the id counter, runner
    checkpoint progress, and live pool capacities (elastic resizes must
    survive the restart)."""
    registry = engine.registry
    doc: dict = {"v": 1, "ctr": registry._ctr,
                 "jobs": [encode_job(j) for j in registry.all_jobs()]}
    prog_fn = getattr(engine.launcher, "checkpoint_progress", None)
    if callable(prog_fn):
        prog = {jid: f for jid, f in prog_fn().items() if f}
        if prog:
            doc["progress"] = prog
    pools = getattr(engine.scheduler, "pools", None) or {}
    if pools:
        doc["pools"] = {name: json_safe(cl.capacity)
                        for name, cl in pools.items()}
    return doc


# -- journal fold --------------------------------------------------------
def fold(snapshot: Optional[dict],
         events: list[dict]) -> tuple[dict, dict, dict]:
    """Fold snapshot + journal into per-job records with idempotent apply
    semantics: records carry absolute states and epochs, stale-epoch
    records and duplicate terminals are dropped. Returns
    ``(job docs by id, pool capacities, checkpoint progress)``."""
    records: dict[str, dict] = {}
    pools: dict[str, dict] = {}
    progress: dict[str, float] = {}
    if snapshot:
        for doc in snapshot.get("jobs", ()):
            records[doc["job_id"]] = dict(doc)
        pools.update(snapshot.get("pools", {}))
        progress.update(snapshot.get("progress", {}))
    for ev in events:
        t = ev.get("t")
        if t == "submit":
            jid = ev["job"]
            if jid in records:
                continue        # replayed submit: idempotent
            records[jid] = {"job_id": jid, "spec": ev["spec"],
                            "state": "SUBMITTED",
                            "submitted_at": ev.get("at"),
                            "epoch": 0, "preemptions": 0, "outputs": {}}
        elif t == "state":
            rec = records.get(ev["job"])
            if rec is None:
                continue
            if int(ev.get("epoch", 0)) < int(rec.get("epoch", 0)):
                continue        # superseded incarnation's write: stale
            if rec.get("state") in _TERMINAL_VALUES:
                # one refinement is legal out of a terminal: FAILED ->
                # QUARANTINED (the crash-loop verdict lands after the
                # failure's own terminal record); everything else is a
                # duplicate terminal for a settled job
                if not (ev["state"] == JobState.QUARANTINED.value and
                        rec.get("state") == JobState.FAILED.value):
                    continue
            rec["state"] = ev["state"]
            rec["epoch"] = int(ev.get("epoch", 0))
            if ev.get("pool") is not None:
                rec["pool"] = ev["pool"]
            if ev.get("error") is not None:
                rec["error"] = ev["error"]
            for field in ("finished_at", "runtime", "cost"):
                if ev.get(field) is not None:
                    rec[field] = ev[field]
        elif t == "preempt":
            rec = records.get(ev["job"])
            if rec is None or rec.get("state") in _TERMINAL_VALUES:
                continue
            if int(ev.get("epoch", 0)) <= int(rec.get("epoch", 0)):
                continue        # replayed bump: the epoch already moved
            rec["epoch"] = int(ev["epoch"])
            rec["preemptions"] = int(ev.get("preemptions",
                                            rec.get("preemptions", 0)))
            rec["state"] = JobState.PREEMPTED.value
        elif t == "retry":
            rec = records.get(ev["job"])
            if rec is None:
                continue
            if int(ev.get("epoch", 0)) <= int(rec.get("epoch", 0)):
                continue        # replayed rebirth: the epoch already moved
            # epoch rebirth out of FAILED: unlike every other record this
            # deliberately overrides a terminal state — the retry budget
            # resurrected the job, and the counters must survive so a
            # recovered engine doesn't grant a crash-looper a fresh budget
            rec["state"] = JobState.QUEUED.value
            rec["epoch"] = int(ev["epoch"])
            rec["retries"] = int(ev.get("retries",
                                        rec.get("retries", 0) + 1))
            rec["failures"] = int(ev.get("failures",
                                         rec.get("failures", 0)))
            rec["finished_at"] = None
            if ev.get("error") is not None:
                rec["error"] = ev["error"]
        elif t == "progress":
            progress[ev["job"]] = float(ev.get("done_frac", 0.0))
        elif t == "final":
            rec = records.get(ev["job"])
            if rec is None:
                continue
            if int(ev.get("epoch", 0)) < int(rec.get("epoch", 0)):
                continue
            rec["state"] = ev.get("state", rec.get("state"))
            rec["epoch"] = int(ev.get("epoch", rec.get("epoch", 0)))
            for field in ("runtime", "cost", "error"):
                if ev.get(field) is not None:
                    rec[field] = ev[field]
            if ev.get("outputs"):
                rec["outputs"] = ev["outputs"]
        elif t == "resize":
            pools[ev["pool"]] = ev.get("capacity", {})
    return records, pools, progress


def _idnum(job_id: str) -> tuple:
    m = re.fullmatch(r"job-(\d+)", job_id)
    return (0, int(m.group(1))) if m else (1, job_id)


# -- recovery entry ------------------------------------------------------
def recover(engine) -> RecoveryReport:
    """Replay the engine's durable store into its live scheduler/registry
    (see the module docstring for the invariants). Returns a report;
    requeued jobs still need the engine driven (``wait_all`` / handle
    waits) to reach terminal states."""
    t0 = time.perf_counter()
    journal = engine.journal
    registry, scheduler = engine.registry, engine.scheduler
    launcher = engine.launcher
    snap, events = journal.load()
    records, pools, progress = fold(snap, events)
    report = RecoveryReport(jobs_total=len(records),
                            events_replayed=len(events))
    with journal.paused():
        for name, cap in pools.items():
            cl = scheduler.pools.get(name)
            if cl is not None:
                scheduler.resize_pool(name, {n: float(v)
                                             for n, v in cap.items()})
        order = sorted(records.values(),
                       key=lambda d: _idnum(d["job_id"]))
        for doc in order:
            registry.adopt(decode_job(doc))
        # process-boundary runner: re-adopt the surviving worker before
        # deciding requeues — its buffered results and in-flight set
        # reclassify jobs the journal last saw as RUNNING
        inflight: dict[str, int] = {}
        results: list[dict] = []
        adopt_fn = getattr(launcher, "adopt", None)
        if callable(adopt_fn):
            inflight, results = adopt_fn()
        apply_fn = getattr(launcher, "apply_result", None)
        for msg in results:
            try:
                job = registry.get(msg.get("job", ""))
            except KeyError:
                continue
            if job.state in TERMINAL_STATES or not callable(apply_fn):
                continue        # duplicate of a journaled terminal: drop
            ep = msg.get("epoch")
            if ep is not None and int(ep) == job.epoch and \
                    job.state not in (JobState.RUNNING, JobState.PREEMPTED):
                # the worker's durable record proves this incarnation
                # reached RUNNING even if the journal lost the state
                # records; reconstruct that step so the terminal applies
                job.state = JobState.RUNNING
            if apply_fn(job, msg, publish=False):
                report.worker_results += 1
                engine.monitor.record_status(job.job_id, job.state.value)
        restore = getattr(launcher, "restore_progress", None)
        for doc in order:
            job = registry.get(doc["job_id"])
            if job.state in TERMINAL_STATES:
                report.terminal += 1
                engine.monitor.record_status(job.job_id, job.state.value,
                                             overwrite=False)
                continue
            if inflight.get(job.job_id) == job.epoch and \
                    job.state in (JobState.RUNNING, JobState.LAUNCHING):
                scheduler.adopt_running(job)
                report.adopted += 1
                continue
            frac = progress.get(job.job_id)
            if frac and callable(restore):
                restore(job.job_id, frac)
                report.resumed += 1
            # re-enter as a fresh incarnation: the epoch bump makes any
            # straggler of the crashed run (zombie worker, replayed
            # record) recognizably stale
            job.state = JobState.SUBMITTED
            job.epoch += 1
            job.started_at = None
            job.finished_at = None
            job.pool = None
            job.gang_pods = None
            scheduler.submit(job)
            report.requeued += 1
    journal.snapshot()      # compacted base: a second crash starts clean
    report.wall_s = time.perf_counter() - t0
    return report
