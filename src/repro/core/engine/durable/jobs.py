"""Importable job payloads for the process-boundary runner.

``SubprocessRunner`` serializes job fns as ``module:qualname``
references, so tests, the crash drill and CLI examples need module-level
callables a bare worker interpreter can import. Each follows the engine
contract ``fn(workdir: Path, job) -> dict``.
"""
from __future__ import annotations

import time
from pathlib import Path


def echo_job(workdir: Path, job) -> dict:
    """Return (and print) the submitted message."""
    msg = job.spec.args.get("msg", "hello")
    print(f"echo: {msg}")
    return {"echo": msg}


def sleep_job(workdir: Path, job) -> dict:
    """Sleep ``args['seconds']`` — in-flight fodder for crash tests."""
    seconds = float(job.spec.args.get("seconds", 0.1))
    time.sleep(seconds)
    return {"slept": seconds}


def append_once_job(workdir: Path, job) -> dict:
    """Append one line to ``args['path']`` — a side-effect counter: the
    exactly-once tests assert the file has one line per job id no matter
    how many times the engine crashed and recovered around it."""
    path = Path(job.spec.args["path"])
    delay = float(job.spec.args.get("seconds", 0.0))
    if delay:
        time.sleep(delay)
    with path.open("a") as fh:
        fh.write(f"{job.job_id}\n")
    return {"marked": job.job_id}


def fail_job(workdir: Path, job) -> dict:
    """Fail deterministically."""
    raise RuntimeError(job.spec.args.get("msg", "deliberate failure"))
