"""JSON codec for every spec/event shape the journal persists.

Specs and jobs must round-trip through the store and back into live
objects: ``encode_spec``/``decode_spec`` cover ``JobSpec`` including the
nested ``GangSpec`` and per-pool resource menus, ``encode_job``/
``decode_job`` cover the full ``Job`` record (epoch, preemptions, gang
width, outputs), and ``encode_transfer_costs`` flattens the
``TransferCostModel``'s tuple-keyed pair table into JSON-safe rows.

The one lossy field is ``JobSpec.fn``: a callable cannot cross a process
boundary, so it is serialized as an importable ``"module:qualname"``
reference. Lambdas and local functions encode to ``None`` — a virtual
job (``spec.duration``) recovers fine without its fn; a real job whose
fn is gone decodes to a stub that FAILs loudly at launch instead of
silently "finishing" as a no-op.
"""
from __future__ import annotations

import importlib
import math
from typing import Any, Callable, Optional

from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import GangSpec, Job, JobSpec, RetryPolicy


# -- fn references -------------------------------------------------------
def encode_fn(fn: Optional[Callable]) -> Optional[str]:
    """``"module:qualname"`` when the callable is importable from a fresh
    process, else None (lambdas, closures, REPL functions)."""
    if fn is None:
        return None
    mod = getattr(fn, "__module__", None)
    qual = getattr(fn, "__qualname__", None)
    if not mod or not qual or "<" in qual:      # <lambda>, <locals>
        return None
    return f"{mod}:{qual}"


def _unresolvable(ref: str) -> Callable:
    def _fail(workdir, job):
        raise RuntimeError(
            f"job fn {ref!r} is not importable in this process; "
            f"re-submit with an importable module-level callable")
    _fail.__qualname__ = "<unresolvable>"
    return _fail


def decode_fn(ref: Optional[str]) -> Optional[Callable]:
    if ref is None:
        return None
    mod, _, qual = ref.partition(":")
    try:
        obj: Any = importlib.import_module(mod)
        for part in qual.split("."):
            obj = getattr(obj, part)
        return obj
    except Exception:
        return _unresolvable(ref)


# -- JSON safety ---------------------------------------------------------
def json_safe(obj: Any) -> Any:
    """Recursively coerce to JSON-representable values (non-finite floats
    and arbitrary objects become strings); dict keys become strings."""
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else str(obj)
    if isinstance(obj, dict):
        return {str(k): json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [json_safe(v) for v in obj]
    return str(obj)


# -- GangSpec ------------------------------------------------------------
def encode_gang(gang: Optional[GangSpec]) -> Optional[dict]:
    if gang is None:
        return None
    return {"n_pods": gang.n_pods,
            "per_pod_resources": json_safe(gang.per_pod_resources),
            "topology": gang.topology,
            "min_pods": gang.min_pods}


def decode_gang(doc: Optional[dict]) -> Optional[GangSpec]:
    if doc is None:
        return None
    return GangSpec(n_pods=int(doc["n_pods"]),
                    per_pod_resources=doc.get("per_pod_resources"),
                    topology=doc.get("topology", "any"),
                    min_pods=int(doc.get("min_pods", 0)))


# -- RetryPolicy ---------------------------------------------------------
def encode_retry(retry: Optional[RetryPolicy]) -> Optional[dict]:
    if retry is None:
        return None
    return {"max_retries": retry.max_retries,
            "backoff_base": retry.backoff_base,
            "backoff_cap": retry.backoff_cap,
            "retry_on": retry.retry_on}


def decode_retry(doc: Optional[dict]) -> Optional[RetryPolicy]:
    if doc is None:
        return None
    return RetryPolicy(max_retries=int(doc.get("max_retries", 3)),
                       backoff_base=float(doc.get("backoff_base", 1.0)),
                       backoff_cap=float(doc.get("backoff_cap", 60.0)),
                       retry_on=doc.get("retry_on", "transient"))


# -- JobSpec -------------------------------------------------------------
def encode_spec(spec: JobSpec) -> dict:
    return {
        "name": spec.name,
        "project": spec.project,
        "user": spec.user,
        "fn": encode_fn(spec.fn),
        "argv": list(spec.argv) if spec.argv is not None else None,
        "input_fileset": spec.input_fileset,
        "output_fileset": spec.output_fileset,
        "resources": json_safe(spec.resources),
        "args": json_safe(spec.args),
        "duration": spec.duration,
        "priority": spec.priority,
        "depends_on": list(spec.depends_on or ()),
        "pool": spec.pool,
        "pool_resources": json_safe(spec.pool_resources),
        "template": spec.template,
        "gang": encode_gang(spec.gang),
        "input_bytes": spec.input_bytes,
        "retry": encode_retry(getattr(spec, "retry", None)),
        "timeout_s": getattr(spec, "timeout_s", None),
        "deadline": getattr(spec, "deadline", None),
    }


def decode_spec(doc: dict) -> JobSpec:
    return JobSpec(
        name=doc["name"],
        project=doc.get("project", ""),
        user=doc.get("user", ""),
        fn=decode_fn(doc.get("fn")),
        argv=doc.get("argv"),
        input_fileset=doc.get("input_fileset"),
        output_fileset=doc.get("output_fileset"),
        resources=dict(doc.get("resources") or {}),
        args=dict(doc.get("args") or {}),
        duration=doc.get("duration"),
        priority=int(doc.get("priority", 0)),
        depends_on=list(doc.get("depends_on") or ()),
        pool=doc.get("pool"),
        pool_resources={p: dict(r) for p, r in
                        (doc.get("pool_resources") or {}).items()},
        template=doc.get("template"),
        gang=decode_gang(doc.get("gang")),
        input_bytes=float(doc.get("input_bytes", 0.0)),
        retry=decode_retry(doc.get("retry")),
        timeout_s=doc.get("timeout_s"),
        deadline=doc.get("deadline"),
    )


# -- Job (snapshot records) ----------------------------------------------
def encode_job(job: Job) -> dict:
    return {
        "job_id": job.job_id,
        "spec": encode_spec(job.spec),
        "state": job.state.value,
        "submitted_at": job.submitted_at,
        "started_at": job.started_at,
        "finished_at": job.finished_at,
        "runtime": job.runtime,
        "cost": job.cost,
        "pool": job.pool,
        "error": job.error,
        "outputs": json_safe(job.outputs),
        "epoch": job.epoch,
        "preemptions": job.preemptions,
        "gang_pods": job.gang_pods,
        "retries": job.retries,
        "failures": job.failures,
    }


def decode_job(doc: dict) -> Job:
    job = Job(job_id=doc["job_id"], spec=decode_spec(doc["spec"]),
              state=JobState(doc.get("state", "SUBMITTED")))
    job.submitted_at = doc.get("submitted_at") or job.submitted_at
    job.started_at = doc.get("started_at")
    job.finished_at = doc.get("finished_at")
    job.runtime = doc.get("runtime")
    job.cost = doc.get("cost")
    job.pool = doc.get("pool")
    job.error = doc.get("error")
    job.outputs = dict(doc.get("outputs") or {})
    job.epoch = int(doc.get("epoch", 0))
    job.preemptions = int(doc.get("preemptions", 0))
    gp = doc.get("gang_pods")
    job.gang_pods = int(gp) if gp is not None else None
    job.retries = int(doc.get("retries", 0))
    job.failures = int(doc.get("failures", 0))
    return job


# -- FaultPlan -----------------------------------------------------------
def encode_fault_plan(plan) -> Optional[dict]:
    if plan is None:
        return None
    return {"seed": plan.seed,
            "node_mtbf_s": plan.node_mtbf_s,
            "transient_mtbf_s": plan.transient_mtbf_s,
            "straggler_mtbf_s": plan.straggler_mtbf_s,
            "straggler_factor": plan.straggler_factor,
            "start": plan.start,
            "max_node_failures": plan.max_node_failures}


def decode_fault_plan(doc: Optional[dict]):
    if doc is None:
        return None
    from repro.core.engine.faults import FaultPlan
    mnf = doc.get("max_node_failures")
    return FaultPlan(
        seed=int(doc.get("seed", 0)),
        node_mtbf_s=doc.get("node_mtbf_s"),
        transient_mtbf_s=doc.get("transient_mtbf_s"),
        straggler_mtbf_s=doc.get("straggler_mtbf_s"),
        straggler_factor=float(doc.get("straggler_factor", 4.0)),
        start=float(doc.get("start", 0.0)),
        max_node_failures=int(mnf) if mnf is not None else None)


# -- TransferCostModel ---------------------------------------------------
def encode_transfer_costs(model) -> dict:
    """Flatten a ``TransferCostModel``: the pair table is keyed by
    ``(src_pool, dst_pool)`` tuples, which JSON cannot key — store it as
    ``[src, dst, rate]`` rows instead."""
    return {
        "cost_per_gb": model.cost_per_gb,
        "pair_cost_per_gb": [[s, d, r] for (s, d), r in
                             sorted(model.pair_cost_per_gb.items())],
        "interconnect_weight": model.interconnect_weight,
    }


def decode_transfer_costs(doc: dict):
    from repro.core.engine.placement import TransferCostModel
    return TransferCostModel(
        cost_per_gb=float(doc.get("cost_per_gb", 0.0)),
        pair_cost_per_gb={(s, d): float(r) for s, d, r in
                          (doc.get("pair_cost_per_gb") or ())},
        interconnect_weight=float(doc.get("interconnect_weight", 1.0)))
