"""Durable control plane: journaled engine state, crash-recoverable
restart, and the process-boundary runner (ACAI's Redis-backed engine,
reproduced as a pluggable StateStore + write-ahead journal)."""
from repro.core.engine.durable.codec import (decode_job, decode_spec,
                                             decode_transfer_costs,
                                             encode_job, encode_spec,
                                             encode_transfer_costs)
from repro.core.engine.durable.journal import (Journal,
                                               attach_terminal_recorder)
from repro.core.engine.durable.recovery import (RecoveryReport, recover,
                                                snapshot_state)
from repro.core.engine.durable.runner import SubprocessRunner
from repro.core.engine.durable.store import (FileStore, MemoryStore,
                                             StateStore)
