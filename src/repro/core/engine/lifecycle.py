"""Job life-cycle state machine (ACAI Fig. 3).

SUBMITTED -> QUEUED -> LAUNCHING -> RUNNING -> {FINISHED, FAILED}
KILLED is reachable from any non-terminal state. The (input fileset, job,
output fileset) triplet is immutable: a job can be submitted/scheduled once.
"""
from __future__ import annotations

import enum


class JobState(str, enum.Enum):
    SUBMITTED = "SUBMITTED"
    QUEUED = "QUEUED"
    LAUNCHING = "LAUNCHING"
    RUNNING = "RUNNING"
    FINISHED = "FINISHED"
    FAILED = "FAILED"
    KILLED = "KILLED"


_TRANSITIONS = {
    JobState.SUBMITTED: {JobState.QUEUED, JobState.KILLED},
    JobState.QUEUED: {JobState.LAUNCHING, JobState.KILLED},
    JobState.LAUNCHING: {JobState.RUNNING, JobState.FAILED, JobState.KILLED},
    JobState.RUNNING: {JobState.FINISHED, JobState.FAILED, JobState.KILLED},
    JobState.FINISHED: set(),
    JobState.FAILED: set(),
    JobState.KILLED: set(),
}

ACTIVE_STATES = {JobState.LAUNCHING, JobState.RUNNING}
TERMINAL_STATES = {JobState.FINISHED, JobState.FAILED, JobState.KILLED}


class IllegalTransition(RuntimeError):
    pass


def check_transition(old: JobState, new: JobState) -> None:
    if new not in _TRANSITIONS[old]:
        raise IllegalTransition(f"{old.value} -> {new.value}")
