"""Job life-cycle state machine (ACAI Fig. 3, extended with dataflow
and checkpoint-aware preemption).

SUBMITTED -> QUEUED -> LAUNCHING -> RUNNING -> {FINISHED, FAILED}
KILLED is reachable from any non-terminal state. UPSTREAM_FAILED is the
terminal outcome of a job that never launched because a declared
dependency (``JobSpec.depends_on``) ended FAILED/KILLED/UPSTREAM_FAILED —
only jobs that have not yet launched can cascade, so it is reachable from
SUBMITTED and QUEUED alone.

PREEMPTED is the one *non-terminal* exit from RUNNING: the scheduler
revoked the job's reservation (priority starvation, a spot reclamation,
or a pool shrink), the runner delivered a checkpoint signal, and the job
re-enters QUEUED for a fresh launch that resumes from its last
checkpoint. This relaxes the original submit-once invariant: the
(input fileset, job, output fileset) triplet is still immutable and the
job id never changes, but a job may now be *scheduled* more than once —
each requeue bumps ``Job.epoch`` so terminal events from a superseded
incarnation are recognizably stale.

Retry rides the same epoch machinery: a FAILED incarnation whose
``JobSpec.retry`` budget allows it is *reborn* into QUEUED by
``JobRegistry.mark_retrying`` — like crash recovery's requeue, a rebirth
is an epoch bump plus direct reassignment, not an edge in the transition
table, so the table itself stays closed (every edge out of a terminal
state lands in a terminal state; FAILED -> QUARANTINED is the only such
edge, refining a crash-looping job's terminal outcome).

QUARANTINED is the crash-loop terminal: K consecutive non-transient
failures and the scheduler stops burning retry budget on the job.
"""
from __future__ import annotations

import enum


class JobState(str, enum.Enum):
    SUBMITTED = "SUBMITTED"
    QUEUED = "QUEUED"
    LAUNCHING = "LAUNCHING"
    RUNNING = "RUNNING"
    PREEMPTED = "PREEMPTED"
    FINISHED = "FINISHED"
    FAILED = "FAILED"
    KILLED = "KILLED"
    UPSTREAM_FAILED = "UPSTREAM_FAILED"
    QUARANTINED = "QUARANTINED"


_TRANSITIONS = {
    JobState.SUBMITTED: {JobState.QUEUED, JobState.KILLED,
                         JobState.UPSTREAM_FAILED},
    JobState.QUEUED: {JobState.LAUNCHING, JobState.KILLED,
                      JobState.UPSTREAM_FAILED},
    JobState.LAUNCHING: {JobState.RUNNING, JobState.FAILED, JobState.KILLED},
    JobState.RUNNING: {JobState.FINISHED, JobState.FAILED, JobState.KILLED,
                       JobState.PREEMPTED},
    JobState.PREEMPTED: {JobState.QUEUED, JobState.KILLED},
    JobState.FINISHED: set(),
    # terminal refinement: a crash-looping FAILED job may be re-labelled
    # QUARANTINED (still terminal) — the one edge out of a terminal state
    JobState.FAILED: {JobState.QUARANTINED},
    JobState.KILLED: set(),
    JobState.UPSTREAM_FAILED: set(),
    JobState.QUARANTINED: set(),
}

ACTIVE_STATES = {JobState.LAUNCHING, JobState.RUNNING}
TERMINAL_STATES = {JobState.FINISHED, JobState.FAILED, JobState.KILLED,
                   JobState.UPSTREAM_FAILED, JobState.QUARANTINED}
# hoisted for event-path dispatch: publishers put the state *value* on the
# bus, and handlers must not rebuild this set per event
TERMINAL_STATUS_VALUES = frozenset(s.value for s in TERMINAL_STATES)


class IllegalTransition(RuntimeError):
    pass


class JobPreempted(RuntimeError):
    """The scheduler's checkpoint signal reached the job: save state and
    stop. Raised by cooperative job functions (see ``train/fault.py``,
    which re-exports it for ``TrainSupervisor``); the preemption-capable
    runners treat it as a hand-back, not a failure."""


class TransientJobError(RuntimeError):
    """A failure the job itself believes is retryable: a lost connection,
    a flaky dependency, a revoked spot node. Job functions raise it (or a
    subclass) instead of a bare exception to tell the runner the failure
    is *transient*; runners stamp the terminal event accordingly and a
    ``RetryPolicy(retry_on="transient")`` requeues the job where an
    arbitrary exception would make it terminally FAILED. Re-exported from
    ``train/fault.py`` alongside ``JobPreempted`` (it lives here so the
    engine can classify failures without importing the jax train stack).
    """


def check_transition(old: JobState, new: JobState) -> None:
    if new not in _TRANSITIONS[old]:
        raise IllegalTransition(f"{old.value} -> {new.value}")
