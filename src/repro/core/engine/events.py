"""In-process pub/sub event bus (the paper's Redis stand-in, §4.2).

Three topics: ``container_status`` (published by the launcher watching the
cluster), ``job_progress`` (published by the in-container agent:
downloading, running, uploading...), and ``scheduler_metrics`` (cluster
utilization / queue-depth snapshots from the capacity scheduler).
Synchronous delivery keeps the engine deterministic for tests; a real
deployment swaps this for Redis without changing publishers/subscribers.

Publish/subscribe are thread-safe for the ThreadPoolRunner's workers;
handlers are invoked outside the bus lock (handlers take their own locks,
and holding the bus lock across them would invert lock order).
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Any, Callable

TOPIC_CONTAINER_STATUS = "container_status"
TOPIC_JOB_PROGRESS = "job_progress"
TOPIC_SCHEDULER = "scheduler_metrics"


class EventBus:
    def __init__(self):
        self._subs: dict[str, list[Callable[[dict], None]]] = defaultdict(list)
        self.history: list[tuple[str, dict]] = []
        self._lock = threading.RLock()

    def subscribe(self, topic: str, fn: Callable[[dict], None]) -> None:
        with self._lock:
            self._subs[topic].append(fn)

    def publish(self, topic: str, msg: dict) -> None:
        with self._lock:
            self.history.append((topic, dict(msg)))
            subs = list(self._subs[topic])
        for fn in subs:
            fn(dict(msg))
