"""In-process pub/sub event bus (the paper's Redis stand-in, §4.2).

Three topics: ``container_status`` (published by the launcher watching the
cluster), ``job_progress`` (published by the in-container agent:
downloading, running, uploading...), and ``scheduler_metrics`` (cluster
utilization / queue-depth snapshots from the capacity scheduler).
Synchronous delivery keeps the engine deterministic for tests; a real
deployment swaps this for Redis without changing publishers/subscribers.

``history`` is a bounded ring buffer (``history_limit`` most recent
messages) — a long-lived engine publishes one event per state transition
per job, so an unbounded log would grow O(total events) for the life of
the process. Each publish snapshots the message exactly once; the same
frozen dict is appended to history and handed to every subscriber, so
messages must be treated as immutable after publish (subscribers that
need a private mutable copy make their own).

Publish/subscribe are thread-safe for the ThreadPoolRunner's workers;
handlers are invoked outside the bus lock (handlers take their own locks,
and holding the bus lock across them would invert lock order).
"""
from __future__ import annotations

import threading
from collections import defaultdict, deque
from typing import Callable

TOPIC_CONTAINER_STATUS = "container_status"
TOPIC_JOB_PROGRESS = "job_progress"
TOPIC_SCHEDULER = "scheduler_metrics"

DEFAULT_HISTORY_LIMIT = 10_000


class EventBus:
    def __init__(self, history_limit: int = DEFAULT_HISTORY_LIMIT, *,
                 store=None, stream: str = "events"):
        """``store`` (a durable ``StateStore``) persists every published
        message to ``stream`` — the Redis-stream half of the paper's bus:
        a fresh process (CLI ``status``/``logs``) reads the stream
        instead of needing to have been subscribed when events fired."""
        self._subs: dict[str, list[Callable[[dict], None]]] = defaultdict(list)  # guarded-by: _lock
        self.history: deque[tuple[str, dict]] = deque(maxlen=history_limit)
        self._store = store
        self._stream = stream
        # handlers are invoked OUTSIDE this lock (they take their own —
        # holding it across them inverts lock order), hence no bare
        # calls and no nested publish under it
        self._lock = threading.RLock()  # acailint: lock(forbid: bare-calls, publish)

    def subscribe(self, topic: str, fn: Callable[[dict], None]) -> None:
        with self._lock:
            self._subs[topic].append(fn)

    def publish(self, topic: str, msg: dict) -> None:
        # one defensive copy per publish (the caller may reuse/mutate its
        # dict); history and every subscriber share that copy instead of
        # re-copying per consumer
        msg = dict(msg)
        with self._lock:
            self.history.append((topic, msg))
            if self._store is not None:
                self._store.append(self._stream, {"topic": topic, **msg})
            subs = list(self._subs[topic])
        for fn in subs:
            fn(msg)
