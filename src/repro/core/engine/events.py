"""In-process pub/sub event bus (the paper's Redis stand-in, §4.2).

Two primary topics, exactly as the paper: ``container_status`` (published by
the launcher watching the cluster) and ``job_progress`` (published by the
in-container agent: downloading, running, uploading...). Synchronous
delivery keeps the engine deterministic for tests; a real deployment swaps
this for Redis without changing publishers/subscribers.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Callable

TOPIC_CONTAINER_STATUS = "container_status"
TOPIC_JOB_PROGRESS = "job_progress"


class EventBus:
    def __init__(self):
        self._subs: dict[str, list[Callable[[dict], None]]] = defaultdict(list)
        self.history: list[tuple[str, dict]] = []

    def subscribe(self, topic: str, fn: Callable[[dict], None]) -> None:
        self._subs[topic].append(fn)

    def publish(self, topic: str, msg: dict) -> None:
        self.history.append((topic, dict(msg)))
        for fn in list(self._subs[topic]):
            fn(dict(msg))
