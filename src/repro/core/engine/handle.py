"""Job futures: the handle half of the pipeline SDK.

``AcaiEngine.submit`` (and ``AcaiPlatform.submit_job``) return a
``JobHandle`` — a future over one job's lifecycle. Synchronisation is
event-driven, not polled: terminal ``container_status`` events on the
EventBus wake waiters through ``JobMonitor.wait_terminal``. Runners that
only make progress when stepped (the virtual clock, and the thread pool's
drain protocol) are driven from inside ``wait`` so a bare
``handle.result()`` is always enough to resolve a job — no ``run_all()``
required.

NSML-style session handles (PAPERS.md) are the model: the handle is the
*only* object a user needs to keep after submit.
"""
from __future__ import annotations

import time
from typing import Any, Optional

from repro.core.engine.lifecycle import TERMINAL_STATES, JobState
from repro.core.engine.registry import Job, JobSpec


class JobFailedError(RuntimeError):
    """``result()`` on a job that ended FAILED or KILLED."""

    def __init__(self, job: Job):
        self.job_id = job.job_id
        self.state = job.state
        super().__init__(f"{job.job_id} ({job.spec.name}) ended "
                         f"{job.state.value}: {job.error or 'no error'}")


class UpstreamFailedError(JobFailedError):
    """``result()`` on a job cascade-cancelled by a failed dependency."""


class JobHandle:
    """Future over one submitted job.

    Cheap and immutable: holds only the job id and the engine assembly
    (registry / scheduler / launcher / monitor); all state reads go to the
    registry, all blocking goes through the EventBus.
    """

    def __init__(self, job: Job, engine):
        self.job_id: str = job.job_id
        self._engine = engine

    # -- introspection ---------------------------------------------------
    @property
    def job(self) -> Job:
        return self._engine.registry.get(self.job_id)

    @property
    def spec(self) -> JobSpec:
        return self.job.spec

    def status(self) -> JobState:
        return self.job.state

    def done(self) -> bool:
        # a FAILED job whose retry decision is still pending is not done:
        # the scheduler may rebirth it as a new epoch a moment later
        return (self.job.state in TERMINAL_STATES
                and not self.job.retry_pending)

    # -- blocking --------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> JobState:
        """Block until the job is terminal; returns the terminal state.

        Raises TimeoutError if ``timeout`` seconds elapse first, and
        RuntimeError if the job can provably never finish (nothing running,
        nothing to step — e.g. waiting on a handle whose engine was never
        drained and has no runnable work).
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        launcher = self._engine.launcher
        while True:
            state = self.status()
            if state in TERMINAL_STATES and not self.job.retry_pending:
                return state
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"{self.job_id} still {state.value} after "
                        f"{timeout}s")
            if getattr(launcher, "threaded", False):
                # workers publish terminal events; block on the bus
                self._engine.monitor.wait_terminal(self.job_id, remaining)
            elif callable(getattr(launcher, "step", None)) \
                    and launcher.pending() > 0:
                launcher.step()     # drive the virtual clock forward
            else:
                raise RuntimeError(
                    f"{self.job_id} is {state.value} but the engine has "
                    f"no runnable work to make progress on")

    def result(self, timeout: Optional[float] = None) -> dict[str, Any]:
        """Wait, then return the job's outputs; raises on non-FINISHED."""
        state = self.wait(timeout)
        job = self.job
        if state == JobState.FINISHED:
            return dict(job.outputs)
        if state == JobState.UPSTREAM_FAILED:
            raise UpstreamFailedError(job)
        raise JobFailedError(job)

    def outputs(self, timeout: Optional[float] = None) -> dict[str, Any]:
        """Wait, then return the outputs dict regardless of outcome
        (log text, fileset ref if any, user-returned values)."""
        self.wait(timeout)
        return dict(self.job.outputs)

    def logs(self) -> str:
        """Log text captured so far (complete once the job is terminal)."""
        return self.job.outputs.get("log", "")

    def cancel(self) -> JobState:
        """Kill the job (queued, held-on-dependencies, or running); held
        dependents cascade to UPSTREAM_FAILED. Returns the new state."""
        self._engine.scheduler.kill(self.job_id)
        return self.status()

    def __repr__(self) -> str:
        return (f"JobHandle({self.job_id}, {self.spec.name!r}, "
                f"{self.status().value})")


def wait_all(handles: list[JobHandle],
             timeout: Optional[float] = None) -> list[JobState]:
    """Resolve every handle; returns terminal states in handle order."""
    deadline = None if timeout is None else time.monotonic() + timeout
    states = []
    for h in handles:
        remaining = None if deadline is None \
            else max(0.0, deadline - time.monotonic())
        states.append(h.wait(remaining))
    return states
