"""Pipeline DAG builder: declared dataflow over the futures SDK.

The paper's headline workflow is the *vertical pipeline* — ETL -> train ->
eval chained through file sets — fanned out *horizontally* across a config
sweep (§1, §3, §5.2). ``Pipeline`` lets users declare exactly that:

    pipe = engine.pipeline("sweep")
    etl = pipe.stage(JobSpec(..., output_fileset="TrainSet"))
    runs = pipe.map(lambda p: JobSpec(..., input_fileset="TrainSet",
                                      output_fileset=f"model-{p['lr']}"),
                    {"lr": [0.5, 0.1], "hidden": [8, 16]})
    report = pipe.stage(JobSpec(...), after=runs)
    handles = pipe.run()            # JobHandle per stage, DAG-gated

Edges come from two sources, merged and deduplicated:
  * explicit ``after=[stage, ...]`` declarations, and
  * inferred dataflow — a stage whose ``input_fileset`` names another
    stage's ``output_fileset`` depends on that producer.

``run()`` topologically sorts the stages (cycles are rejected), stamps
each spec's ``depends_on`` with the parent job ids, and submits; the
scheduler holds children until every parent FINISHES and cascades
UPSTREAM_FAILED otherwise. Each declared edge is also recorded in the
project's ProvenanceGraph (action="pipeline_dep"), so lineage reflects the
*declared* dataflow, not just observed reads/writes.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Iterable, Optional, Union

from repro.core.engine.handle import JobHandle, wait_all
from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import GangSpec, JobSpec


class Stage:
    """One node of the pipeline DAG; resolves to a JobHandle after run()."""

    def __init__(self, spec: JobSpec, after: list["Stage"]):
        self.spec = spec
        self.after = after
        self.handle: Optional[JobHandle] = None

    @property
    def job_id(self) -> Optional[str]:
        return self.handle.job_id if self.handle is not None else None

    def __repr__(self) -> str:
        state = self.handle.status().value if self.handle else "declared"
        return f"Stage({self.spec.name!r}, {state})"


StageOrStages = Union[Stage, Iterable[Stage]]


class Pipeline:
    def __init__(self, engine, *, name: str = "pipeline",
                 submit: Optional[Callable[..., JobHandle]] = None):
        self._engine = engine
        self.name = name
        self._submit = submit or \
            (lambda spec: engine.submit(spec, pipeline=name))
        self._stages: list[Stage] = []
        self._ran = False

    # -- declaration -----------------------------------------------------
    def stage(self, spec: JobSpec, after: StageOrStages = (),
              gang: Union[int, GangSpec, None] = None) -> Stage:
        """Declare one stage; ``after`` adds explicit dependency edges on
        previously declared stages (dataflow edges are inferred anyway).

        ``gang=n`` makes the stage a co-scheduled gang of ``n`` pods, each
        with the spec's ``resources`` shape (sharded multi-host training
        next to single-pod sweep jobs, in one pipeline); pass a
        :class:`GangSpec` for per-pod overrides, topology hints, or an
        elastic ``min_pods`` floor.
        """
        if self._ran:
            raise RuntimeError("pipeline already ran; declare a new one")
        if gang is not None:
            spec.gang = gang if isinstance(gang, GangSpec) \
                else GangSpec(n_pods=int(gang))
        after = [after] if isinstance(after, Stage) else list(after)
        for parent in after:
            if parent not in self._stages:
                raise ValueError(
                    f"after= references a stage not in pipeline "
                    f"{self.name!r}: {parent!r}")
        st = Stage(spec, after)
        self._stages.append(st)
        return st

    def map(self, spec_fn: Callable[[dict[str, Any]], JobSpec],
            grid: Union[dict[str, Iterable], Iterable[dict[str, Any]]],
            after: StageOrStages = (),
            gang: Union[int, GangSpec, None] = None) -> list[Stage]:
        """Horizontal fan-out: one stage per grid point.

        ``grid`` is either a dict of value-lists (cartesian product, the
        hyperparameter-sweep case) or an explicit iterable of param dicts;
        ``spec_fn(params)`` builds each stage's JobSpec. ``gang`` applies
        to every fanned-out stage (see :meth:`stage`).
        """
        if isinstance(grid, dict):
            keys = list(grid)
            combos = [dict(zip(keys, vals))
                      for vals in itertools.product(*(grid[k] for k in keys))]
        else:
            combos = [dict(g) for g in grid]
        return [self.stage(spec_fn(params), after=after, gang=gang)
                for params in combos]

    # -- DAG assembly ----------------------------------------------------
    def _parents(self) -> dict[int, list[Stage]]:
        """Explicit ``after`` edges + inferred fileset-dataflow edges,
        deduplicated, keyed by id(stage)."""
        producers: dict[str, list[Stage]] = {}
        for st in self._stages:
            if st.spec.output_fileset:
                producers.setdefault(st.spec.output_fileset, []).append(st)
        parents: dict[int, list[Stage]] = {}
        for st in self._stages:
            ps = list(st.after)
            if st.spec.input_fileset:
                ps += [p for p in producers.get(st.spec.input_fileset, [])
                       if p is not st]
            seen: set[int] = set()
            parents[id(st)] = [p for p in ps if not
                               (id(p) in seen or seen.add(id(p)))]
        return parents

    def run(self) -> list[JobHandle]:
        """Submit every stage (topological order), returning handles in
        declaration order. Raises ValueError on a dependency cycle."""
        if self._ran:
            raise RuntimeError("pipeline already ran")
        parents = self._parents()
        remaining = list(self._stages)
        done: set[int] = set()
        order: list[Stage] = []
        while remaining:
            ready = [st for st in remaining
                     if all(id(p) in done for p in parents[id(st)])]
            if not ready:
                cyc = ", ".join(st.spec.name for st in remaining)
                raise ValueError(
                    f"pipeline {self.name!r} has a dependency cycle "
                    f"among: {cyc}")
            for st in ready:
                order.append(st)
                done.add(id(st))
            remaining = [st for st in remaining if id(st) not in done]
        for st in order:
            dep_ids = [p.handle.job_id for p in parents[id(st)]]
            merged = list(st.spec.depends_on or []) + dep_ids
            st.spec.depends_on = list(dict.fromkeys(merged))
            st.handle = self._submit(st.spec)
        self._ran = True
        return self.handles

    # -- resolution ------------------------------------------------------
    @property
    def handles(self) -> list[JobHandle]:
        return [st.handle for st in self._stages if st.handle is not None]

    def wait(self, timeout: Optional[float] = None) -> list[JobState]:
        """Resolve every stage; returns terminal states in declaration
        order."""
        if not self._ran:
            raise RuntimeError("pipeline.run() first")
        return wait_all(self.handles, timeout)
