"""Cluster capacity model (ACAI §3.3.1 scaled up).

The paper schedules jobs onto shared cloud capacity; the seed engine only
gated on a per-(project, user) quota, which admits unbounded aggregate
resources. ``Cluster`` holds finite totals per resource dimension and the
scheduler reserves/releases against them on launch/terminal events, so the
engine models a real shared deployment: admission waits for capacity, and
utilization is observable.

Totals are derived from the pricing model's node shapes — a "node" is the
largest allocatable amount per dimension in ``pricing.grid()`` — times a
node count, mirroring how a real cluster is a number of machine shapes.
"""
from __future__ import annotations

import threading
from typing import Any, Optional


class CapacityError(RuntimeError):
    """A reservation that can never fit (exceeds cluster totals)."""


class Cluster:
    """Finite multi-dimensional capacity with per-job reservations.

    All mutating calls are thread-safe (the ThreadPoolRunner finalizes jobs
    from worker threads). Missing dimensions in a job's resource dict are
    charged at ``defaults`` (the pricing minimum), matching how
    ``Pricing.job_cost`` bills them. Dimensions the cluster does not have
    (e.g. ``chips`` on a CPU pool) are kept in the charge with an implicit
    capacity of zero, so ``fits``/``ever_fits`` reject instead of silently
    admitting the job as if the request were free.

    ``name`` identifies the pool in a heterogeneous deployment (one
    Cluster per accelerator family; see ``core/engine/placement.py``).
    ``spot`` marks a preemptible pool (priced below on-demand, capacity
    reclaimable at any time — the scheduler models a reclamation as a
    forced preemption) and ``reclaim_rate`` is its expected reclamations
    per second, which the placement layer prices into spot scores.
    """

    def __init__(self, capacity: dict[str, float],
                 defaults: Optional[dict[str, float]] = None,
                 name: str = "default", *, spot: bool = False,
                 reclaim_rate: float = 0.0):
        self.name = name
        self.spot = spot
        self.reclaim_rate = reclaim_rate
        self.capacity = {k: float(v) for k, v in capacity.items()}
        self.defaults = dict(defaults or {})
        self.used: dict[str, float] = {k: 0.0 for k in self.capacity}
        self._held: dict[str, dict[str, float]] = {}   # job_id -> resources
        # accounting-drift counters: a release that would drive ``used``
        # negative is clamped but *counted* (see ``release``), so a
        # double-release bug surfaces in stats instead of silently
        # vanishing into the clamp
        self.stats = {"release_underflow": 0, "release_underflow_amount": 0.0}
        self._lock = threading.RLock()

    # -- construction ---------------------------------------------------
    @classmethod
    def from_pricing(cls, pricing, nodes: int = 8,
                     name: str = "default") -> "Cluster":
        """Totals = ``nodes`` x the largest node shape the pricing allocates."""
        capacity = {name_: max(dim.values) * nodes
                    for name_, dim in pricing.dims.items()}
        defaults = {name_: dim.minimum for name_, dim in pricing.dims.items()}
        return cls(capacity, defaults, name=name)

    # -- normalization --------------------------------------------------
    def charge(self, resources: Optional[dict[str, Any]]) -> dict[str, float]:
        """The amounts a job is billed against capacity, per dimension.

        Dimensions requested but absent from ``capacity`` are included so
        admission rejects them (capacity for an unknown dimension is zero);
        dropping them would admit e.g. a ``tpu=8`` job onto a CPU pool for
        free."""
        resources = resources or {}
        req = {name: float(resources.get(name, self.defaults.get(name, 0.0)))
               for name in self.capacity}
        for name, amt in resources.items():
            if name not in req:
                req[name] = float(amt)
        return req

    # -- admission ------------------------------------------------------
    def fits(self, resources: Optional[dict[str, Any]]) -> bool:
        return self.fits_charge(self.charge(resources))

    def fits_charge(self, req: dict[str, float]) -> bool:
        """Admission check on a pre-computed charge (the scheduler caches
        charges at submit to keep the dispatch scan cheap)."""
        with self._lock:
            return all(self.used.get(n, 0.0) + amt
                       <= self.capacity.get(n, 0.0) + 1e-9
                       for n, amt in req.items())

    def ever_fits(self, resources: Optional[dict[str, Any]]) -> bool:
        """Could this job run on an empty cluster at all?"""
        return self.ever_fits_charge(self.charge(resources))

    def ever_fits_charge(self, req: dict[str, float]) -> bool:
        return all(amt <= self.capacity.get(n, 0.0) + 1e-9
                   for n, amt in req.items())

    def reserve(self, job_id: str,
                resources: Optional[dict[str, Any]]) -> dict[str, float]:
        req = self.charge(resources)
        with self._lock:
            if job_id in self._held:
                return self._held[job_id]
            if not all(self.used.get(n, 0.0) + amt
                       <= self.capacity.get(n, 0.0) + 1e-9
                       for n, amt in req.items()):
                raise CapacityError(f"{job_id}: {req} oversubscribes "
                                    f"{self.name}: {self.free()}")
            for n, amt in req.items():
                if n in self.used:
                    self.used[n] += amt
            self._held[job_id] = req
            return req

    def release(self, job_id: str) -> Optional[dict[str, float]]:
        """Idempotent: releasing an unknown/already-released job is a no-op.

        A release that would drive ``used`` below zero means the books
        drifted (a double-release or an externally-mutated ``used``); the
        value is still clamped to keep the pool usable, but the drift is
        counted in ``stats`` so it cannot silently mask an accounting bug.
        """
        with self._lock:
            req = self._held.pop(job_id, None)
            if req is not None:
                for n, amt in req.items():
                    if n in self.used:
                        left = self.used[n] - amt
                        if left < -1e-9:
                            self.stats["release_underflow"] += 1
                            self.stats["release_underflow_amount"] += -left
                            left = 0.0
                        self.used[n] = max(0.0, left)
            return req

    # -- elasticity -----------------------------------------------------
    def resize(self, capacity: dict[str, float]) -> dict[str, float]:
        """Set new totals for the given dimensions (others keep theirs).

        Reservations are untouched: shrinking below live usage leaves the
        pool *over-committed* (``used > capacity``) until the scheduler
        drains the overage — via the preemption path, or by letting the
        outliving jobs finish naturally. Returns the per-dimension
        overage (``used - capacity`` where positive) so the caller knows
        what must drain; new admissions are rejected meanwhile because
        ``fits`` already fails on an over-committed dimension.
        """
        with self._lock:
            for n, v in capacity.items():
                self.capacity[n] = float(v)
                self.used.setdefault(n, 0.0)
            return {n: self.used[n] - self.capacity[n]
                    for n in capacity
                    if self.used[n] > self.capacity[n] + 1e-9}

    def held(self, job_id: str) -> Optional[dict[str, float]]:
        with self._lock:
            return dict(self._held[job_id]) if job_id in self._held else None

    def reservations(self) -> dict[str, dict[str, float]]:
        with self._lock:
            return {jid: dict(res) for jid, res in self._held.items()}

    # -- observability --------------------------------------------------
    def free(self) -> dict[str, float]:
        with self._lock:
            return {n: self.capacity[n] - self.used[n] for n in self.capacity}

    def utilization(self) -> dict[str, float]:
        """Per-dimension used/capacity. A zero-capacity dimension with
        live usage (a pool shrunk to nothing under running reservations)
        reports ``inf`` — a flagged over-commit, not a silent 0% — and
        never divides by zero."""
        with self._lock:
            out = {}
            for n in self.capacity:
                cap = self.capacity[n]
                if cap > 0:
                    out[n] = self.used[n] / cap
                else:
                    out[n] = float("inf") if self.used[n] > 1e-9 else 0.0
            return out

    def dominant_share(self, resources: Optional[dict[str, Any]]) -> float:
        """DRF-style dominant share of one job's charge — the fair-share
        accounting unit (usage = dominant_share x runtime)."""
        return self.dominant_share_charge(self.charge(resources))

    def dominant_share_charge(self, req: dict[str, float]) -> float:
        """Dominant share of an already-normalized charge (the scheduler
        settles with the reservation it released, which *is* a charge —
        re-normalizing it through ``charge()`` is an identity walk)."""
        shares = [amt / self.capacity[n] for n, amt in req.items()
                  if self.capacity.get(n, 0.0) > 0]
        return max(shares) if shares else 0.0
