"""Cluster capacity model (ACAI §3.3.1 scaled up).

The paper schedules jobs onto shared cloud capacity; the seed engine only
gated on a per-(project, user) quota, which admits unbounded aggregate
resources. ``Cluster`` holds finite totals per resource dimension and the
scheduler reserves/releases against them on launch/terminal events, so the
engine models a real shared deployment: admission waits for capacity, and
utilization is observable.

Totals are derived from the pricing model's node shapes — a "node" is the
largest allocatable amount per dimension in ``pricing.grid()`` — times a
node count, mirroring how a real cluster is a number of machine shapes.
"""
from __future__ import annotations

import threading
from typing import Any, Optional


class CapacityError(RuntimeError):
    """A reservation that can never fit (exceeds cluster totals)."""


class Cluster:
    """Finite multi-dimensional capacity with per-job reservations.

    All mutating calls are thread-safe (the ThreadPoolRunner finalizes jobs
    from worker threads). Missing dimensions in a job's resource dict are
    charged at ``defaults`` (the pricing minimum), matching how
    ``Pricing.job_cost`` bills them. Dimensions the cluster does not have
    (e.g. ``chips`` on a CPU pool) are kept in the charge with an implicit
    capacity of zero, so ``fits``/``ever_fits`` reject instead of silently
    admitting the job as if the request were free.

    ``name`` identifies the pool in a heterogeneous deployment (one
    Cluster per accelerator family; see ``core/engine/placement.py``).
    ``spot`` marks a preemptible pool (priced below on-demand, capacity
    reclaimable at any time — the scheduler models a reclamation as a
    forced preemption) and ``reclaim_rate`` is its expected reclamations
    per second, which the placement layer prices into spot scores.
    """

    def __init__(self, capacity: dict[str, float],
                 defaults: Optional[dict[str, float]] = None,
                 name: str = "default", *, spot: bool = False,
                 reclaim_rate: float = 0.0,
                 node_shape: Optional[dict[str, float]] = None,
                 close_gang_pods: Optional[int] = None):
        self.name = name
        self.spot = spot
        self.reclaim_rate = reclaim_rate
        self.capacity = {k: float(v) for k, v in capacity.items()}
        self.defaults = dict(defaults or {})
        # ``used``/``capacity`` are read lock-free by scheduler hot paths
        # (dashboards, snapshots) — a torn read there is a stale gauge,
        # not a correctness bug — so they deliberately carry no
        # guarded-by annotation; every *write* still happens under _lock
        self.used: dict[str, float] = {k: 0.0 for k in self.capacity}
        self._held: dict[str, dict[str, float]] = {}  # guarded-by: _lock
        # gang holds: job_id -> (per-pod charge, pod count). The aggregate
        # (n_pods x per-pod) also lives in ``_held`` so release/settle paths
        # need no gang awareness; this record is what makes a shrink-to-k
        # resize and partial-hold audits possible.
        self._gangs: dict[str, tuple[dict[str, float], int]] = {}  # guarded-by: _lock
        # node-granular accounting (opt in): a pool built from whole nodes
        # of ``node_shape`` tracks per-node free vectors so a gang's pods
        # must each pack onto SOME node, not merely fit the pool aggregate.
        # job_id -> [(node_idx, per-pod charge), ...]
        self.node_shape = dict(node_shape) if node_shape else None
        self._node_free: list[dict[str, float]] = []  # guarded-by: _lock
        self._node_holds: dict[str, list[tuple[int, dict[str, float]]]] = {}  # guarded-by: _lock
        if self.node_shape:
            self._node_free = [dict(self.node_shape)
                               for _ in range(self._target_nodes())]
        # node health: indices of down nodes (failed or draining) are
        # excluded from packing and their shape is subtracted from the
        # aggregate capacity; residents of a *failed* node are handed to
        # the caller to kill/retry, residents of a *drained* node finish
        # naturally (the pool runs over-committed meanwhile).
        # node_idx -> "failed" | "drained"
        self._down: dict[int, str] = {}  # guarded-by: _lock
        # topology: how many gang pods this pool can host "close" (one
        # interconnect island). None = unconstrained; the placement layer
        # penalizes (not rejects) close-topology gangs that exceed it.
        self.close_gang_pods = close_gang_pods
        # accounting-drift counters: a release that would drive ``used``
        # negative is clamped but *counted* (see ``release``), so a
        # double-release bug surfaces in stats instead of silently
        # vanishing into the clamp
        self.stats = {"release_underflow": 0, "release_underflow_amount": 0.0}
        self._lock = threading.RLock()

    def _target_nodes(self) -> int:
        """Node count implied by capacity / node_shape (max across dims
        tolerates a partially-shaped pool)."""
        counts = [self.capacity.get(d, 0.0) / amt
                  for d, amt in (self.node_shape or {}).items() if amt > 0]
        return max(1, int(round(max(counts, default=1))))

    # -- construction ---------------------------------------------------
    @classmethod
    def from_pricing(cls, pricing, nodes: int = 8,
                     name: str = "default") -> "Cluster":
        """Totals = ``nodes`` x the largest node shape the pricing allocates."""
        capacity = {name_: max(dim.values) * nodes
                    for name_, dim in pricing.dims.items()}
        defaults = {name_: dim.minimum for name_, dim in pricing.dims.items()}
        return cls(capacity, defaults, name=name)

    # -- normalization --------------------------------------------------
    def charge(self, resources: Optional[dict[str, Any]]) -> dict[str, float]:
        """The amounts a job is billed against capacity, per dimension.

        Dimensions requested but absent from ``capacity`` are included so
        admission rejects them (capacity for an unknown dimension is zero);
        dropping them would admit e.g. a ``tpu=8`` job onto a CPU pool for
        free."""
        resources = resources or {}
        req = {name: float(resources.get(name, self.defaults.get(name, 0.0)))
               for name in self.capacity}
        for name, amt in resources.items():
            if name not in req:
                req[name] = float(amt)
        return req

    # -- admission ------------------------------------------------------
    def fits(self, resources: Optional[dict[str, Any]]) -> bool:
        return self.fits_charge(self.charge(resources))

    def fits_charge(self, req: dict[str, float]) -> bool:
        """Admission check on a pre-computed charge (the scheduler caches
        charges at submit to keep the dispatch scan cheap)."""
        with self._lock:
            return all(self.used.get(n, 0.0) + amt
                       <= self.capacity.get(n, 0.0) + 1e-9
                       for n, amt in req.items())

    def ever_fits(self, resources: Optional[dict[str, Any]]) -> bool:
        """Could this job run on an empty cluster at all?"""
        return self.ever_fits_charge(self.charge(resources))

    def ever_fits_charge(self, req: dict[str, float]) -> bool:
        return all(amt <= self.capacity.get(n, 0.0) + 1e-9
                   for n, amt in req.items())

    def reserve(self, job_id: str,
                resources: Optional[dict[str, Any]]) -> dict[str, float]:
        req = self.charge(resources)
        with self._lock:
            if job_id in self._held:
                return self._held[job_id]
            if not all(self.used.get(n, 0.0) + amt
                       <= self.capacity.get(n, 0.0) + 1e-9
                       for n, amt in req.items()):
                raise CapacityError(f"{job_id}: {req} oversubscribes "
                                    f"{self.name}: {self.free()}")
            for n, amt in req.items():
                if n in self.used:
                    self.used[n] += amt
            self._held[job_id] = req
            return req

    # -- gang admission (atomic all-or-none) ----------------------------
    def _node_fits(self, free: dict[str, float],
                   pod: dict[str, float]) -> bool:
        return all(free.get(n, 0.0) + 1e-9 >= amt
                   for n, amt in pod.items() if amt > 0)

    def _pack_pods(self, pod: dict[str, float],
                   n_pods: int) -> Optional[list[int]]:
        """First-fit node indices for ``n_pods`` pods of shape ``pod``
        against the current free vectors — or None if they cannot all be
        placed. Pure planning: mutates nothing. Callers already hold the
        lock; re-entering the RLock here keeps the free-vector read
        atomic even for a future caller that does not."""
        with self._lock:
            shadow = [dict(f) for f in self._node_free]
            picked: list[int] = []
            for _ in range(n_pods):
                for i, free in enumerate(shadow):
                    if i in self._down:
                        continue    # dead/draining node: never packable
                    if self._node_fits(free, pod):
                        for n, amt in pod.items():
                            free[n] = free.get(n, 0.0) - amt
                        picked.append(i)
                        break
                else:
                    return None
            return picked

    def can_pack(self, per_pod: Optional[dict[str, Any]],
                 n_pods: int) -> bool:
        """Would ``n_pods`` pods of ``per_pod`` each fit on some node right
        now?  Pools without node accounting fall back to the aggregate
        check (any aggregate fit is trivially packable)."""
        pod = self.charge(per_pod)
        agg = {n: amt * n_pods for n, amt in pod.items()}
        with self._lock:
            if not self.fits_charge(agg):
                return False
            if self.node_shape is None:
                return True
            if n_pods == 1:
                # hot path (every single job on a node-shaped pool asks
                # this at dispatch): scan free vectors in place, no
                # shadow copies
                return any(self._node_fits(free, pod)
                           for i, free in enumerate(self._node_free)
                           if i not in self._down)
            return self._pack_pods(pod, n_pods) is not None

    def reserve_gang(self, job_id: str, per_pod: Optional[dict[str, Any]],
                     n_pods: int) -> dict[str, float]:
        """Atomically reserve ``n_pods`` pods of ``per_pod`` each:
        reserve-all-or-release-all, so a gang can never partially hold
        capacity. Returns the *aggregate* charge (which is what
        ``release``/settle later hand back). Idempotent per job_id."""
        if n_pods < 1:
            raise ValueError(f"{job_id}: gang needs n_pods >= 1")
        pod = self.charge(per_pod)
        agg = {n: amt * n_pods for n, amt in pod.items()}
        with self._lock:
            if job_id in self._held:
                return self._held[job_id]
            if not self.fits_charge(agg):
                raise CapacityError(f"{job_id}: gang {n_pods}x{pod} "
                                    f"oversubscribes {self.name}: "
                                    f"{self.free()}")
            if self.node_shape is not None:
                picked = self._pack_pods(pod, n_pods)
                if picked is None:
                    # aggregate fits but the pods cannot all be node-packed
                    raise CapacityError(
                        f"{job_id}: gang {n_pods}x{pod} does not pack "
                        f"onto {self.name}'s nodes")
                holds = []
                for i in picked:
                    for n, amt in pod.items():
                        self._node_free[i][n] = \
                            self._node_free[i].get(n, 0.0) - amt
                    holds.append((i, dict(pod)))
                self._node_holds[job_id] = holds
            for n, amt in agg.items():
                if n in self.used:
                    self.used[n] += amt
            self._held[job_id] = agg
            self._gangs[job_id] = (pod, n_pods)
            return agg

    def gang_of(self, job_id: str) -> Optional[tuple[dict[str, float], int]]:
        """(per-pod charge, pod count) for a live gang hold, else None."""
        with self._lock:
            g = self._gangs.get(job_id)
            return (dict(g[0]), g[1]) if g is not None else None

    def shrink_gang_hold(self, job_id: str, k: int) -> dict[str, float]:
        """Shrink a live gang reservation to ``k`` pods in place (elastic
        resize): frees the (n-k) surplus pods' charge — and their node
        slots — without ever dropping to zero pods held. Returns the
        per-dimension amount freed."""
        with self._lock:
            if job_id not in self._gangs:
                raise KeyError(f"{job_id}: no gang hold on {self.name}")
            pod, n = self._gangs[job_id]
            if not (1 <= k <= n):
                raise ValueError(f"{job_id}: shrink to {k} of {n} pods")
            drop = n - k
            freed = {dim: amt * drop for dim, amt in pod.items()}
            for dim, amt in freed.items():
                if dim in self.used:
                    self.used[dim] = max(0.0, self.used[dim] - amt)
            if job_id in self._node_holds:
                holds = self._node_holds[job_id]
                for i, pcharge in holds[k:]:
                    if i < len(self._node_free):
                        for dim, amt in pcharge.items():
                            self._node_free[i][dim] = \
                                self._node_free[i].get(dim, 0.0) + amt
                self._node_holds[job_id] = holds[:k]
            self._gangs[job_id] = (pod, k)
            self._held[job_id] = {dim: amt * k for dim, amt in pod.items()}
            return freed

    def release(self, job_id: str) -> Optional[dict[str, float]]:
        """Idempotent: releasing an unknown/already-released job is a no-op.

        A gang hold releases whole: every pod's charge (and node slot)
        comes back in the same call — release-all mirrors reserve-all.

        A release that would drive ``used`` below zero means the books
        drifted (a double-release or an externally-mutated ``used``); the
        value is still clamped to keep the pool usable, but the drift is
        counted in ``stats`` so it cannot silently mask an accounting bug.
        """
        with self._lock:
            req = self._held.pop(job_id, None)
            self._gangs.pop(job_id, None)
            for i, pod in self._node_holds.pop(job_id, []):
                if i < len(self._node_free):
                    for n, amt in pod.items():
                        self._node_free[i][n] = \
                            self._node_free[i].get(n, 0.0) + amt
            if req is not None:
                for n, amt in req.items():
                    if n in self.used:
                        left = self.used[n] - amt
                        if left < -1e-9:
                            self.stats["release_underflow"] += 1
                            self.stats["release_underflow_amount"] += -left
                            left = 0.0
                        self.used[n] = max(0.0, left)
            return req

    # -- node health ----------------------------------------------------
    def _mark_down(self, node_idx: int, kind: str) -> list[str]:
        if self.node_shape is None:
            raise ValueError(f"{self.name}: node health needs node_shape")
        with self._lock:
            if not (0 <= node_idx < len(self._node_free)):
                raise IndexError(f"{self.name}: no node {node_idx}")
            residents = []
            if node_idx not in self._down:
                self._down[node_idx] = kind
                # the node's whole shape leaves the aggregate books; live
                # usage stays until residents release, so the pool may run
                # over-committed exactly like a shrink under load
                for dim, amt in self.node_shape.items():
                    if dim in self.capacity:
                        self.capacity[dim] = max(
                            0.0, self.capacity[dim] - amt)
            else:
                self._down[node_idx] = kind
            for jid, holds in self._node_holds.items():
                if any(i == node_idx for i, _ in holds):
                    residents.append(jid)
            return residents

    def fail_node(self, node_idx: int) -> list[str]:
        """Kill a node: it stops packing, its shape leaves capacity, and
        the job_ids holding reservations on it are returned for the
        caller (the scheduler / fault injector) to fail — a gang with any
        pod on the node fails whole, since its reservation releases
        atomically. Reservations themselves are NOT touched here: the
        scheduler's settle path releases them when it fails the jobs."""
        return self._mark_down(node_idx, "failed")

    def drain_node(self, node_idx: int) -> list[str]:
        """Cordon a node: no new pods pack onto it, but residents keep
        running and release naturally. Returns the resident job_ids for
        observability."""
        return self._mark_down(node_idx, "drained")

    def node_health(self) -> dict[str, Any]:
        """{"nodes": total, "up": n, "failed": [...], "drained": [...]}
        — empty-ish for pools without node accounting."""
        with self._lock:
            failed = sorted(i for i, k in self._down.items()
                            if k == "failed")
            drained = sorted(i for i, k in self._down.items()
                             if k == "drained")
            total = len(self._node_free)
            return {"nodes": total, "up": total - len(self._down),
                    "failed": failed, "drained": drained}

    def up_nodes(self) -> list[int]:
        """Indices of schedulable nodes (for the fault injector's target
        draw — deterministic given the same history)."""
        with self._lock:
            return [i for i in range(len(self._node_free))
                    if i not in self._down]

    # -- elasticity -----------------------------------------------------
    def resize(self, capacity: dict[str, float]) -> dict[str, float]:
        """Set new totals for the given dimensions (others keep theirs).

        Reservations are untouched: shrinking below live usage leaves the
        pool *over-committed* (``used > capacity``) until the scheduler
        drains the overage — via the preemption path, or by letting the
        outliving jobs finish naturally. Returns the per-dimension
        overage (``used - capacity`` where positive) so the caller knows
        what must drain; new admissions are rejected meanwhile because
        ``fits`` already fails on an over-committed dimension.
        """
        with self._lock:
            for n, v in capacity.items():
                self.capacity[n] = float(v)
                self.used.setdefault(n, 0.0)
            if self.node_shape is not None:
                target = self._target_nodes()
                while len(self._node_free) < target:
                    self._node_free.append(dict(self.node_shape))
                # shrink only trims *empty* trailing nodes; nodes still
                # hosting pods survive until their gangs drain (the pool
                # is over-committed meanwhile, same as the aggregate books)
                busy = {i for holds in self._node_holds.values()
                        for i, _ in holds}
                while len(self._node_free) > target:
                    idx = len(self._node_free) - 1
                    if idx in busy:
                        break
                    self._node_free.pop()
                    self._down.pop(idx, None)
            return {n: self.used[n] - self.capacity[n]
                    for n in capacity
                    if self.used[n] > self.capacity[n] + 1e-9}

    def held(self, job_id: str) -> Optional[dict[str, float]]:
        with self._lock:
            return dict(self._held[job_id]) if job_id in self._held else None

    def reservations(self) -> dict[str, dict[str, float]]:
        with self._lock:
            return {jid: dict(res) for jid, res in self._held.items()}

    def gang_reservations(self) -> dict[str, tuple[dict[str, float], int]]:
        """Live gang holds: {job_id: (per-pod charge, pod count)} — what
        the scheduler's shrink-to-k drain enumerates."""
        with self._lock:
            return {jid: (dict(pod), n)
                    for jid, (pod, n) in self._gangs.items()}

    # -- observability --------------------------------------------------
    def free(self) -> dict[str, float]:
        with self._lock:
            return {n: self.capacity[n] - self.used[n] for n in self.capacity}

    def utilization(self) -> dict[str, float]:
        """Per-dimension used/capacity. A zero-capacity dimension with
        live usage (a pool shrunk to nothing under running reservations)
        reports ``inf`` — a flagged over-commit, not a silent 0% — and
        never divides by zero."""
        with self._lock:
            out = {}
            for n in self.capacity:
                cap = self.capacity[n]
                if cap > 0:
                    out[n] = self.used[n] / cap
                else:
                    out[n] = float("inf") if self.used[n] > 1e-9 else 0.0
            return out

    def dominant_share(self, resources: Optional[dict[str, Any]]) -> float:
        """DRF-style dominant share of one job's charge — the fair-share
        accounting unit (usage = dominant_share x runtime)."""
        return self.dominant_share_charge(self.charge(resources))

    def dominant_share_charge(self, req: dict[str, float]) -> float:
        """Dominant share of an already-normalized charge (the scheduler
        settles with the reservation it released, which *is* a charge —
        re-normalizing it through ``charge()`` is an identity walk)."""
        shares = [amt / self.capacity[n] for n, amt in req.items()
                  if self.capacity.get(n, 0.0) > 0]
        return max(shares) if shares else 0.0
