"""Job launcher + in-container agent (ACAI §4.2, §4.2.1).

The paper provisions a Kubernetes container whose pre-installed agent
downloads code + input file set, runs the user command, uploads the output
file set, and broadcasts progress on the event bus. The ``Runner`` interface
reproduces that protocol; two implementations ship:

  LocalRunner      — executes the job's python callable synchronously in a
                     scratch "container" directory (real measured runtime).
  ThreadPoolRunner — LocalRunner semantics on a bounded worker pool:
                     ``launch`` returns immediately and the agent protocol
                     (download/run/upload/publish) runs on a worker thread;
                     ``pending``/``step`` let the scheduler drain it like
                     the virtual runner.
  VirtualRunner    — completes jobs on a virtual clock using a runtime
                     oracle (duration = spec.duration or oracle(job)); this
                     is what the auto-provisioning experiments schedule
                     thousands of profiling jobs on, and what exercises
                     quota/capacity/straggler logic deterministically. It
                     exposes expected completion times so the scheduler's
                     EASY backfill can compute shadow start times.
"""
from __future__ import annotations

import heapq
import io
import sys
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from contextlib import contextmanager, redirect_stdout
from pathlib import Path
from typing import Callable, Optional

from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_JOB_PROGRESS)
from repro.core.engine.lifecycle import (IllegalTransition, JobState,
                                         TERMINAL_STATES)
from repro.core.engine.logparse import parse_log
from repro.core.engine.registry import Job, JobRegistry


def resolve_pricing(pricing, job: Job):
    """The pricing that bills ``job``: a plain ``Pricing`` applies to every
    job; a catalog (``{pool_name: Pricing}``, heterogeneous deployments)
    resolves through the pool placement launched the job on."""
    if isinstance(pricing, dict):
        if job.pool and job.pool in pricing:
            return pricing[job.pool]
        if "default" in pricing:
            return pricing["default"]
        return next(iter(pricing.values()), None) if pricing else None
    return pricing


class Runner:
    # True when jobs complete on worker threads (terminal events arrive
    # asynchronously); JobHandle.wait blocks on the bus instead of stepping
    threaded = False

    # runner-clock time, or None to fall back to wall time: the virtual
    # runner advances this; schedulers read it for queue-wait accounting,
    # fair-share decay and backfill math
    now: Optional[float] = None

    def launch(self, job: Job) -> None:
        raise NotImplementedError

    # -- optional hooks the capacity scheduler consults -----------------
    def expected_duration(self, job: Job,
                          pool: Optional[str] = None) -> Optional[float]:
        """Best-effort runtime estimate for backfill — on ``pool`` when
        the scheduler is sizing a specific pool's hole; None if unknown.
        Must be a pure read when ``job.spec.duration`` is declared (the
        scheduler may then consult it eagerly at enqueue); estimates that
        draw from an oracle are only requested from inside a dispatch
        scan, and are drawn once per (job, pool)."""
        return job.spec.duration

    def expected_end(self, job_id: str) -> Optional[float]:
        """Expected completion time of a running job; None if unknown.
        The scheduler reads this once, immediately after ``launch``, to
        feed the pool's incrementally-maintained shadow state — the
        estimate must therefore be available synchronously at launch (the
        virtual runner schedules the completion inside ``launch``) and
        stay fixed for the life of the job."""
        return None


class LocalRunner(Runner):
    """Synchronous agent: download -> run -> upload -> publish."""

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 datalake=None, workroot: str = "/tmp/acai-jobs",
                 pricing=None):
        self.registry = registry
        self.bus = bus
        self.datalake = datalake            # AcaiProject-like facade or None
        self.workroot = Path(workroot)
        self.pricing = pricing

    def _capture(self, log_buf: io.StringIO):
        """Capture the job fn's stdout into its log buffer."""
        return redirect_stdout(log_buf)

    def launch(self, job: Job) -> None:
        bus, reg = self.bus, self.registry
        try:
            reg.set_state(job.job_id, JobState.RUNNING)
        except IllegalTransition:
            # killed between dispatch and worker pickup: publish the
            # terminal status so waiters and dependents still observe it
            reg.persist_state(job.job_id)
            bus.publish(TOPIC_CONTAINER_STATUS,
                        {"job_id": job.job_id,
                         "status": reg.get(job.job_id).state.value})
            return
        bus.publish(TOPIC_CONTAINER_STATUS,
                    {"job_id": job.job_id, "status": "provisioned"})
        workdir = self.workroot / job.job_id
        (workdir / "out").mkdir(parents=True, exist_ok=True)
        log_buf = io.StringIO()
        t0 = time.perf_counter()
        try:
            if job.spec.input_fileset and self.datalake is not None:
                bus.publish(TOPIC_JOB_PROGRESS,
                            {"job_id": job.job_id, "stage": "downloading"})
                self.datalake.filesets.materialize(job.spec.input_fileset,
                                                   workdir)
            bus.publish(TOPIC_JOB_PROGRESS,
                        {"job_id": job.job_id, "stage": "running"})
            with self._capture(log_buf):
                result = job.spec.fn(workdir, job) if job.spec.fn else None
            if isinstance(result, dict):
                job.outputs.update(result)
            runtime = time.perf_counter() - t0
            job.runtime = job.spec.duration if job.spec.duration is not None \
                else runtime
            self._upload_outputs(job, workdir, bus)
            self._finalize(job, log_buf.getvalue(), JobState.FINISHED)
        except Exception:  # noqa: BLE001 — user code failure => FAILED
            job.runtime = time.perf_counter() - t0
            self._finalize(job, log_buf.getvalue()
                           + "\n" + traceback.format_exc(), JobState.FAILED,
                           error=traceback.format_exc())

    def _upload_outputs(self, job: Job, workdir: Path, bus: EventBus) -> None:
        if not (job.spec.output_fileset and self.datalake is not None):
            return
        bus.publish(TOPIC_JOB_PROGRESS,
                    {"job_id": job.job_id, "stage": "uploading"})
        lake = self.datalake
        outdir = workdir / "out"
        files = [p for p in sorted(outdir.rglob("*")) if p.is_file()]
        specs = []
        if files:
            paths = [f"/{job.spec.output_fileset}/{p.relative_to(outdir)}"
                     for p in files]
            sid = lake.storage.begin_session(paths, creator=job.spec.user)
            for p, path in zip(files, paths):
                lake.storage.session_put(sid, path, p.read_bytes())
            for fv in lake.storage.commit_session(sid):
                specs.append(f"{fv.path}@{fv.version}")
                lake.metadata.register(f"{fv.path}@{fv.version}",
                                       kind="file", creator=job.spec.user)
        fsv = lake.filesets.create(job.spec.output_fileset, specs,
                                   creator=job.spec.user)
        lake.metadata.register(fsv.ref, kind="fileset",
                               creator=job.spec.user)
        src_ref = None
        if job.spec.input_fileset:
            src_ref = lake.filesets.resolve(job.spec.input_fileset).ref
        lake.provenance.add_job_edge(src=src_ref, dst=fsv.ref,
                                     job_id=job.job_id,
                                     creator=job.spec.user)
        job.outputs["fileset"] = fsv.ref

    def _finalize(self, job: Job, log_text: str, state: JobState,
                  error: Optional[str] = None) -> None:
        # the job may have been killed while the fn ran (thread workers):
        # keep the registry's terminal state, don't overwrite it
        if self.registry.get(job.job_id).state in TERMINAL_STATES:
            state = self.registry.get(job.job_id).state
        else:
            try:
                self.registry.set_state(job.job_id, state, error=error)
            except IllegalTransition:   # killed between check and set
                state = self.registry.get(job.job_id).state
        pricing = resolve_pricing(self.pricing, job)
        if pricing is not None and job.runtime is not None:
            job.cost = pricing.job_cost(job.spec.resources, job.runtime)
        if self.datalake is not None:
            meta = parse_log(log_text)      # intelligent log parser
            if meta:
                self.datalake.metadata.put(job.job_id, **meta)
            self.datalake.metadata.put(job.job_id, runtime=job.runtime,
                                       cost=job.cost, state=state.value)
            # log text goes to the lake, not the metadata store: metadata
            # values are bisect-indexed and rewritten wholesale on every
            # put, so logs there would grow completion cost quadratically
            self.datalake.storage.upload(f"/.logs/{job.job_id}.log",
                                         log_text.encode(),
                                         creator=job.spec.user)
        job.outputs["log"] = log_text
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job.job_id, "status": state.value})


class _ThreadLocalStdout(io.TextIOBase):
    """Dispatches writes to a per-thread buffer, falling back to the real
    stdout. ``contextlib.redirect_stdout`` swaps the process-global
    ``sys.stdout``, so concurrent agents would capture each other's logs;
    this proxy keeps each worker's job log isolated."""

    def __init__(self, fallback):
        self.fallback = fallback
        self._local = threading.local()

    def push(self, buf) -> None:
        self._local.buf = buf

    def pop(self) -> None:
        self._local.buf = None

    def _target(self):
        return getattr(self._local, "buf", None) or self.fallback

    def write(self, s) -> int:
        return self._target().write(s)

    def flush(self) -> None:
        self._target().flush()

    def writable(self) -> bool:
        return True


_stdout_proxy_lock = threading.Lock()


class ThreadPoolRunner(LocalRunner):
    """Concurrent LocalRunner: the same agent protocol (download -> run ->
    upload -> publish), executed on a bounded pool of worker threads so the
    scheduler can keep the cluster full. ``pending``/``step`` mirror the
    virtual runner so ``run_to_completion`` drains either transparently."""

    threaded = True

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 datalake=None, workroot: str = "/tmp/acai-jobs",
                 pricing=None, max_workers: int = 4):
        super().__init__(registry, bus, datalake=datalake,
                         workroot=workroot, pricing=pricing)
        self.max_workers = max_workers
        self._executor = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="acai-agent")
        self._cv = threading.Condition()
        self._inflight: set[str] = set()
        self._completions = 0

    @contextmanager
    def _capture(self, log_buf: io.StringIO):
        with _stdout_proxy_lock:
            if not isinstance(sys.stdout, _ThreadLocalStdout):
                sys.stdout = _ThreadLocalStdout(sys.stdout)
            proxy = sys.stdout
        proxy.push(log_buf)
        try:
            yield
        finally:
            proxy.pop()

    def launch(self, job: Job) -> None:
        with self._cv:
            self._inflight.add(job.job_id)
        self._executor.submit(self._run, job)

    def _run(self, job: Job) -> None:
        try:
            LocalRunner.launch(self, job)
        finally:
            with self._cv:
                self._inflight.discard(job.job_id)
                self._completions += 1
                self._cv.notify_all()

    def pending(self) -> int:
        with self._cv:
            return len(self._inflight)

    def step(self, timeout: float = 120.0) -> None:
        """Block until at least one in-flight job completes (or none are
        left) — the drain primitive ``run_to_completion`` loops on."""
        with self._cv:
            seen = self._completions
            self._cv.wait_for(
                lambda: self._completions > seen or not self._inflight,
                timeout)

    def shutdown(self) -> None:
        self._executor.shutdown(wait=True)


class VirtualRunner(Runner):
    """Virtual-clock agent for simulated fleets (profiling experiments).

    The duration is drawn ONCE at launch (stochastic oracles stay
    consistent between the scheduled end and the recorded runtime) and the
    expected completion time is exposed for EASY backfill. KILLED jobs
    publish their terminal ``container_status`` exactly like FINISHED ones,
    so monitors/dashboards observe kills on the virtual clock.
    """

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 oracle: Optional[Callable[[Job], float]] = None,
                 pricing=None):
        self.registry = registry
        self.bus = bus
        self.oracle = oracle
        self.pricing = pricing
        self.now = 0.0
        self._heap: list[tuple[float, int, str, float]] = []
        self._ends: dict[str, float] = {}
        # job_id -> {pool: duration}: pool-dependent oracles (heterogeneous
        # fleets where a TPU pool runs the same work faster) are re-drawn
        # when placement assigns a pool, while the pre-launch backfill
        # estimate and the launch still share one draw per (job, pool)
        self._dur_cache: dict[str, dict] = {}
        self._seq = 0

    _UNSET = object()

    def _draw_duration(self, job: Job, pool=_UNSET) -> float:
        """One oracle draw per (job, pool), shared between the backfill
        estimate and the actual launch — stochastic oracles stay
        consistent and the RNG stream does not depend on how often the
        scheduler peeks. ``pool`` lets the scheduler ask "how long on
        THIS pool" before placement assigns one; the oracle sees it as
        ``job.pool`` for the duration of the draw."""
        if job.spec.duration is not None:
            return job.spec.duration
        key = job.pool if pool is self._UNSET else pool
        per_pool = self._dur_cache.setdefault(job.job_id, {})
        if key not in per_pool:
            prev, job.pool = job.pool, key
            try:
                per_pool[key] = self.oracle(job)
            finally:
                job.pool = prev
        return per_pool[key]

    def launch(self, job: Job) -> None:
        self.registry.set_state(job.job_id, JobState.RUNNING)
        dur = self._draw_duration(job)
        self._seq += 1
        self._ends[job.job_id] = self.now + dur
        heapq.heappush(self._heap, (self.now + dur, self._seq, job.job_id,
                                    dur))

    def step(self) -> Optional[str]:
        """Advance to the next completion; returns the finished job id."""
        if not self._heap:
            return None
        t, _, job_id, dur = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        self._ends.pop(job_id, None)
        self._dur_cache.pop(job_id, None)
        job = self.registry.get(job_id)
        if job.state == JobState.KILLED:
            self.bus.publish(TOPIC_CONTAINER_STATUS,
                             {"job_id": job_id, "status": "KILLED"})
            return job_id
        job.runtime = dur
        pricing = resolve_pricing(self.pricing, job)
        if pricing is not None:
            job.cost = pricing.job_cost(job.spec.resources, job.runtime)
        self.registry.set_state(job_id, JobState.FINISHED)
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job_id, "status": "FINISHED"})
        return job_id

    def pending(self) -> int:
        return len(self._heap)

    # -- open-loop arrival processes ------------------------------------
    def next_completion(self) -> Optional[float]:
        """When the next running job will complete (None if none are)."""
        return self._heap[0][0] if self._heap else None

    def advance_to(self, t: float) -> None:
        """Advance the idle clock to ``t`` (a future arrival instant);
        never rewinds, never skips scheduled completions — drain those
        with ``step()`` first."""
        self.now = max(self.now, t)

    # -- capacity-scheduler hooks ---------------------------------------
    def expected_duration(self, job: Job,
                          pool: Optional[str] = None) -> Optional[float]:
        if job.spec.duration is None and self.oracle is None:
            return None
        if pool is None:
            return self._draw_duration(job)
        return self._draw_duration(job, pool)

    def expected_end(self, job_id: str) -> Optional[float]:
        return self._ends.get(job_id)
