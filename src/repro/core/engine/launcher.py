"""Job launcher + in-container agent (ACAI §4.2, §4.2.1).

The paper provisions a Kubernetes container whose pre-installed agent
downloads code + input file set, runs the user command, uploads the output
file set, and broadcasts progress on the event bus. The ``Runner`` interface
reproduces that protocol; two implementations ship:

  LocalRunner   — executes the job's python callable synchronously in a
                  scratch "container" directory (real measured runtime).
  VirtualRunner — completes jobs on a virtual clock using a runtime oracle
                  (duration = spec.duration or oracle(job)); this is what the
                  auto-provisioning experiments schedule thousands of
                  profiling jobs on, and what exercises quota/straggler
                  logic deterministically.
"""
from __future__ import annotations

import heapq
import io
import time
import traceback
from contextlib import redirect_stdout
from pathlib import Path
from typing import Callable, Optional

from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_JOB_PROGRESS)
from repro.core.engine.lifecycle import JobState
from repro.core.engine.logparse import parse_log
from repro.core.engine.registry import Job, JobRegistry


class Runner:
    def launch(self, job: Job) -> None:
        raise NotImplementedError


class LocalRunner(Runner):
    """Synchronous agent: download -> run -> upload -> publish."""

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 datalake=None, workroot: str = "/tmp/acai-jobs",
                 pricing=None):
        self.registry = registry
        self.bus = bus
        self.datalake = datalake            # AcaiProject-like facade or None
        self.workroot = Path(workroot)
        self.pricing = pricing

    def launch(self, job: Job) -> None:
        bus, reg = self.bus, self.registry
        reg.set_state(job.job_id, JobState.RUNNING)
        bus.publish(TOPIC_CONTAINER_STATUS,
                    {"job_id": job.job_id, "status": "provisioned"})
        workdir = self.workroot / job.job_id
        (workdir / "out").mkdir(parents=True, exist_ok=True)
        log_buf = io.StringIO()
        t0 = time.perf_counter()
        try:
            if job.spec.input_fileset and self.datalake is not None:
                bus.publish(TOPIC_JOB_PROGRESS,
                            {"job_id": job.job_id, "stage": "downloading"})
                self.datalake.filesets.materialize(job.spec.input_fileset,
                                                   workdir)
            bus.publish(TOPIC_JOB_PROGRESS,
                        {"job_id": job.job_id, "stage": "running"})
            with redirect_stdout(log_buf):
                result = job.spec.fn(workdir, job) if job.spec.fn else None
            if isinstance(result, dict):
                job.outputs.update(result)
            runtime = time.perf_counter() - t0
            job.runtime = job.spec.duration if job.spec.duration is not None \
                else runtime
            self._upload_outputs(job, workdir, bus)
            self._finalize(job, log_buf.getvalue(), JobState.FINISHED)
        except Exception:  # noqa: BLE001 — user code failure => FAILED
            job.runtime = time.perf_counter() - t0
            self._finalize(job, log_buf.getvalue()
                           + "\n" + traceback.format_exc(), JobState.FAILED,
                           error=traceback.format_exc())

    def _upload_outputs(self, job: Job, workdir: Path, bus: EventBus) -> None:
        if not (job.spec.output_fileset and self.datalake is not None):
            return
        bus.publish(TOPIC_JOB_PROGRESS,
                    {"job_id": job.job_id, "stage": "uploading"})
        lake = self.datalake
        outdir = workdir / "out"
        files = [p for p in sorted(outdir.rglob("*")) if p.is_file()]
        specs = []
        if files:
            paths = [f"/{job.spec.output_fileset}/{p.relative_to(outdir)}"
                     for p in files]
            sid = lake.storage.begin_session(paths, creator=job.spec.user)
            for p, path in zip(files, paths):
                lake.storage.session_put(sid, path, p.read_bytes())
            for fv in lake.storage.commit_session(sid):
                specs.append(f"{fv.path}@{fv.version}")
                lake.metadata.register(f"{fv.path}@{fv.version}",
                                       kind="file", creator=job.spec.user)
        fsv = lake.filesets.create(job.spec.output_fileset, specs,
                                   creator=job.spec.user)
        lake.metadata.register(fsv.ref, kind="fileset",
                               creator=job.spec.user)
        src_ref = None
        if job.spec.input_fileset:
            src_ref = lake.filesets.resolve(job.spec.input_fileset).ref
        lake.provenance.add_job_edge(src=src_ref, dst=fsv.ref,
                                     job_id=job.job_id,
                                     creator=job.spec.user)
        job.outputs["fileset"] = fsv.ref

    def _finalize(self, job: Job, log_text: str, state: JobState,
                  error: Optional[str] = None) -> None:
        if self.pricing is not None and job.runtime is not None:
            job.cost = self.pricing.job_cost(job.spec.resources, job.runtime)
        if self.datalake is not None:
            meta = parse_log(log_text)      # intelligent log parser
            if meta:
                self.datalake.metadata.put(job.job_id, **meta)
            self.datalake.metadata.put(job.job_id, runtime=job.runtime,
                                       cost=job.cost, state=state.value)
        job.outputs["log"] = log_text
        self.registry.set_state(job.job_id, state, error=error)
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job.job_id, "status": state.value})


class VirtualRunner(Runner):
    """Virtual-clock agent for simulated fleets (profiling experiments)."""

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 oracle: Optional[Callable[[Job], float]] = None,
                 pricing=None):
        self.registry = registry
        self.bus = bus
        self.oracle = oracle
        self.pricing = pricing
        self.now = 0.0
        self._heap: list[tuple[float, int, str]] = []
        self._seq = 0

    def launch(self, job: Job) -> None:
        self.registry.set_state(job.job_id, JobState.RUNNING)
        dur = job.spec.duration if job.spec.duration is not None \
            else self.oracle(job)
        self._seq += 1
        heapq.heappush(self._heap, (self.now + dur, self._seq, job.job_id))

    def step(self) -> Optional[str]:
        """Advance to the next completion; returns the finished job id."""
        if not self._heap:
            return None
        t, _, job_id = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        job = self.registry.get(job_id)
        if job.state == JobState.KILLED:
            return job_id
        job.runtime = (job.spec.duration if job.spec.duration is not None
                       else self.oracle(job))
        if self.pricing is not None:
            job.cost = self.pricing.job_cost(job.spec.resources, job.runtime)
        self.registry.set_state(job_id, JobState.FINISHED)
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job_id, "status": "FINISHED"})
        return job_id

    def pending(self) -> int:
        return len(self._heap)
