"""Job launcher + in-container agent (ACAI §4.2, §4.2.1).

The paper provisions a Kubernetes container whose pre-installed agent
downloads code + input file set, runs the user command, uploads the output
file set, and broadcasts progress on the event bus. The ``Runner`` interface
reproduces that protocol; two implementations ship:

  LocalRunner      — executes the job's python callable synchronously in a
                     scratch "container" directory (real measured runtime).
  ThreadPoolRunner — LocalRunner semantics on a bounded worker pool:
                     ``launch`` returns immediately and the agent protocol
                     (download/run/upload/publish) runs on a worker thread;
                     ``pending``/``step`` let the scheduler drain it like
                     the virtual runner.
  VirtualRunner    — completes jobs on a virtual clock using a runtime
                     oracle (duration = spec.duration or oracle(job)); this
                     is what the auto-provisioning experiments schedule
                     thousands of profiling jobs on, and what exercises
                     quota/capacity/straggler logic deterministically. It
                     exposes expected completion times so the scheduler's
                     EASY backfill can compute shadow start times.
"""
from __future__ import annotations

import heapq
import io
import sys
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from contextlib import contextmanager, redirect_stdout
from pathlib import Path
from typing import Callable, Optional

from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_JOB_PROGRESS)
from repro.core.engine.lifecycle import (IllegalTransition, JobPreempted,
                                         JobState, TERMINAL_STATES,
                                         TransientJobError)
from repro.core.engine.logparse import parse_log
from repro.core.engine.registry import Job, JobRegistry


# per-segment billing accumulates into job.cost from worker threads — a
# zombie (superseded) worker and the live incarnation's finalize can
# race the read-modify-write and silently drop a segment without this
_billing_lock = threading.Lock()


def _gang_width(job: Job) -> int:
    """The job's current pod count: the live (possibly shrunk) width when
    the scheduler tracks one, else the declared gang width, else 1."""
    width = getattr(job, "gang_pods", None)
    if width:
        return width
    return getattr(job.spec, "n_pods", 1)


def _bill_segment(pricing, job: Job, seconds: float) -> None:
    """Accumulate one segment's cost onto the job, thread-safely. A gang
    bills every pod: n_pods x the per-pod resource cost."""
    if pricing is None:
        return
    cost = pricing.job_cost(job.spec.resources, seconds) * _gang_width(job)
    with _billing_lock:
        job.cost = (job.cost or 0.0) + cost


def resolve_pricing(pricing, job: Job):
    """The pricing that bills ``job``: a plain ``Pricing`` applies to every
    job; a catalog (``{pool_name: Pricing}``, heterogeneous deployments)
    resolves through the pool placement launched the job on."""
    if isinstance(pricing, dict):
        if job.pool and job.pool in pricing:
            return pricing[job.pool]
        if "default" in pricing:
            return pricing["default"]
        return next(iter(pricing.values()), None) if pricing else None
    return pricing


class Runner:
    # True when jobs complete on worker threads (terminal events arrive
    # asynchronously); JobHandle.wait blocks on the bus instead of stepping
    threaded = False

    # optional write-ahead journal (durable control plane): runners that
    # bank checkpoint progress record it here so a crash-recovered
    # relaunch resumes from the checkpoint instead of step 0
    journal = None

    # runner-clock time, or None to fall back to wall time: the virtual
    # runner advances this; schedulers read it for queue-wait accounting,
    # fair-share decay and backfill math
    now: Optional[float] = None

    def launch(self, job: Job) -> None:
        raise NotImplementedError

    # -- optional hooks the capacity scheduler consults -----------------
    def expected_duration(self, job: Job,
                          pool: Optional[str] = None) -> Optional[float]:
        """Best-effort runtime estimate for backfill — on ``pool`` when
        the scheduler is sizing a specific pool's hole; None if unknown.
        Must be a pure read when ``job.spec.duration`` is declared (the
        scheduler may then consult it eagerly at enqueue); estimates that
        draw from an oracle are only requested from inside a dispatch
        scan, and are drawn once per (job, pool)."""
        return job.spec.duration

    def expected_end(self, job_id: str) -> Optional[float]:
        """Expected completion time of a running job; None if unknown.
        The scheduler reads this once, immediately after ``launch``, to
        feed the pool's incrementally-maintained shadow state — the
        estimate must therefore be available synchronously at launch (the
        virtual runner schedules the completion inside ``launch``) and
        stay fixed for the life of the job."""
        return

    # Runners that can deliver a checkpoint signal to a RUNNING job
    # implement ``preempt(job) -> bool`` (True = signal delivered, the
    # job will stop; False = the job is not running here). The scheduler
    # only enables its preemption policy when the launcher has it; the
    # base Runner and the synchronous LocalRunner deliberately do not
    # (a synchronous agent cannot be signalled mid-run).


class LocalRunner(Runner):
    """Synchronous agent: download -> run -> upload -> publish."""

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 datalake=None, workroot: str = "/tmp/acai-jobs",
                 pricing=None):
        self.registry = registry
        self.bus = bus
        self.datalake = datalake            # AcaiProject-like facade or None
        self.workroot = Path(workroot)
        self.pricing = pricing

    def _capture(self, log_buf: io.StringIO):
        """Capture the job fn's stdout into its log buffer."""
        return redirect_stdout(log_buf)

    def launch(self, job: Job) -> None:
        bus, reg = self.bus, self.registry
        epoch = job.epoch        # incarnation this launch belongs to
        try:
            reg.set_state(job.job_id, JobState.RUNNING)
        except IllegalTransition:
            # killed between dispatch and worker pickup: publish the
            # terminal status so waiters and dependents still observe it
            reg.persist_state(job.job_id)
            bus.publish(TOPIC_CONTAINER_STATUS,
                        {"job_id": job.job_id, "epoch": epoch,
                         "status": reg.get(job.job_id).state.value})
            return
        bus.publish(TOPIC_CONTAINER_STATUS,
                    {"job_id": job.job_id, "status": "provisioned"})
        workdir = self.workroot / job.job_id
        (workdir / "out").mkdir(parents=True, exist_ok=True)
        log_buf = io.StringIO()
        t0 = time.perf_counter()
        try:
            if job.spec.input_fileset and self.datalake is not None:
                bus.publish(TOPIC_JOB_PROGRESS,
                            {"job_id": job.job_id, "stage": "downloading"})
                self.datalake.filesets.materialize(job.spec.input_fileset,
                                                   workdir)
            bus.publish(TOPIC_JOB_PROGRESS,
                        {"job_id": job.job_id, "stage": "running"})
            with self._capture(log_buf):
                result = job.spec.fn(workdir, job) if job.spec.fn else None
            if job.epoch != epoch:
                # superseded while the fn ran (preempted, but it never
                # observed the signal): the live incarnation owns the
                # job's outputs and state — discard this zombie segment
                # without uploading or finalizing, but bill the compute
                # it really consumed (same as the cooperative path)
                _bill_segment(resolve_pricing(self.pricing, job), job,
                              time.perf_counter() - t0)
                bus.publish(TOPIC_JOB_PROGRESS,
                            {"job_id": job.job_id, "stage": "superseded",
                             "epoch": epoch})
                return
            # stage result/fileset mutations instead of applying them:
            # they commit in _finalize only after the epoch-guarded
            # terminal write succeeds, so a worker superseded *during*
            # the (slow) upload cannot clobber the live incarnation's
            # outputs — its staged delta is simply dropped
            delta = dict(result) if isinstance(result, dict) else {}
            runtime = time.perf_counter() - t0
            job.runtime = job.spec.duration if job.spec.duration is not None \
                else runtime
            ref = self._upload_outputs(job, workdir, bus)
            if ref is not None:
                delta["fileset"] = ref
            self._finalize(job, log_buf.getvalue(), JobState.FINISHED,
                           epoch=epoch, outputs=delta)
        except JobPreempted:
            # the checkpoint signal reached the fn. A *real* preemption
            # bumped the job's epoch (and settled/re-queued it — possibly
            # already relaunched as a new RUNNING incarnation): bill the
            # partial segment and hand back with no terminal publish. A
            # spurious JobPreempted (same epoch, still RUNNING: nobody
            # preempted this job) fails like any other exception, or the
            # job would hang non-terminal forever.
            if job.epoch == epoch and \
                    reg.get(job.job_id).state == JobState.RUNNING:
                job.runtime = time.perf_counter() - t0
                self._finalize(job, log_buf.getvalue()
                               + "\nJobPreempted without a scheduler "
                               "preemption", JobState.FAILED,
                               error="JobPreempted outside a preemption",
                               epoch=epoch)
                return
            _bill_segment(resolve_pricing(self.pricing, job), job,
                          time.perf_counter() - t0)
            bus.publish(TOPIC_JOB_PROGRESS,
                        {"job_id": job.job_id, "stage": "preempted",
                         "epoch": epoch})
        except TransientJobError:
            # the job classified its own failure as retryable (lost
            # connection, flaky dependency): FAILED, but stamped transient
            # so a retry_on="transient" policy has a real signal
            job.runtime = time.perf_counter() - t0
            self._finalize(job, log_buf.getvalue()
                           + "\n" + traceback.format_exc(), JobState.FAILED,
                           error=traceback.format_exc(), epoch=epoch,
                           transient=True)
        except Exception:  # noqa: BLE001 — user code failure => FAILED
            job.runtime = time.perf_counter() - t0
            self._finalize(job, log_buf.getvalue()
                           + "\n" + traceback.format_exc(), JobState.FAILED,
                           error=traceback.format_exc(), epoch=epoch)

    def _upload_outputs(self, job: Job, workdir: Path,
                        bus: EventBus) -> Optional[str]:
        """Upload the job's output fileset; returns its versioned ref
        (committed onto ``job.outputs`` by the caller only once the
        epoch-guarded terminal write lands)."""
        if not (job.spec.output_fileset and self.datalake is not None):
            return None
        bus.publish(TOPIC_JOB_PROGRESS,
                    {"job_id": job.job_id, "stage": "uploading"})
        lake = self.datalake
        outdir = workdir / "out"
        files = [p for p in sorted(outdir.rglob("*")) if p.is_file()]
        specs = []
        if files:
            paths = [f"/{job.spec.output_fileset}/{p.relative_to(outdir)}"
                     for p in files]
            sid = lake.storage.begin_session(paths, creator=job.spec.user)
            for p, path in zip(files, paths):
                lake.storage.session_put(sid, path, p.read_bytes())
            for fv in lake.storage.commit_session(sid):
                specs.append(f"{fv.path}@{fv.version}")
                lake.metadata.register(f"{fv.path}@{fv.version}",
                                       kind="file", creator=job.spec.user)
        fsv = lake.filesets.create(job.spec.output_fileset, specs,
                                   creator=job.spec.user)
        lake.metadata.register(fsv.ref, kind="fileset",
                               creator=job.spec.user)
        src_ref = None
        if job.spec.input_fileset:
            src_ref = lake.filesets.resolve(job.spec.input_fileset).ref
        lake.provenance.add_job_edge(src=src_ref, dst=fsv.ref,
                                     job_id=job.job_id,
                                     creator=job.spec.user)
        return fsv.ref

    def _finalize(self, job: Job, log_text: str, state: JobState,
                  error: Optional[str] = None,
                  epoch: Optional[int] = None,
                  outputs: Optional[dict] = None,
                  transient: bool = False) -> None:
        if epoch is not None and job.epoch != epoch:
            # a superseded incarnation must not write the registry, bill,
            # or publish: the job is live again (re-queued or relaunched)
            # and a FINISHED/FAILED here would terminal-ize it under the
            # new incarnation's feet
            return
        # the job may have been killed while the fn ran (thread workers):
        # keep the registry's terminal state, don't overwrite it
        if self.registry.get(job.job_id).state in TERMINAL_STATES:
            state = self.registry.get(job.job_id).state
        else:
            try:
                # epoch-guarded write: the check above is advisory (the
                # preemption can land between it and here), but the
                # registry re-checks the epoch under its own lock — a
                # zombie can never terminal-ize the live incarnation
                if self.registry.set_state(job.job_id, state, error=error,
                                           expect_epoch=epoch) is None:
                    return              # superseded mid-flight: hands off
            except IllegalTransition:   # killed between check and set
                state = self.registry.get(job.job_id).state
        if epoch is not None and job.epoch != epoch:
            return      # superseded on the IllegalTransition path: the
                        # job re-queued under us — no billing/publish
        if outputs:
            # commit the staged result/fileset delta only now, with the
            # terminal state claimed: a zombie never reaches this line
            job.outputs.update(outputs)
        if job.runtime is not None:
            # accumulate, not overwrite: preempted incarnations already
            # billed their partial segments
            _bill_segment(resolve_pricing(self.pricing, job), job,
                          job.runtime)
        if self.datalake is not None:
            meta = parse_log(log_text)      # intelligent log parser
            if meta:
                self.datalake.metadata.put(job.job_id, **meta)
            self.datalake.metadata.put(job.job_id, runtime=job.runtime,
                                       cost=job.cost, state=state.value)
            # log text goes to the lake, not the metadata store: metadata
            # values are bisect-indexed and rewritten wholesale on every
            # put, so logs there would grow completion cost quadratically
            self.datalake.storage.upload(f"/.logs/{job.job_id}.log",
                                         log_text.encode(),
                                         creator=job.spec.user)
        job.outputs["log"] = log_text
        msg = {"job_id": job.job_id, "status": state.value}
        if transient and state == JobState.FAILED:
            # transient-vs-fatal rides the terminal event: the scheduler's
            # retry policy reads it without re-parsing the traceback
            msg["transient"] = True
        if epoch is not None:
            # stamp the incarnation: the scheduler drops terminal events
            # whose epoch predates the job's current one (a worker that
            # finished after its job was preempted and relaunched must
            # not settle the new incarnation's reservation)
            msg["epoch"] = epoch
        self.bus.publish(TOPIC_CONTAINER_STATUS, msg)


class _ThreadLocalStdout(io.TextIOBase):
    """Dispatches writes to a per-thread buffer, falling back to the real
    stdout. ``contextlib.redirect_stdout`` swaps the process-global
    ``sys.stdout``, so concurrent agents would capture each other's logs;
    this proxy keeps each worker's job log isolated."""

    def __init__(self, fallback):
        self.fallback = fallback
        self._local = threading.local()

    def push(self, buf) -> None:
        self._local.buf = buf

    def pop(self) -> None:
        self._local.buf = None

    def _target(self):
        return getattr(self._local, "buf", None) or self.fallback

    def write(self, s) -> int:
        return self._target().write(s)

    def flush(self) -> None:
        self._target().flush()

    def writable(self) -> bool:
        return True


_stdout_proxy_lock = threading.Lock()


class ThreadPoolRunner(LocalRunner):
    """Concurrent LocalRunner: the same agent protocol (download -> run ->
    upload -> publish), executed on a bounded pool of worker threads so the
    scheduler can keep the cluster full. ``pending``/``step`` mirror the
    virtual runner so ``run_to_completion`` drains either transparently."""

    threaded = True

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 datalake=None, workroot: str = "/tmp/acai-jobs",
                 pricing=None, max_workers: int = 4):
        super().__init__(registry, bus, datalake=datalake,
                         workroot=workroot, pricing=pricing)
        self.max_workers = max_workers
        self._executor = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="acai-agent")
        self._cv = threading.Condition()
        # job_id -> number of in-flight runs: a preempted job's relaunch
        # can overlap its superseded worker, and a plain set would let
        # the zombie's exit erase the live incarnation from the books
        # (pending() -> 0 while the job still runs)
        self._inflight: dict[str, int] = {}
        self._completions = 0

    @contextmanager
    def _capture(self, log_buf: io.StringIO):
        with _stdout_proxy_lock:
            if not isinstance(sys.stdout, _ThreadLocalStdout):
                sys.stdout = _ThreadLocalStdout(sys.stdout)
            proxy = sys.stdout
        proxy.push(log_buf)
        try:
            yield
        finally:
            proxy.pop()

    def launch(self, job: Job) -> None:
        # fresh checkpoint signal per incarnation: a relaunched preempted
        # job must not see the previous incarnation's set flag
        job.preempt_flag = threading.Event()
        with self._cv:
            self._inflight[job.job_id] = \
                self._inflight.get(job.job_id, 0) + 1
        self._executor.submit(self._run, job)

    def preempt(self, job: Job) -> bool:
        """Cooperative checkpoint signal: sets the job's ``preempt_flag``.
        The job fn is expected to poll it (e.g. via
        ``train.fault.preemption_hook``) and raise ``JobPreempted`` at
        its next checkpoint; capacity is handed back immediately (the
        same early-release semantics as ``kill`` on a running worker)."""
        with self._cv:
            if job.job_id not in self._inflight:
                return False
        flag = job.preempt_flag
        if flag is None:
            return False
        flag.set()
        return True

    def _run(self, job: Job) -> None:
        try:
            LocalRunner.launch(self, job)
        finally:
            with self._cv:
                left = self._inflight.get(job.job_id, 0) - 1
                if left > 0:
                    self._inflight[job.job_id] = left
                else:
                    self._inflight.pop(job.job_id, None)
                self._completions += 1
                self._cv.notify_all()

    def pending(self) -> int:
        with self._cv:
            return len(self._inflight)

    def step(self, timeout: float = 120.0) -> None:
        """Block until at least one in-flight job completes (or none are
        left) — the drain primitive ``run_to_completion`` loops on."""
        with self._cv:
            seen = self._completions
            self._cv.wait_for(
                lambda: self._completions > seen or not self._inflight,
                timeout)

    def shutdown(self) -> None:
        self._executor.shutdown(wait=True)


class VirtualRunner(Runner):
    """Virtual-clock agent for simulated fleets (profiling experiments).

    The duration is drawn ONCE at launch (stochastic oracles stay
    consistent between the scheduled end and the recorded runtime) and the
    expected completion time is exposed for EASY backfill. KILLED jobs
    publish their terminal ``container_status`` exactly like FINISHED ones,
    so monitors/dashboards observe kills on the virtual clock.

    Checkpoint-aware preemption: ``preempt(job)`` cancels the scheduled
    completion and records the job's checkpointed progress — work done
    this segment rounds *down* to the last multiple of the checkpoint
    interval (``checkpoint_interval`` here, or a per-job
    ``spec.args["checkpoint_interval"]`` override), so the work lost to a
    preemption is bounded by one interval; with no interval configured
    the job restarts from zero (there was never a checkpoint to restore).
    Progress is kept as a *fraction* of the job, so a relaunch on a
    different (faster/slower) pool resumes from the same logical step.
    A preempted launch's stale heap entry is suppressed by sequence
    number — it can neither complete the new incarnation nor advance the
    clock.
    """

    def __init__(self, registry: JobRegistry, bus: EventBus, *,
                 oracle: Optional[Callable[[Job], float]] = None,
                 pricing=None, checkpoint_interval: Optional[float] = None):
        self.registry = registry
        self.bus = bus
        self.oracle = oracle
        self.pricing = pricing
        self.checkpoint_interval = checkpoint_interval
        self.now = 0.0
        self._heap: list[tuple[float, int, str, float]] = []
        self._ends: dict[str, float] = {}
        # job_id -> {pool: duration}: pool-dependent oracles (heterogeneous
        # fleets where a TPU pool runs the same work faster) are re-drawn
        # when placement assigns a pool, while the pre-launch backfill
        # estimate and the launch still share one draw per (job, pool)
        self._dur_cache: dict[str, dict] = {}
        self._seq = 0
        # preemption bookkeeping: the live heap-entry seq per running job
        # (mismatched pops are stale), this segment's launch time and full
        # duration on its pool, and checkpointed progress as a fraction
        self._live_seq: dict[str, int] = {}
        self._launch_t: dict[str, float] = {}
        self._full_dur: dict[str, float] = {}
        self._done_frac: dict[str, float] = {}
        # advance-warning checkpoints: job_id -> work-seconds explicitly
        # banked by request_checkpoint (a reclaim grace window), honored
        # by the next preempt even when off the interval grid
        self._ckpt_mark: dict[str, float] = {}
        self.preempt_stats = {"preemptions": 0, "lost_work_s": 0.0,
                              "max_lost_s": 0.0, "resumed_s": 0.0}

    _UNSET = object()

    def _draw_duration(self, job: Job, pool=_UNSET) -> float:
        """One oracle draw per (job, pool), shared between the backfill
        estimate and the actual launch — stochastic oracles stay
        consistent and the RNG stream does not depend on how often the
        scheduler peeks. ``pool`` lets the scheduler ask "how long on
        THIS pool" before placement assigns one; the oracle sees it as
        ``job.pool`` for the duration of the draw."""
        if job.spec.duration is not None:
            return job.spec.duration
        key = job.pool if pool is self._UNSET else pool
        per_pool = self._dur_cache.setdefault(job.job_id, {})
        if key not in per_pool:
            prev, job.pool = job.pool, key
            try:
                per_pool[key] = self.oracle(job)
            finally:
                job.pool = prev
        return per_pool[key]

    def launch(self, job: Job) -> None:
        self.registry.set_state(job.job_id, JobState.RUNNING)
        full = self._draw_duration(job)
        done = self._done_frac.get(job.job_id, 0.0)
        # resume from the last checkpoint: only the un-checkpointed
        # remainder of the job runs this segment
        dur = max(full * (1.0 - done), 0.0)
        if done:
            self.preempt_stats["resumed_s"] += full * done
        self._seq += 1
        self._live_seq[job.job_id] = self._seq
        self._launch_t[job.job_id] = self.now
        self._full_dur[job.job_id] = full
        self._ends[job.job_id] = self.now + dur
        heapq.heappush(self._heap, (self.now + dur, self._seq, job.job_id,
                                    dur))

    def step(self) -> Optional[str]:
        """Advance to the next completion; returns the finished job id."""
        while self._heap:
            t, seq, job_id, dur = heapq.heappop(self._heap)
            if self._live_seq.get(job_id) != seq:
                continue    # stale entry from a preempted incarnation:
                            # must not complete the job or move the clock
            self.now = max(self.now, t)
            self._ends.pop(job_id, None)
            self._dur_cache.pop(job_id, None)
            self._live_seq.pop(job_id, None)
            self._launch_t.pop(job_id, None)
            self._full_dur.pop(job_id, None)
            self._done_frac.pop(job_id, None)
            self._ckpt_mark.pop(job_id, None)
            job = self.registry.get(job_id)
            # the seq check already filtered stale incarnations, but the
            # published events still carry the epoch stamp: handlers
            # (and replayed histories) must be able to judge staleness
            # without knowing this runner's private seq bookkeeping
            if job.state == JobState.KILLED:
                self.bus.publish(TOPIC_CONTAINER_STATUS,
                                 {"job_id": job_id, "status": "KILLED",
                                  "epoch": job.epoch})
                return job_id
            job.runtime = dur
            pricing = resolve_pricing(self.pricing, job)
            if pricing is not None:
                # accumulate: preempted segments already billed theirs
                job.cost = (job.cost or 0.0) + \
                    pricing.job_cost(job.spec.resources, dur) * \
                    _gang_width(job)
            self.registry.set_state(job_id, JobState.FINISHED,
                                    expect_epoch=job.epoch)
            self.bus.publish(TOPIC_CONTAINER_STATUS,
                             {"job_id": job_id, "status": "FINISHED",
                              "epoch": job.epoch})
            return job_id
        return None

    def pending(self) -> int:
        return len(self._heap)

    # -- checkpoint-aware preemption ------------------------------------
    def preempt(self, job: Job) -> bool:
        """Deliver the checkpoint signal: cancel the scheduled completion
        and bank the segment's checkpointed progress. Returns False when
        the job is not running here (already completed or never launched).
        """
        jid = job.job_id
        if jid not in self._ends or jid not in self._live_seq:
            return False
        full = self._full_dur.get(jid, 0.0)
        elapsed = max(0.0, self.now - self._launch_t.get(jid, self.now))
        done0 = self._done_frac.get(jid, 0.0)
        interval = self.checkpoint_interval
        if isinstance(job.spec.args, dict):
            interval = job.spec.args.get("checkpoint_interval", interval)
        progressed = done0 * full + elapsed     # work done, in this
        if interval and interval > 0:           # pool's runtime seconds
            saved = min(int(progressed / interval + 1e-9) * interval,
                        progressed)
        else:
            saved = 0.0     # never checkpointed: restart from step 0
        # an advance-warning checkpoint (request_checkpoint) banked exact
        # progress off the interval grid: honor whichever saved more
        mark = self._ckpt_mark.pop(jid, None)
        if mark is not None:
            saved = max(saved, min(mark, progressed))
        lost = progressed - saved
        self.preempt_stats["preemptions"] += 1
        self.preempt_stats["lost_work_s"] += lost
        self.preempt_stats["max_lost_s"] = max(
            self.preempt_stats["max_lost_s"], lost)
        self._done_frac[jid] = saved / full if full > 0 else 0.0
        if self.journal is not None:
            self.journal.job_progress(jid, self._done_frac[jid])
        pricing = resolve_pricing(self.pricing, job)
        if pricing is not None:
            job.cost = (job.cost or 0.0) + \
                pricing.job_cost(job.spec.resources, elapsed) * \
                _gang_width(job)
        # drop the live entry; the heap row becomes a stale tombstone
        # (suppressed by seq in step/next_completion)
        self._ends.pop(jid, None)
        self._live_seq.pop(jid, None)
        self._launch_t.pop(jid, None)
        self._full_dur.pop(jid, None)
        return True

    def request_checkpoint(self, job: Job) -> bool:
        """Advance warning (a spot reclamation's grace window): bank the
        job's *exact* current progress as a checkpoint, so the forced
        preempt that lands moments later loses (near) zero work instead
        of up to one checkpoint interval. Returns False when the job is
        not running here."""
        jid = job.job_id
        if jid not in self._ends or jid not in self._live_seq:
            return False
        full = self._full_dur.get(jid, 0.0)
        elapsed = max(0.0, self.now - self._launch_t.get(jid, self.now))
        progressed = self._done_frac.get(jid, 0.0) * full + elapsed
        prev = self._ckpt_mark.get(jid)
        self._ckpt_mark[jid] = max(prev or 0.0, progressed)
        return True

    # -- fault tolerance ------------------------------------------------
    def fail_running(self, job: Job, error: str = "injected fault", *,
                     transient: bool = False) -> bool:
        """Fail a RUNNING job on the virtual clock — the fault injector's
        node-kill / flaky-job path, and the scheduler's per-incarnation
        timeout. Checkpointed progress banks exactly like a preemption
        (a retried incarnation resumes from the last checkpoint), the
        elapsed segment bills, and the terminal event carries the
        transient/fatal classification plus the incarnation's epoch.
        Returns False when the job is not running here."""
        jid = job.job_id
        if jid not in self._ends or jid not in self._live_seq:
            return False
        epoch = job.epoch
        full = self._full_dur.get(jid, 0.0)
        elapsed = max(0.0, self.now - self._launch_t.get(jid, self.now))
        done0 = self._done_frac.get(jid, 0.0)
        interval = self.checkpoint_interval
        if isinstance(job.spec.args, dict):
            interval = job.spec.args.get("checkpoint_interval", interval)
        progressed = done0 * full + elapsed
        if interval and interval > 0:
            saved = min(int(progressed / interval + 1e-9) * interval,
                        progressed)
        else:
            saved = 0.0     # never checkpointed: a retry restarts at 0
        mark = self._ckpt_mark.pop(jid, None)
        if mark is not None:
            saved = max(saved, min(mark, progressed))
        self._done_frac[jid] = saved / full if full > 0 else 0.0
        if self.journal is not None:
            self.journal.job_progress(jid, self._done_frac[jid])
        pricing = resolve_pricing(self.pricing, job)
        if pricing is not None:
            job.cost = (job.cost or 0.0) + \
                pricing.job_cost(job.spec.resources, elapsed) * \
                _gang_width(job)
        # drop the live entry; the heap row becomes a stale tombstone
        self._ends.pop(jid, None)
        self._live_seq.pop(jid, None)
        self._launch_t.pop(jid, None)
        self._full_dur.pop(jid, None)
        if self.registry.set_state(jid, JobState.FAILED, error=error,
                                   expect_epoch=epoch) is None:
            return False
        job.runtime = elapsed
        msg = {"job_id": jid, "status": "FAILED", "epoch": epoch,
               "error": error}
        if transient:
            msg["transient"] = True
        self.bus.publish(TOPIC_CONTAINER_STATUS, msg)
        return True

    def slow_running(self, job: Job, factor: float) -> Optional[float]:
        """Straggler injection: stretch the *remaining* work of a running
        job by ``factor`` (progress already made keeps its original
        pace). Reschedules the completion and returns the new expected
        end — None when the job is not running here."""
        jid = job.job_id
        if jid not in self._ends or jid not in self._live_seq \
                or factor <= 0:
            return None
        full = self._full_dur.get(jid, 0.0)
        elapsed = max(0.0, self.now - self._launch_t.get(jid, self.now))
        done = self._done_frac.get(jid, 0.0)
        if full > 0:
            done = min(1.0, done + elapsed / full)
        pricing = resolve_pricing(self.pricing, job)
        if pricing is not None and elapsed > 0:
            job.cost = (job.cost or 0.0) + \
                pricing.job_cost(job.spec.resources, elapsed) * \
                _gang_width(job)
        new_full = full * factor if full > 0 else 0.0
        rem = max(new_full * (1.0 - done), 0.0)
        self._done_frac[jid] = done
        self._launch_t[jid] = self.now
        self._full_dur[jid] = new_full
        if job.spec.duration is None:
            # a later preempt/retry of this segment resumes against the
            # slowed duration, not a fresh full-speed draw
            self._dur_cache.setdefault(jid, {})[job.pool] = new_full
        self._seq += 1
        self._live_seq[jid] = self._seq
        self._ends[jid] = self.now + rem
        heapq.heappush(self._heap, (self.now + rem, self._seq, jid, rem))
        return self._ends[jid]

    # -- elastic gang resize --------------------------------------------
    def resize_gang(self, job: Job, k: int) -> Optional[float]:
        """Shrink a running gang to ``k`` pods in place (no requeue): the
        segment so far bills at the old width, and the *remaining* work
        re-paces at ``old/k`` x slower — a work-conserving data-parallel
        model. Reschedules the completion and returns the new expected
        end (None when the job is not running here)."""
        jid = job.job_id
        if jid not in self._ends or jid not in self._live_seq:
            return None
        old = _gang_width(job)
        if k < 1 or k == old:
            return self._ends.get(jid)
        full = self._full_dur.get(jid, 0.0)
        elapsed = max(0.0, self.now - self._launch_t.get(jid, self.now))
        done = self._done_frac.get(jid, 0.0)
        if full > 0:
            done = min(1.0, done + elapsed / full)
        pricing = resolve_pricing(self.pricing, job)
        if pricing is not None and elapsed > 0:
            job.cost = (job.cost or 0.0) + \
                pricing.job_cost(job.spec.resources, elapsed) * old
        # remaining logical work runs on k of old pods: the full-job
        # duration at the new width stretches by old/k
        new_full = full * (old / k) if full > 0 else 0.0
        rem = max(new_full * (1.0 - done), 0.0)
        job.gang_pods = k
        self._done_frac[jid] = done
        self._launch_t[jid] = self.now
        self._full_dur[jid] = new_full
        if job.spec.duration is None:
            # future relaunches (a later preemption) must resume against
            # the re-paced duration, not a fresh original-width draw
            self._dur_cache.setdefault(jid, {})[job.pool] = new_full
        self._seq += 1
        self._live_seq[jid] = self._seq
        self._ends[jid] = self.now + rem
        heapq.heappush(self._heap, (self.now + rem, self._seq, jid, rem))
        return self._ends[jid]

    # -- durable recovery hooks -----------------------------------------
    def restore_progress(self, job_id: str, done_frac: float) -> None:
        """Seed a recovered job's checkpointed fraction before its
        relaunch (recovery's counterpart of a live preemption's bank)."""
        if done_frac > 0.0:
            self._done_frac[job_id] = min(1.0, float(done_frac))

    def checkpoint_progress(self) -> dict[str, float]:
        """Banked progress fractions by job id — snapshotted so progress
        survives even after journal compaction discards the records."""
        return dict(self._done_frac)

    def forget(self, job_id: str) -> None:
        """Drop restore/duration state for a job that went terminal with
        no live run here (killed while preempted-queued): nothing will
        ever pop its entries off the completion heap, so a long-lived
        engine would otherwise leak its checkpoint progress and draws.
        A job with a live heap entry keeps everything — its own pop does
        this cleanup (and must still publish the KILLED event)."""
        if job_id in self._live_seq:
            return
        self._done_frac.pop(job_id, None)
        self._dur_cache.pop(job_id, None)
        self._launch_t.pop(job_id, None)
        self._full_dur.pop(job_id, None)
        self._ends.pop(job_id, None)
        self._ckpt_mark.pop(job_id, None)

    # -- open-loop arrival processes ------------------------------------
    def next_completion(self) -> Optional[float]:
        """When the next running job will complete (None if none are)."""
        heap = self._heap
        while heap and self._live_seq.get(heap[0][2]) != heap[0][1]:
            heapq.heappop(heap)     # prune stale preempted entries
        return heap[0][0] if heap else None

    def advance_to(self, t: float) -> None:
        """Advance the idle clock to ``t`` (a future arrival instant);
        never rewinds, never skips scheduled completions — drain those
        with ``step()`` first."""
        self.now = max(self.now, t)

    # -- capacity-scheduler hooks ---------------------------------------
    def expected_duration(self, job: Job,
                          pool: Optional[str] = None) -> Optional[float]:
        if job.spec.duration is None and self.oracle is None:
            return None
        full = self._draw_duration(job) if pool is None \
            else self._draw_duration(job, pool)
        # a preempted job resumes from its checkpoint: size backfill (and
        # relaunch) at the remaining work, not the full duration
        done = self._done_frac.get(job.job_id, 0.0)
        return full * (1.0 - done) if done else full

    def expected_end(self, job_id: str) -> Optional[float]:
        return self._ends.get(job_id)
