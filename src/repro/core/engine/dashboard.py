"""Dashboard (ACAI §3.4, Figs. 4–5) — terminal/markdown rendition.

The paper's web dashboard has two pages: a job-history page (status,
metadata, runtime logs; filtering, sorting, pagination) and a provenance
page (whole graph + interactive fore/back tracing). Both renderers work
off the same registry/metadata/provenance state the web UI would."""
from __future__ import annotations

from typing import Optional

from repro.core.engine.registry import JobRegistry


def job_history(registry: JobRegistry, metadata=None, *,
                status: Optional[str] = None, user: Optional[str] = None,
                sort_by: str = "job_id", descending: bool = False,
                page: int = 0, page_size: int = 20) -> str:
    """The job-history page: filter -> sort -> paginate -> render."""
    jobs = registry.all_jobs()
    if status:
        jobs = [j for j in jobs if j.state.value == status]
    if user:
        jobs = [j for j in jobs if j.spec.user == user]

    def key(j):
        if sort_by == "runtime":
            return j.runtime or 0.0
        if sort_by == "cost":
            return j.cost or 0.0
        if sort_by == "submitted":
            return j.submitted_at
        return j.job_id
    jobs = sorted(jobs, key=key, reverse=descending)
    total = len(jobs)
    jobs = jobs[page * page_size:(page + 1) * page_size]

    lines = [f"| job | name | user | state | runtime_s | cost | tags |",
             f"|---|---|---|---|---|---|---|"]
    for j in jobs:
        md = metadata.get(j.job_id) if metadata else {}
        tags = ",".join(f"{k}={v}" for k, v in sorted(md.items())
                        if v is not None and k not in
                        ("create_time", "kind", "state", "runtime",
                         "cost", "creator", "project", "model")) or "-"
        rt = f"{j.runtime:.2f}" if j.runtime is not None else "-"
        cost = f"${j.cost:.5f}" if j.cost is not None else "-"
        lines.append(f"| {j.job_id} | {j.spec.name} | {j.spec.user} "
                     f"| {j.state.value} | {rt} | {cost} | {tags} |")
    lines.append(f"\npage {page + 1} of "
                 f"{max(1, (total + page_size - 1) // page_size)} "
                 f"({total} jobs)")
    return "\n".join(lines)


def provenance_page(provenance, root: Optional[str] = None,
                    direction: str = "backward", max_depth: int = 10) -> str:
    """The provenance page: whole graph, or interactive trace from a node."""
    if root is None:
        g = provenance.whole_graph()
        lines = [f"{len(g['nodes'])} filesets, {len(g['edges'])} actions"]
        for u, v, d in g["edges"]:
            tag = d.get("job_id", d.get("action", "?"))
            lines.append(f"  {u} --[{tag}]--> {v}")
        return "\n".join(lines)

    step = provenance.backward if direction == "backward" \
        else provenance.forward
    arrow = "<--" if direction == "backward" else "-->"
    lines = [root]
    frontier = [(root, 0)]
    seen = {root}
    while frontier:
        node, depth = frontier.pop()
        if depth >= max_depth:
            continue
        for other, d in step(node):
            tag = d.get("job_id", d.get("action", "?"))
            lines.append("  " * (depth + 1) + f"{arrow}[{tag}] {other}")
            if other not in seen:
                seen.add(other)
                frontier.append((other, depth + 1))
    return "\n".join(lines)
