"""Dashboard (ACAI §3.4, Figs. 4–5) — terminal/markdown rendition.

The paper's web dashboard has two pages: a job-history page (status,
metadata, runtime logs; filtering, sorting, pagination) and a provenance
page (whole graph + interactive fore/back tracing). Both renderers work
off the same registry/metadata/provenance state the web UI would."""
from __future__ import annotations

from typing import Optional

from repro.core.engine.registry import JobRegistry


def job_history(registry: JobRegistry, metadata=None, *,
                status: Optional[str] = None, user: Optional[str] = None,
                sort_by: str = "job_id", descending: bool = False,
                page: int = 0, page_size: int = 20) -> str:
    """The job-history page: filter -> sort -> paginate -> render."""
    jobs = registry.all_jobs()
    if status:
        jobs = [j for j in jobs if j.state.value == status]
    if user:
        jobs = [j for j in jobs if j.spec.user == user]

    def key(j):
        if sort_by == "runtime":
            return j.runtime or 0.0
        if sort_by == "cost":
            return j.cost or 0.0
        if sort_by == "submitted":
            return j.submitted_at
        return j.job_id
    jobs = sorted(jobs, key=key, reverse=descending)
    total = len(jobs)
    jobs = jobs[page * page_size:(page + 1) * page_size]

    lines = [f"| job | name | user | state | runtime_s | cost | tags |",
             f"|---|---|---|---|---|---|---|"]
    for j in jobs:
        md = metadata.get(j.job_id) if metadata else {}
        tags = ",".join(f"{k}={v}" for k, v in sorted(md.items())
                        if v is not None and k not in
                        ("create_time", "kind", "state", "runtime",
                         "cost", "creator", "project", "model")) or "-"
        rt = f"{j.runtime:.2f}" if j.runtime is not None else "-"
        cost = f"${j.cost:.5f}" if j.cost is not None else "-"
        lines.append(f"| {j.job_id} | {j.spec.name} | {j.spec.user} "
                     f"| {j.state.value} | {rt} | {cost} | {tags} |")
    lines.append(f"\npage {page + 1} of "
                 f"{max(1, (total + page_size - 1) // page_size)} "
                 f"({total} jobs)")
    return "\n".join(lines)


def _pct(u: float) -> str:
    """Render a utilization fraction; an over-committed dimension (a pool
    shrunk below its live reservations reports ``inf``) is flagged
    instead of fed to arithmetic that would print garbage."""
    if u == float("inf"):
        return "OVERCOMMIT"
    return f"{u * 100:.1f}%"


def scheduler_page(scheduler, monitor=None) -> str:
    """The cluster page: per-pool capacity + utilization + placement
    counts (spot pools tagged), per-queue pressure and queue-wait
    statistics from the capacity scheduler."""
    lines = []
    with scheduler._lock:     # dispatch may be running on a worker thread
        pools = getattr(scheduler, "pools", {})
        if pools:
            placed = scheduler.stats.get("placed_by_pool", {})
            lines.append("| pool | resource | capacity | used "
                         "| utilization | placed |")
            lines.append("|---|---|---|---|---|---|")
            for pname in sorted(pools):
                cl = pools[pname]
                util = cl.utilization()
                tag = f"{pname} (spot)" if getattr(cl, "spot", False) \
                    else pname
                for dim in cl.capacity:
                    lines.append(f"| {tag} | {dim} "
                                 f"| {cl.capacity[dim]:g} "
                                 f"| {cl.used[dim]:g} "
                                 f"| {_pct(util[dim])} "
                                 f"| {placed.get(pname, 0)} |")
            health_lines = []
            for pname in sorted(pools):
                health_fn = getattr(pools[pname], "node_health", None)
                h = health_fn() if callable(health_fn) else {}
                if not h.get("nodes"):
                    continue    # no node accounting on this pool
                note = ""
                if h["failed"]:
                    note += f" failed={h['failed']}"
                if h["drained"]:
                    note += f" drained={h['drained']}"
                health_lines.append(
                    f"  {pname}: {h['up']}/{h['nodes']} nodes up{note}")
            if health_lines:
                lines.append("node health:")
                lines.extend(health_lines)
        else:
            lines.append("(no cluster attached — capacity-unconstrained)")

        placement = getattr(scheduler, "placement", None)
        pstats = getattr(placement, "stats", None)
        if pstats and any(pstats.values()):
            # where scored runtimes came from — a high "default" count
            # means placement is ranking on silent 1.0s guesses
            lines.append("prediction sources: " + " ".join(
                f"{k}={pstats[k]}" for k in sorted(pstats)))

        lines.append("")
        lines.append("| queue (project, user) | depth | active | waits | "
                     "mean_wait_s |")
        lines.append("|---|---|---|---|---|")
        keys = sorted(set(scheduler._queues) | set(scheduler._active)
                      | set(scheduler.stats["wait_by_key"]))
        for key in keys:
            count, total = scheduler.stats["wait_by_key"].get(key, (0, 0.0))
            mean_w = total / count if count else 0.0
            # live depth, not raw deque length: launched/killed jobs leave
            # tombstones in the deque until they are compacted away
            depth = scheduler._qlen.get(key, 0)
            active = len(scheduler._active.get(key, ()))
            lines.append(f"| {key} | {depth} | {active} | {count} "
                         f"| {mean_w:.2f} |")
        s = scheduler.stats
        lines.append(f"\nlaunched={s['launched']} "
                     f"completed={s['completed']} "
                     f"backfilled={s['backfilled']} "
                     f"mean_queue_wait={scheduler.mean_queue_wait():.2f}s")
        if s.get("preempted") or s.get("reclaimed") or s.get("drained"):
            lines.append(f"preempted={s['preempted']} "
                         f"spot_reclaimed={s['reclaimed']} "
                         f"shrink_drained={s['drained']}")
        if (s.get("retried") or s.get("quarantined") or s.get("timeouts")
                or s.get("deadline_kills") or s.get("node_failures")):
            lines.append(f"retried={s.get('retried', 0)} "
                         f"quarantined={s.get('quarantined', 0)} "
                         f"timeouts={s.get('timeouts', 0)} "
                         f"deadline_kills={s.get('deadline_kills', 0)} "
                         f"node_failures={s.get('node_failures', 0)} "
                         f"retry_wasted_s={s.get('retry_wasted_s', 0.0):.1f}")
        drift = sum(cl.stats.get("release_underflow", 0)
                    for cl in pools.values() if hasattr(cl, "stats"))
        if drift:
            lines.append(f"release_underflow={drift}  "
                         "(capacity accounting drift — investigate)")
        if s.get("snapshots_skipped"):
            lines.append(f"snapshots={s['snapshots']} "
                         f"coalesced={s['snapshots_skipped']} "
                         f"(interval={scheduler.snapshot_interval:g}s)")
    if monitor is not None:
        # one locked snapshot: peak and mean must come from the same
        # ingest point, not interleave with a concurrent sample
        has_samples, peak, mean = monitor.utilization_summary()
        if has_samples:
            for dim in peak:
                lines.append(f"utilization.{dim}: "
                             f"mean={_pct(mean.get(dim, 0.0))} "
                             f"peak={_pct(peak[dim])}")
    return "\n".join(lines)


def provenance_page(provenance, root: Optional[str] = None,
                    direction: str = "backward", max_depth: int = 10) -> str:
    """The provenance page: whole graph, or interactive trace from a node."""
    if root is None:
        g = provenance.whole_graph()
        lines = [f"{len(g['nodes'])} filesets, {len(g['edges'])} actions"]
        for u, v, d in g["edges"]:
            tag = d.get("job_id", d.get("action", "?"))
            lines.append(f"  {u} --[{tag}]--> {v}")
        return "\n".join(lines)

    step = provenance.backward if direction == "backward" \
        else provenance.forward
    arrow = "<--" if direction == "backward" else "-->"
    lines = [root]
    frontier = [(root, 0)]
    seen = {root}
    while frontier:
        node, depth = frontier.pop()
        if depth >= max_depth:
            continue
        for other, d in step(node):
            tag = d.get("job_id", d.get("action", "?"))
            lines.append("  " * (depth + 1) + f"{arrow}[{tag}] {other}")
            if other not in seen:
                seen.add(other)
                frontier.append((other, depth + 1))
    return "\n".join(lines)
