"""Placement layer: heterogeneous cluster pools (ACAI §4.2 scaled out).

The paper's auto-provisioner earns its speedup/cost-saving by choosing
*where* a job runs; this module is the engine-side half of that choice.
A deployment holds one ``Cluster`` pool per accelerator family (CPU node
shapes vs TPU pod slices, each with its own pricing catalog), and
``Placement`` scores each job's eligible pools on the profiler's
cost/speed frontier plus dataflow locality:

  eligibility  — the pool can ever fit the job's resource shape for that
                 pool (``JobSpec.pool_resources`` declares per-family
                 alternatives; a plain ``resources`` dict is tried on
                 every pool, where unknown dimensions reject).
  score        — expected runtime (profiler prediction when available,
                 else the declared duration) x the pool's price =
                 predicted cost; ``objective`` selects cost, runtime, or
                 their product ("balanced" — the cost/speed frontier
                 scalarized).
  locality     — pools already holding a parent stage's output filesets
                 (the pools the parents ran on) get their score
                 discounted, co-placing pipeline stages with their
                 inputs instead of paying a cross-pool transfer.
  spot risk    — a spot pool (``Cluster.spot``) has its score inflated by
                 the reclamations the job is expected to suffer there
                 (``reclaim_rate`` x predicted runtime x
                 ``spot_risk_weight``): short jobs harvest the spot
                 discount, long jobs stay on-demand unless the discount
                 covers the expected lost work + requeues.

The scheduler calls ``eligible`` once per job at submit (failing fast
when no pool can ever satisfy it) and ``rank`` when the job becomes
dispatchable — after dependency release, so every parent's pool is
known. Ties break deterministically on (score, runtime, pool name).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.core.engine.cluster import Cluster


@dataclasses.dataclass
class PoolOption:
    """One pool a job may run on, with the shape/charge/score it would get.

    For a gang, ``resources`` is the shape of ONE pod and ``charge`` the
    *aggregate* (``pods`` x per-pod charge) — the unit the scheduler's
    admission, certificates and shadow math account in, so a gang is
    admitted whole or not at all.
    """
    pool: str
    resources: dict[str, float]
    charge: dict[str, float]
    runtime: Optional[float] = None     # predicted seconds (None = unknown)
    cost: Optional[float] = None        # predicted $ for the whole run
    score: float = 0.0
    local: bool = False                 # a parent stage ran on this pool
    pods: int = 1                       # gang width (1 = ordinary job)


# predictor(spec, pool_name, resources) -> expected runtime seconds | None
Predictor = Callable[[Any, str, dict[str, float]], Optional[float]]


@dataclasses.dataclass
class TransferCostModel:
    """Explicit cross-pool data-movement pricing (replaces the flat
    locality discount when attached to a ``Placement``).

    ``cost_per_gb`` prices moving a parent stage's fileset bytes between
    accelerator families (``pair_cost_per_gb[(src, dst)]`` overrides per
    ordered pair); the cheapest parent pool is charged when a child lands
    off-pool. ``interconnect_weight`` scales the intra-gang penalty for a
    pool that cannot host all of a close-topology gang's pods on one
    interconnect island (``Cluster.close_gang_pods``): the score is
    inflated proportionally to the fraction of pods forced off-island,
    modelling the all-reduce slowdown of a spread data-parallel mesh.
    """
    cost_per_gb: float = 0.0
    pair_cost_per_gb: dict[tuple[str, str], float] = \
        dataclasses.field(default_factory=dict)
    interconnect_weight: float = 1.0

    def transfer_cost(self, src: str, dst: str, nbytes: float) -> float:
        if src == dst or nbytes <= 0:
            return 0.0
        rate = self.pair_cost_per_gb.get((src, dst), self.cost_per_gb)
        return rate * nbytes / 1e9

    def cheapest_transfer(self, parent_pools, dst: str,
                          nbytes: float) -> float:
        """A child with several parents streams from the cheapest one."""
        costs = [self.transfer_cost(src, dst, nbytes)
                 for src in parent_pools]
        return min(costs) if costs else 0.0

    def spread_fraction(self, spec, cluster) -> float:
        """Fraction of a close-topology gang's pods this pool would host
        off-island (0.0 when the gang fits close or topology is 'any')."""
        gang = getattr(spec, "gang", None)
        if gang is None or gang.topology != "close":
            return 0.0
        close = getattr(cluster, "close_gang_pods", None)
        if close is None or close >= gang.n_pods:
            return 0.0
        return (gang.n_pods - close) / gang.n_pods


class Placement:
    """Scores each job's eligible pools; lower score wins.

    ``pools`` maps pool name -> Cluster; ``pricing`` (optional) maps pool
    name -> Pricing so scores are dollars instead of normalized
    resource-time. ``predictor`` supplies expected runtimes — typically
    the profiler, attached via :meth:`use_profiler`.
    """

    def __init__(self, pools: dict[str, Cluster], *,
                 pricing: Optional[dict[str, Any]] = None,
                 predictor: Optional[Predictor] = None,
                 objective: str = "cost",
                 locality_discount: float = 0.75,
                 spot_risk_weight: float = 1.0,
                 transfer_costs: Optional[TransferCostModel] = None):
        if objective not in ("cost", "runtime", "balanced"):
            raise ValueError(f"unknown objective {objective!r}")
        self.pools = dict(pools)
        self.pricing = dict(pricing or {})
        self.predictor = predictor
        self.objective = objective
        self.locality_discount = locality_discount
        # explicit data-movement pricing: when set, it REPLACES the flat
        # locality discount (off-pool children pay the modelled transfer,
        # close-topology gangs pay the interconnect spread penalty); when
        # None the legacy discount path runs, bit-identically
        self.transfer_costs = transfer_costs
        # spot risk pricing: a spot pool's score is inflated by the
        # reclamations the job is expected to suffer there — long jobs
        # lose more to a reclaim (up to a checkpoint interval each, plus
        # the requeue), so the discount has to *earn* the risk
        self.spot_risk_weight = spot_risk_weight
        # where each scored runtime came from, per _score_one call:
        # "predictor" (fitted model / custom predictor), "prior"
        # (roofline cold-start estimate), "declared" (spec.duration),
        # "default" (the silent 1.0s fallback — the number this counter
        # exists to make visible). Dashboard renders these.
        self.stats: dict[str, int] = {"predictor": 0, "prior": 0,
                                      "declared": 0, "default": 0}
        self._pred_source = "predictor"

    # -- eligibility -----------------------------------------------------
    def resources_for(self, spec, pool: str) -> Optional[dict[str, float]]:
        """The resource shape the job would get on ``pool``: its declared
        per-pool alternative, or the generic ``resources`` dict when no
        per-pool menu was declared. None = the job did not declare a shape
        for this pool (an explicit menu is authoritative)."""
        if spec.pool_resources:
            return spec.pool_resources.get(pool)
        return spec.resources

    def eligible(self, spec) -> dict[str, PoolOption]:
        """Pools that could ever run this job (empty => fail fast).

        A gang's option carries the per-pod shape but the *aggregate*
        charge (n_pods x per-pod) — downstream admission/certificate/
        shadow accounting then treats the gang as one unit for free. On a
        node-shaped pool a pod that exceeds the node shape can never pack,
        so the pool is ineligible even when the aggregate would fit."""
        gang = getattr(spec, "gang", None)
        out: dict[str, PoolOption] = {}
        for name, cl in self.pools.items():
            if spec.pool and spec.pool != name:
                continue                      # pinned to another pool
            res = self.resources_for(spec, name)
            if res is None:
                continue
            if gang is not None and gang.per_pod_resources is not None:
                res = gang.per_pod_resources
            charge = cl.charge(res)
            if gang is not None:
                agg = {n: amt * gang.n_pods for n, amt in charge.items()}
                if not cl.ever_fits_charge(agg):
                    continue
                shape = getattr(cl, "node_shape", None)
                if shape is not None and any(
                        amt > shape.get(n, 0.0) + 1e-9
                        for n, amt in charge.items() if amt > 0):
                    continue                  # one pod overflows a node
                out[name] = PoolOption(name, dict(res or {}), agg,
                                       pods=gang.n_pods)
            elif cl.ever_fits_charge(charge):
                out[name] = PoolOption(name, dict(res or {}), charge)
        return out

    # -- scoring ---------------------------------------------------------
    def use_profiler(self, profiler) -> None:
        """Feed the auto-provisioner's profiler into scoring.

        ``spec.template`` names the profiled command template; the
        profiler's ``predict_for_pool`` resolves the per-pool model
        (``"<template>@<pool>"``) with fallback to the family-agnostic
        one. The prediction config is the job's numeric args plus the
        pool's resource shape, matching what the profiler's grids
        explore. Missing models / failed predictions degrade to None
        (placement falls back to declared durations) rather than making
        the job ineligible."""
        def predict(spec, pool: str,
                    resources: dict[str, float]) -> Optional[float]:
            if not spec.template:
                return None
            cfg = {k: v for k, v in (spec.args or {}).items()
                   if isinstance(v, (int, float))}
            cfg.update(resources or {})
            try:
                val = profiler.predict_for_pool(spec.template, pool, cfg)
            except Exception:              # noqa: BLE001 — stay eligible
                return None
            if getattr(profiler, "last_source", None) == "prior":
                self._pred_source = "prior"
            return val
        self.predictor = predict

    def _score_one(self, spec, opt: PoolOption,
                   parent_pools: set[str]) -> None:
        runtime = None
        if self.predictor is not None:
            self._pred_source = "predictor"
            runtime = self.predictor(spec, opt.pool, opt.resources)
        if runtime is None:
            source = "declared" if spec.duration is not None else "default"
            runtime = spec.duration if spec.duration is not None else 1.0
        else:
            source = self._pred_source
        self.stats[source] = self.stats.get(source, 0) + 1
        pricing = self.pricing.get(opt.pool)
        if pricing is not None:
            cost = pricing.job_cost(opt.resources, runtime) * opt.pods
        else:
            # no price catalog: dollars degrade to normalized resource-time
            cl = self.pools[opt.pool]
            cost = runtime * sum(
                amt / cl.capacity[n] for n, amt in opt.charge.items()
                if cl.capacity.get(n, 0.0) > 0)
        opt.runtime, opt.cost = runtime, cost
        score = {"cost": cost, "runtime": runtime,
                 "balanced": cost * runtime}[self.objective]
        opt.local = opt.pool in parent_pools
        cl = self.pools[opt.pool]
        if self.transfer_costs is not None:
            # explicit data movement: an off-pool child pays to move its
            # input bytes from the cheapest parent pool; a close-topology
            # gang pays for every pod the pool forces off-island
            if parent_pools and not opt.local:
                score += self.transfer_costs.cheapest_transfer(
                    parent_pools, opt.pool,
                    getattr(spec, "input_bytes", 0.0))
            frac = self.transfer_costs.spread_fraction(spec, cl)
            if frac > 0.0:
                score *= 1.0 + self.transfer_costs.interconnect_weight * frac
        elif opt.local and len(self.pools) > 1:
            score *= self.locality_discount
        if getattr(cl, "spot", False):
            # expected reclamations over the run × risk weight: a spot
            # pool must be cheap enough to beat on-demand *after* paying
            # for the work a reclaim loses and the requeue it forces
            score *= 1.0 + self.spot_risk_weight * \
                getattr(cl, "reclaim_rate", 0.0) * runtime
        opt.score = score

    def rank(self, spec, options: dict[str, PoolOption],
             parent_pools: set[str] = frozenset()) -> list[str]:
        """Pool names ordered best-first (lowest score)."""
        if len(options) == 1:
            # a single eligible pool ranks as itself: skip the predictor
            # and pricing walk entirely (the homogeneous-deployment hot
            # path — every submit ranks, so this is per-job overhead)
            return list(options)
        for opt in options.values():
            self._score_one(spec, opt, parent_pools)
        return sorted(options, key=lambda p: (options[p].score,
                                              options[p].runtime, p))

    # -- diagnostics -----------------------------------------------------
    def explain_infeasible(self, spec) -> str:
        """Why no pool can run this job — surfaced in the submit error."""
        parts = []
        for name, cl in self.pools.items():
            if spec.pool and spec.pool != name:
                parts.append(f"{name}: pinned to {spec.pool!r}")
                continue
            res = self.resources_for(spec, name)
            if res is None:
                parts.append(f"{name}: no resource shape declared")
                continue
            charge = cl.charge(res)
            bad = [f"{n}={charge[n]:g}>" +
                   (f"{cl.capacity[n]:g}" if n in cl.capacity
                    else "absent")
                   for n in charge
                   if charge[n] > cl.capacity.get(n, 0.0) + 1e-9]
            parts.append(f"{name}: {', '.join(bad) or 'ok'}")
        if spec.pool and spec.pool not in self.pools:
            parts.append(f"(pool {spec.pool!r} does not exist)")
        return "; ".join(parts)
