"""Intelligent log parser (ACAI §3.2.3): user programs print specially
formatted lines and the platform auto-attaches them as metadata.

Recognized formats (tolerant):
    [[acai:key=value]]
    [[acai:key=value,key2=value2]]
Values are parsed as float/int when possible.
"""
from __future__ import annotations

import re
from typing import Any

_PATTERN = re.compile(r"\[\[acai:([^\]]+)\]\]")


def _coerce(v: str) -> Any:
    v = v.strip()
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def parse_line(line: str) -> dict[str, Any]:
    out: dict[str, Any] = {}
    for m in _PATTERN.finditer(line):
        for pair in m.group(1).split(","):
            if "=" in pair:
                k, v = pair.split("=", 1)
                out[k.strip()] = _coerce(v)
    return out


def parse_log(text: str) -> dict[str, Any]:
    out: dict[str, Any] = {}
    for line in text.splitlines():
        out.update(parse_line(line))
    return out
