"""Deterministic chaos injection for the fault-tolerance layer.

A :class:`FaultPlan` is a seeded description of the faults a run should
suffer; a :class:`FaultInjector` replays it against a scheduler + virtual
runner on the *virtual clock*, so a chaos scenario is exactly as
reproducible as the fleet it torments. Three fault classes, each an
independent Poisson process (exponential inter-arrival times drawn from
one seeded ``random.Random``):

- **node kills** — a uniformly-drawn up node on a uniformly-drawn pool
  dies (``Scheduler.fail_node``): it leaves packing and capacity, and
  every resident job fails atomically as *transient* (whole gangs — the
  reservation is one unit), flowing the normal retry path;
- **transient job failures** — a uniformly-drawn RUNNING job fails
  transient (``VirtualRunner.fail_running``), modeling flaky
  infrastructure below the node level (NIC resets, container OOM-kill);
- **stragglers** — a uniformly-drawn RUNNING job's remaining work
  stretches by ``straggler_factor`` (``VirtualRunner.slow_running``),
  the failure mode ``JobSpec.timeout_s`` exists to bound.

Determinism: the injector draws from its own ``Random(seed)`` only — it
never reads wall clocks — and every draw is a function of the (plan,
event-loop order) pair, so two runs over the same fleet with the same
plan inject bit-identical fault sequences. With no plan (or a plan whose
rates are all None/0) the injector schedules nothing, and a fleet run
is byte-for-byte the pre-chaos run — the golden-trace gate relies on it.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

from repro.core.engine.lifecycle import JobState


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded fault schedule. Rates are mean seconds between events on
    the virtual clock (None or <= 0 disables the class). ``start``
    shields warm-up: no fault fires before it. ``max_node_failures``
    bounds the dead-node count so a long run cannot grind the whole
    cluster away."""
    seed: int = 0
    node_mtbf_s: Optional[float] = None       # mean time between node kills
    transient_mtbf_s: Optional[float] = None  # ... transient job failures
    straggler_mtbf_s: Optional[float] = None  # ... straggler slowdowns
    straggler_factor: float = 4.0             # remaining-work stretch
    start: float = 0.0
    max_node_failures: Optional[int] = None


class FaultInjector:
    """Replays a :class:`FaultPlan` against a scheduler + runner.

    Event-loop contract (mirrors ``Scheduler.next_timer``): advance the
    virtual clock to ``min(runner completion, injector.next_event(),
    scheduler.next_timer())``, then call ``advance_to(now)`` — the
    injector applies every fault scheduled at or before ``now`` and
    draws the next arrival for each class. ``events`` accumulates an
    audit log of what was actually applied (skipped draws — no running
    job, no up node — are logged too; they still consume randomness, so
    the schedule stays independent of fleet state)."""

    def __init__(self, plan: FaultPlan, scheduler, runner):
        self.plan = plan
        self.scheduler = scheduler
        self.runner = runner
        self.rng = random.Random(plan.seed)
        self.events: list[dict] = []
        self.node_failures = 0
        now = getattr(runner, "now", 0.0) or 0.0
        t0 = max(now, plan.start)
        self._next = {
            kind: self._draw(t0, mtbf)
            for kind, mtbf in (("node", plan.node_mtbf_s),
                               ("transient", plan.transient_mtbf_s),
                               ("straggler", plan.straggler_mtbf_s))
            if mtbf is not None and mtbf > 0}

    def _draw(self, t: float, mtbf: float) -> float:
        return t + self.rng.expovariate(1.0 / mtbf)

    def next_event(self) -> Optional[float]:
        """Virtual time of the earliest scheduled fault, or None."""
        return min(self._next.values()) if self._next else None

    def advance_to(self, now: float) -> list[dict]:
        """Apply every fault scheduled at or before ``now``; returns the
        newly-applied event records."""
        applied = []
        while self._next:
            kind = min(self._next, key=self._next.get)
            t = self._next[kind]
            if t > now + 1e-9:
                break
            rec = self._apply(kind, t)
            if rec is not None:
                applied.append(rec)
                self.events.append(rec)
            mtbf = {"node": self.plan.node_mtbf_s,
                    "transient": self.plan.transient_mtbf_s,
                    "straggler": self.plan.straggler_mtbf_s}[kind]
            self._next[kind] = self._draw(t, mtbf)
        return applied

    # ------------------------------------------------------------------
    def _running_jobs(self) -> list:
        jobs = [j for j in self.scheduler.registry.all_jobs()
                if j.state == JobState.RUNNING]
        jobs.sort(key=lambda j: j.job_id)       # deterministic draw order
        return jobs

    def _apply(self, kind: str, t: float) -> Optional[dict]:
        if kind == "node":
            cap = self.plan.max_node_failures
            if cap is not None and self.node_failures >= cap:
                self._next.pop("node", None)
                return {"t": t, "kind": "node", "skipped": "cap"}
            targets = []        # (pool, node_idx) over every up node
            for pname in sorted(self.scheduler.pools):
                cl = self.scheduler.pools[pname]
                up = getattr(cl, "up_nodes", None)
                if callable(up):
                    targets.extend((pname, i) for i in up())
            if not targets:
                self.rng.random()       # burn the draw: state-independent
                return {"t": t, "kind": "node", "skipped": "no-up-nodes"}
            pool, idx = targets[self.rng.randrange(len(targets))]
            failed = self.scheduler.fail_node(pool, idx)
            self.node_failures += 1
            return {"t": t, "kind": "node", "pool": pool, "node": idx,
                    "failed_jobs": failed}
        jobs = self._running_jobs()
        if not jobs:
            self.rng.random()
            return {"t": t, "kind": kind, "skipped": "no-running-jobs"}
        job = jobs[self.rng.randrange(len(jobs))]
        if kind == "transient":
            ok = self.runner.fail_running(
                job, error="injected transient fault", transient=True)
            return {"t": t, "kind": "transient", "job": job.job_id,
                    "applied": bool(ok)}
        new_end = self.runner.slow_running(job, self.plan.straggler_factor)
        return {"t": t, "kind": "straggler", "job": job.job_id,
                "factor": self.plan.straggler_factor,
                "new_end": new_end}
