"""Cluster-capacity scheduler (ACAI §3.3.1–§3.3.2, scaled to shared
heterogeneous capacity).

The seed engine was a per-(project, user) FIFO with a quota of at most
``quota_k`` jobs in LAUNCHING|RUNNING per tuple. That quota survives, but
admission is now gated on finite capacity *pools* — one ``Cluster`` per
accelerator family, chosen per job by the ``Placement`` layer
(``core/engine/placement.py``): a job launches only when its resource
charge fits some eligible pool, reserved on launch and released on
terminal events. A single ``cluster=`` degenerates to one pool named
"default" (the homogeneous deployment); a job no pool can ever satisfy
fails fast at submit instead of queuing forever. Across queues the
scheduler orders work by

  1. priority      — queue priority + per-job priority, higher first;
  2. fair share    — accumulated dominant-share x runtime per queue,
                     divided by the queue's weight, lower first (DRF-style);
  3. submit order  — FIFO tie-break.

When the head candidate fits none of its pools, EASY backfill lets later
(smaller) jobs launch into the capacity hole as long as they provably do
not delay the blocked job *on its preferred pool*: either they finish
before the blocked job's shadow start time there (computed from that
pool's running jobs' expected completions), or they fit into the capacity
that remains spare on that pool after the blocked job starts. Shadow
state is per pool — a blocked head on the TPU pool never throttles CPU
dispatch, and a flexible job whose best pool is blocked simply takes its
next-ranked pool. With ``policy="fifo"`` the scheduler degrades to a
strict global-submission-order convoy (the benchmark baseline).

Dispatch is *incremental* (see docs/engine.md "Dispatch internals &
complexity"): the per-event hot path never rebuilds the world. Per-queue
candidate slices are cached sorted by ``(-priority, seq)`` and merged
lazily through a heap keyed by ``(-priority, decayed_share, seq)``, so a
pass only pays for the candidates it actually examines and only queues
whose contents/headroom changed re-sort. Queue deletion is tombstoned
(``kill``/launch are O(1) amortized instead of ``deque.remove``'s O(n)).
Per-pool EASY shadow state — the sorted expected-end list and the free
capacity it walks — is maintained incrementally on launch/terminal
instead of re-copying and re-sorting every reservation each round, and
the ``_min_charge`` saturation bound is a set of per-pool per-dimension
min-heaps over *live* queued charges (lazily pruned), so it tightens as
small jobs drain instead of going monotonically stale. Scheduler
snapshots are coalesced behind a change gate plus an optional
``snapshot_interval``. All of this is decision-preserving: the replay
equivalence tests assert bit-identical launch order and pool assignment
against traces recorded before the incremental core landed.

Dependency gating (the pipeline SDK's dataflow layer): a job whose
``spec.depends_on`` names unfinished parents is *held* — QUEUED in the
registry but absent from every dispatch queue, so it never enters the
candidate scan, the quota count, or the backfill shadow-time math. Parent
terminal events release it (all parents FINISHED -> enqueued) or cascade
it (any parent FAILED/KILLED -> terminal UPSTREAM_FAILED, published on the
bus so the cascade propagates transitively and handles/monitors wake).

Fair-share usage optionally decays with a configurable half-life
(``usage_halflife``, in runner-clock seconds) so past consumption stops
penalizing a queue forever.

Checkpoint-aware preemption (``preemption=True``, off by default so every
recorded decision trace replays bit-identically): when a queue head has
starved past ``starvation_threshold`` runner-clock seconds and fits no
pool, the scheduler preempts the lowest-priority / latest-started running
jobs whose released reservations provably unblock it — the launcher
delivers a checkpoint signal (``launcher.preempt``), fair-share settles
the victim's *actual partial runtime*, the reservation is released, and
the victim re-enters QUEUED (``RUNNING -> PREEMPTED -> QUEUED``) to
resume later from its last checkpoint. Each requeue bumps ``Job.epoch``;
terminal events stamped with an older epoch are dropped, so a superseded
incarnation can never settle (or double-release) the reservation of the
next one. The same preemption path drains a pool shrunk below its live
reservations (``resize_pool``) and models spot reclamations
(``reclaim``).

Dispatch is iterative and non-reentrant: runners that publish a terminal
``container_status`` synchronously from inside ``launch`` (instant local
jobs) re-enter the scheduler through the bus; a guard flag folds those
re-entries into the outer dispatch loop instead of recursing, so a fast job
can neither double-launch nor miscount quota/capacity. All entry points
are locked for the ThreadPoolRunner's worker threads.

The paper's 95 % profiling quorum (§4.2.2) stays a first-class
straggler-mitigation policy.
"""
from __future__ import annotations

import heapq
import inspect
import threading
import time
from bisect import bisect_left, insort
from collections import defaultdict, deque
from typing import Optional

from repro.core.engine.cluster import CapacityError, Cluster
from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_SCHEDULER)
from repro.core.engine.lifecycle import (IllegalTransition, TERMINAL_STATES,
                                         TERMINAL_STATUS_VALUES, JobState)
from repro.core.engine.placement import Placement
from repro.core.engine.registry import Job, JobRegistry


def validate_spec(spec) -> None:
    """Reject malformed specs at submit, before any state change.

    Zero/negative resource dimensions silently fit every pool (a zero
    charge passes every capacity check), so a typo like ``{"tpu": 0}``
    would queue, launch, and hold nothing — fail loudly instead. Gang
    shapes are sanity-checked here too so a bad width/topology surfaces
    at submit rather than deep in admission.
    """
    shapes = [("resources", spec.resources or {})]
    for pool, res in (spec.pool_resources or {}).items():
        shapes.append((f"pool_resources[{pool!r}]", res or {}))
    gang = getattr(spec, "gang", None)
    if gang is not None and gang.per_pod_resources is not None:
        shapes.append(("gang.per_pod_resources", gang.per_pod_resources))
    for where, res in shapes:
        for dim, amt in res.items():
            if not isinstance(amt, (int, float)) or amt <= 0:
                raise ValueError(
                    f"job {spec.name!r}: {where} dimension {dim!r} must "
                    f"be a positive number, got {amt!r}")
    if gang is not None:
        if gang.n_pods < 1:
            raise ValueError(f"job {spec.name!r}: gang.n_pods must be "
                             f">= 1, got {gang.n_pods}")
        if not 0 <= gang.min_pods <= gang.n_pods:
            raise ValueError(
                f"job {spec.name!r}: gang.min_pods must be in "
                f"[0, n_pods={gang.n_pods}], got {gang.min_pods}")
        if gang.topology not in ("any", "close"):
            raise ValueError(f"job {spec.name!r}: gang.topology must be "
                             f"'any' or 'close', got {gang.topology!r}")
    retry = getattr(spec, "retry", None)
    if retry is not None:
        if retry.max_retries < 0:
            raise ValueError(f"job {spec.name!r}: retry.max_retries must "
                             f"be >= 0, got {retry.max_retries}")
        if retry.backoff_base < 0 or retry.backoff_cap < 0:
            raise ValueError(f"job {spec.name!r}: retry backoff must be "
                             f">= 0")
        if retry.retry_on not in ("transient", "any"):
            raise ValueError(f"job {spec.name!r}: retry.retry_on must be "
                             f"'transient' or 'any', got "
                             f"{retry.retry_on!r}")
    for knob in ("timeout_s", "deadline"):
        v = getattr(spec, knob, None)
        if v is not None and (not isinstance(v, (int, float)) or v <= 0):
            raise ValueError(f"job {spec.name!r}: {knob} must be a "
                             f"positive number of seconds, got {v!r}")


class QueueConfig:
    """Per-(project, user) scheduling knobs."""

    def __init__(self, priority: int = 0, weight: float = 1.0):
        self.priority = priority
        self.weight = max(weight, 1e-9)


class _Window:
    """A queue's candidate window, maintained incrementally.

    ``rows`` always holds the queue's first ``min(live, maxdepth)`` live
    jobs in arrival order as sort-keyed tuples (``(-priority, seq, jid,
    dispatch-records)`` under fair, ``(seq, jid, records)`` under fifo);
    jobs beyond it wait in the queue's tail deque and are promoted as the
    window drains, so a dispatch pass slices instead of rescanning the
    queue. ``fast`` means arrival order already equals candidate sort
    order (uniform priority, monotone seqs — the common case), making
    the slice the sorted window.

    ``agg``/``pdurs`` are the window-level rejection certificate (see
    ``_dispatch_once``). Minima are updated exactly on insert and left
    stale-but-conservative on removal (a too-small minimum only makes
    the certificate *less* willing to skip, never wrong); a full
    recompute runs every 64 mutations to restore tightness.
    """

    __slots__ = ("rows", "ids", "fast", "per_depth",
                 "muts", "stale", "agg", "pdurs", "pdur_of")

    def __init__(self):
        self.rows: list = []
        self.ids: set = set()
        self.fast = True
        self.per_depth: Optional[dict] = None
        self.muts = 0
        self.stale = False
        # per-pool window certificate: {pool: [per-dim minimum charge,
        # minimum expected duration, unprobed count, live member count]}
        # — when a pool is blocked and both backfill paths are provably
        # dead for every member, candidates eligible only there reject
        # wholesale. Durations fold in eagerly only when declared
        # statically (oracle draws must stay at the launcher's own probe
        # points); unknown estimates keep duration certificates off via
        # the unprobed count, and member counts drop a pool the moment
        # no live member references it. None = voided (unknown member).
        self.agg: Optional[dict] = {}
        # per-pool duration index: {pool: [(dur, -prio, seq, jid, recs)]}
        # sorted by dur, so a spare-dead pass enumerates only the
        # candidates that could still backfill by finishing early
        self.pdurs: dict = {}
        self.pdur_of: dict = {}


class Scheduler:
    def __init__(self, registry: JobRegistry, launcher, bus: EventBus,
                 quota_k: int = 2, *, cluster: Optional[Cluster] = None,
                 placement: Optional[Placement] = None,
                 policy: str = "fair", backfill: bool = True,
                 backfill_depth: int = 100,
                 usage_halflife: Optional[float] = None,
                 snapshot_interval: float = 0.0,
                 preemption: bool = False,
                 starvation_threshold: float = 300.0,
                 quarantine_threshold: int = 3,
                 user_failure_budget: Optional[int] = None):
        if policy not in ("fair", "fifo"):
            raise ValueError(f"unknown policy {policy!r}")
        if cluster is not None and placement is not None:
            raise ValueError("pass cluster= or placement=, not both")
        self.registry = registry
        self.launcher = launcher
        self.bus = bus
        self.quota_k = quota_k
        self.policy = policy
        self.backfill = backfill and policy == "fair"
        self.backfill_depth = backfill_depth
        self.usage_halflife = usage_halflife
        # checkpoint-aware preemption: off by default (decision traces
        # recorded without it must replay bit-identically), and only
        # meaningful when the launcher can deliver a checkpoint signal
        self.preemption = preemption
        self.starvation_threshold = starvation_threshold
        # fault tolerance (all inert unless some spec opts in): a job
        # whose spec carries a RetryPolicy re-queues FAILED incarnations
        # (epoch rebirth) after an exponential backoff hold; K
        # *consecutive* non-transient failures end it QUARANTINED (a
        # crash loop is a bug, not bad luck); a per-(project, user)
        # budget of non-transient failures-without-a-success stops a
        # crash-looping sweep from monopolizing dispatch with retries
        self.quarantine_threshold = quarantine_threshold
        self.user_failure_budget = user_failure_budget
        # backoff holds: job_id -> release time. QUEUED in the registry
        # but absent from every dispatch queue (like dependency holds),
        # released into _enqueue by the timer sweep at dispatch entry.
        self._backoff: dict[str, float] = {}
        # deadline/timeout enforcement points: a min-heap of
        # (fire_at, kind 0=timeout|1=deadline, job_id, epoch) — timeout
        # entries are per-incarnation (stale epochs skipped), deadline
        # entries absolute from submit (epoch -1, any incarnation)
        self._timers: list[tuple] = []
        self._ticking = False
        # wall-clock alarm for real-clock engines (no launcher.now):
        # nothing external calls tick() there, so the earliest pending
        # backoff release / deadline / timeout arms a daemon timer
        self._wall_alarm: Optional[threading.Timer] = None
        self._wall_alarm_at = 0.0
        # non-transient failures per queue key since its last success
        self._user_fails: dict[tuple, int] = defaultdict(int)
        self._can_preempt = callable(getattr(launcher, "preempt", None))
        self._can_forget = callable(getattr(launcher, "forget", None))
        self._preempting = False
        # snapshot coalescing: 0.0 publishes on every state change; > 0
        # rate-limits to one snapshot per interval of runner-clock seconds
        self.snapshot_interval = snapshot_interval
        self._queues: dict[tuple, deque[str]] = defaultdict(deque)
        self._active: dict[tuple, set[str]] = defaultdict(set)
        self._qconf: dict[tuple, QueueConfig] = defaultdict(QueueConfig)
        self._usage: dict[tuple, float] = defaultdict(float)
        self._usage_t: dict[tuple, float] = {}
        # dependency gating: held job -> unmet parent ids, and the reverse
        # index parent -> held children released/cascaded on its terminal
        self._held: dict[str, set[str]] = {}
        self._dependents: dict[str, set[str]] = defaultdict(set)
        self._seq_of: dict[str, int] = {}
        self._seq = 0
        # -- incremental dispatch state --------------------------------
        # tombstoned queues: _queued_set holds the ids that are *live*;
        # deque entries absent from it are tombstones skipped (and
        # compacted) lazily, making launch/kill removal O(1) amortized
        self._queued_set: set[str] = set()
        self._qlen: dict[tuple, int] = {}          # live length per queue
        self._tombs: dict[tuple, int] = {}         # tombstones per queue
        # per-queue candidate windows (see _Window): the first
        # quota_k + backfill_depth live jobs stay materialized in sort
        # order and mutate incrementally; _queues holds only each
        # queue's tail beyond its window
        self._qwin: dict[tuple, _Window] = {}
        # per-job dispatch-scan caches
        self._prio_of: dict[str, int] = {}
        self._opts_of: dict[str, dict] = {}       # job -> {pool: PoolOption}
        self._rank_of: dict[str, list[str]] = {}  # job -> pools best-first
        self._job_of: dict[str, Job] = {}         # skip registry lock
        # pre-flattened per-job dispatch records in rank order:
        # [pool, pool.used, ((dim, amt, cap+eps), ...), charge.items(),
        #  charge, memoized-expected-duration] — everything the admission
        # hot loop touches, resolved once per job instead of per visit
        self._dinfo: dict[str, list] = {}
        self._dur_takes_pool: Optional[bool] = None
        # submit fast path: when nothing changed since the last completed
        # (and therefore futile-ending) dispatch except new arrivals, and
        # none of them fits any of its pools right now (plus the blocked
        # registration certificate below), a full scan provably launches
        # nothing and is skipped entirely
        self._dirty_full = True
        self._new_cands: list[str] = []
        # futile-pass certificate: {pool: sort key of the candidate that
        # registered its blocked entry} plus how many candidates fit some
        # pool but were backfill-rejected; None = no valid certificate
        self._futile_blocked: Optional[dict] = None
        self._futile_fit_rejects = 0
        # saturation bound: pool -> dim -> min-heap of (charge, jid) over
        # live queued jobs, pruned lazily — replaces the old write-only
        # monotone _min_charge dict, so the bound tightens on settle
        self._min_charge: dict[str, dict[str, list]] = {}
        # per-pool EASY shadow state, maintained on launch/terminal:
        # sorted [(end, launch_seq, jid, reservation)], plus the count of
        # running jobs whose end the launcher could not estimate (any > 0
        # disables backfill on that pool, as the full rescan used to)
        self._pool_ends: dict[str, list] = {}
        self._end_key: dict[str, tuple] = {}      # jid -> (pool, sort key)
        self._unknown_ends: dict[str, int] = {}
        self._lseq = 0
        self._has_end = callable(getattr(launcher, "expected_end", None))
        self._has_dur = callable(getattr(launcher, "expected_duration",
                                         None))
        self._queued_at: dict[str, float] = {}
        self._started_at: dict[str, float] = {}
        self._lock = threading.RLock()
        self._dispatching = False
        self._dispatch_pending = False
        # snapshot gate: publish only when the revision moved (and the
        # interval elapsed); every state mutation bumps _state_rev
        self._state_rev = 0
        self._pub_rev = -1
        self._pub_t = float("-inf")
        self._settles = 0
        # running aggregates (not per-job lists): a long-lived platform
        # schedules millions of jobs, so metrics must stay O(queues)
        self.stats = {"launched": 0, "completed": 0, "backfilled": 0,
                      "wait_count": 0, "wait_sum": 0.0,
                      "wait_by_key": defaultdict(lambda: [0, 0.0]),
                      "placed_by_pool": defaultdict(int),
                      "snapshots": 0, "snapshots_skipped": 0,
                      "preempted": 0, "reclaimed": 0, "drained": 0,
                      "gang_shrunk": 0, "retried": 0, "quarantined": 0,
                      "timeouts": 0, "deadline_kills": 0,
                      "node_failures": 0, "retry_wasted_s": 0.0}
        self.placement: Optional[Placement] = None
        if placement is not None:
            self.placement = placement
        elif cluster is not None:
            self.placement = Placement({cluster.name or "default": cluster})
        # optional write-ahead journal (durable control plane): elastic
        # capacity changes record through it so a restarted engine
        # rebuilds the *current* pool sizes, not the boot-time ones
        self.journal = None
        bus.subscribe(TOPIC_CONTAINER_STATUS, self._on_container_status)

    # -- pools ----------------------------------------------------------
    @property
    def pools(self) -> dict[str, Cluster]:
        return self.placement.pools if self.placement is not None else {}

    @property
    def cluster(self) -> Optional[Cluster]:
        """The sole pool's cluster in a homogeneous deployment (legacy
        single-cluster callers); None when capacity-unconstrained or
        genuinely multi-pool."""
        pools = self.pools
        if len(pools) == 1:
            return next(iter(pools.values()))
        return None

    @cluster.setter
    def cluster(self, cl: Optional[Cluster]) -> None:
        with self._lock:
            self.placement = None if cl is None else \
                Placement({cl.name or "default": cl})
            # the pool set changed: every cached eligibility/ranking is
            # stale (they name pools that may no longer exist) — drop
            # them; _ensure_opts re-derives lazily per job. Shadow state
            # and the saturation bound belong to the old pools too; jobs
            # still running there release against the old Cluster object
            # (settle guards make the removal a no-op).
            self._min_charge = {}
            self._opts_of = {}
            self._rank_of = {}
            self._dinfo = {}
            self._pool_ends = {}
            self._end_key = {}
            self._unknown_ends = {}
            for w in self._qwin.values():
                w.stale = True      # window certificates name old pools
            self._dirty_full = True
            self._state_rev += 1

    # -- elasticity ------------------------------------------------------
    def resize_pool(self, pool: str, capacity: dict[str, float], *,
                    drain: bool = True) -> dict[str, float]:
        """Grow or shrink a pool's capacity (the provisioning loop's
        actuator). Per-job placement caches bake capacity thresholds and
        eligibility, so they are dropped and re-derived lazily; window
        rejection certificates are refreshed the same way. Reservations
        that outlive a shrink are drained through the preemption path
        (lowest-priority, latest-started first) when the launcher
        supports it — otherwise they simply finish naturally while the
        over-committed pool admits nothing new. Returns the immediate
        post-resize overage per dimension (before any drain completes).
        """
        with self._lock:
            cl = self.pools[pool]
            old_cap = dict(cl.capacity)
            overage = cl.resize(capacity)
            if self.journal is not None:
                # journal the full post-resize capacity (absolute, so
                # replay is idempotent even across partial-dim resizes)
                self.journal.pool_resized(pool, cl.capacity)
            grew = any(float(v) > old_cap.get(n, 0.0) + 1e-9
                       for n, v in capacity.items())
            if grew:
                # growth can make jobs eligible on this pool that were
                # not before (their caches do not reference it, so a
                # scoped drop would miss them): drop everything. Note
                # jobs already FAILED infeasible at submit are *not*
                # resurrected — declare shapes within the pool's floor
                # capacity, or submit after growing.
                self._opts_of = {}
                self._rank_of = {}
                self._dinfo = {}
            else:
                # shrink only narrows eligibility/thresholds of jobs
                # that reference this pool: a scoped drop is complete,
                # and the routine elastic control path stays cheap
                stale = [jid for jid, opts in self._opts_of.items()
                         if pool in opts]
                for jid in stale:
                    self._opts_of.pop(jid, None)
                    self._rank_of.pop(jid, None)
                    self._dinfo.pop(jid, None)
            for w in self._qwin.values():
                w.stale = True      # certificates embed old thresholds
            self._futile_blocked = None
            self._dirty_full = True
            self._state_rev += 1
            if overage and drain:
                # elastic gangs shrink to min_pods in place first — a
                # resize beats a full requeue (the trainer re-meshes from
                # its checkpoint without losing its slot)
                need = dict(overage)
                self._shrink_to_cover(cl, need)
                overage = {n: cl.used.get(n, 0.0) - cl.capacity.get(n, 0.0)
                           for n in overage
                           if cl.used.get(n, 0.0) >
                           cl.capacity.get(n, 0.0) + 1e-9}
            if overage and drain and self._can_preempt:
                # drain through the one victim-selection policy (lowest
                # priority, latest started), best-effort: even if no
                # victim set fully covers the overage, preempt what helps
                vics = self._pick_victims(cl, dict(overage), partial=True)
                over = lambda: any(
                    cl.used.get(n, 0.0) > cl.capacity.get(n, 0.0) + 1e-9
                    for n in capacity)
                was = self._preempting
                self._preempting = True     # batch: one dispatch at the end
                try:
                    for vid in vics or ():
                        if not over():
                            break
                        if self.preempt(vid):
                            self.stats["drained"] += 1
                finally:
                    self._preempting = was
            self._dispatch()
            return overage

    def reclaim(self, pool: str,
                capacity: Optional[dict[str, float]] = None, *,
                warning: float = 0.0) -> list[str]:
        """Forced preemption on a (spot) pool — the cloud took the nodes
        back. Frees at least ``capacity`` on every listed dimension
        (None = evict everything running there) by first shrinking
        resizable gangs to their floor, then preempting victims in the
        one shared victim order (lowest priority, latest started —
        ``_pick_victims``); they checkpoint and re-queue like any
        preemption. ``warning > 0`` models the cloud's advance notice: a
        checkpoint request (``launcher.request_checkpoint``) fires for
        every victim before the forced preempt lands, banking exact
        progress so the work lost to the reclaim is (near) zero instead
        of up to one checkpoint interval. Returns the preempted job ids
        (shrunk gangs keep running and are not listed)."""
        with self._lock:
            cl = self.pools.get(pool)
            if cl is None or not self._can_preempt:
                return []
            if capacity is None:
                # evict all: the need is everything currently reserved
                need: dict[str, float] = defaultdict(float)
                for res in cl.reservations().values():
                    for n, amt in res.items():
                        need[n] += amt
            else:
                free = cl.free()
                need = {n: amt - free.get(n, 0.0)
                        for n, amt in capacity.items()
                        if amt > free.get(n, 0.0) + 1e-9}
                if need:
                    # a partial reclaim is elastic pressure: resizable
                    # gangs give back pods in place before anyone is
                    # evicted (a full reclaim must evict regardless)
                    self._shrink_to_cover(cl, need)
            if not need:
                return []           # already free: nothing to evict
            victims = self._pick_victims(cl, dict(need), partial=True)
            req_ckpt = getattr(self.launcher, "request_checkpoint", None) \
                if warning > 0 else None
            if callable(req_ckpt):
                # the grace window: checkpoint requests land first, the
                # forced preemption only after — lost work ~ 0
                for vid in victims or ():
                    vjob = self._job_of.get(vid)
                    if vjob is not None:
                        req_ckpt(vjob)
            out = []
            was = self._preempting
            self._preempting = True         # batch: one dispatch at the end
            try:
                for vid in victims or ():
                    if self.preempt(vid):
                        out.append(vid)
            finally:
                self._preempting = was
            self.stats["reclaimed"] += len(out)
            if out:
                self._dispatch()
            return out

    # -- elastic gang resize (shrink-to-k) ------------------------------
    def shrink_gang(self, job_id: str, k: int) -> bool:
        """Shrink a RUNNING resizable gang to ``k`` pods in place: the
        surplus pods' reservation frees immediately, the launcher
        re-paces the remaining work at the new width, and the job's
        ``gang_pods`` drops so an in-process trainer can re-mesh from its
        checkpoint (``train.fault.gang_resize_hook``) — no requeue, no
        epoch bump. Returns False when the job is not a running gang or
        ``k`` is outside [max(1, min_pods), n_pods)."""
        with self._lock:
            job = self._job_of.get(job_id)
            if job is None:
                try:
                    job = self.registry.get(job_id)
                except KeyError:
                    return False
            if job.state != JobState.RUNNING or not job.pool:
                return False
            cl = self.pools.get(job.pool)
            g = cl.gang_of(job_id) if cl is not None and \
                hasattr(cl, "gang_of") else None
            if g is None:
                return False
            _pod, n = g
            gang = getattr(job.spec, "gang", None)
            floor = max(1, gang.min_pods if gang is not None else 0)
            if gang is None or gang.min_pods <= 0 or not floor <= k < n:
                return False
            cl.shrink_gang_hold(job_id, k)
            # re-pace BEFORE dropping the job's width: the launcher reads
            # the old width off the job to stretch the remaining work
            # (and to bill the elapsed segment at what it actually used)
            resize = getattr(self.launcher, "resize_gang", None)
            new_end = resize(job, k) if callable(resize) else None
            job.gang_pods = k
            # the shadow entry carries the old aggregate + old end: swap
            # it for the shrunk reservation at the re-paced completion
            self._drop_shadow(job_id)
            if job_id in self._started_at:
                if new_end is None:
                    self._unknown_ends[job.pool] = \
                        self._unknown_ends.get(job.pool, 0) + 1
                    self._end_key[job_id] = (job.pool, None)
                else:
                    self._lseq += 1
                    insort(self._pool_ends.setdefault(job.pool, []),
                           (new_end, self._lseq, job_id, cl.held(job_id)))
                    self._end_key[job_id] = (job.pool,
                                             (new_end, self._lseq))
            self.stats["gang_shrunk"] += 1
            self._dirty_full = True
            self._futile_blocked = None
            self._state_rev += 1
            return True

    def _shrink_to_cover(self, cl, need: dict[str, float]) -> list[str]:
        """Cover (part of) ``need`` by shrinking resizable running gangs
        toward their ``min_pods`` floor — tried before any preemption, in
        the same victim order (lowest effective priority, latest
        started). Mutates ``need`` in place; returns the resized ids."""
        gangs = getattr(cl, "gang_reservations", None)
        if gangs is None or not need:
            return []
        cands = []
        for vid, (pod, n) in gangs().items():
            vjob = self._job_of.get(vid)
            if vjob is None or vjob.state != JobState.RUNNING:
                continue
            gang = getattr(vjob.spec, "gang", None)
            if gang is None or gang.min_pods <= 0:
                continue
            floor = max(1, gang.min_pods)
            if n <= floor:
                continue
            vprio = self._qconf[vjob.queue_key].priority + \
                self._prio_of.get(vid, 0)
            cands.append((vprio, -self._started_at.get(vid, 0.0),
                          vid, pod, n, floor))
        cands.sort()
        shrunk = []
        for _, _, vid, pod, n, floor in cands:
            if not need:
                break
            want = 0            # pods whose release covers the shortfall
            for dim, amt in need.items():
                per = pod.get(dim, 0.0)
                if per > 1e-12:
                    want = max(want, int(-(-amt // per)))
            if want <= 0:
                continue        # this gang's pods carry none of the dims
            drop = min(want, n - floor)
            if drop <= 0 or not self.shrink_gang(vid, n - drop):
                continue
            shrunk.append(vid)
            for dim in list(need):
                left = need[dim] - pod.get(dim, 0.0) * drop
                if left <= 1e-9:
                    del need[dim]
                else:
                    need[dim] = left
        return shrunk

    def queued_demand(self, pool: str) -> int:
        """Live queued jobs eligible on ``pool`` — the provisioning
        controller's pressure signal. Jobs whose eligibility cache was
        dropped (a resize just happened) count conservatively as demand."""
        with self._lock:
            n = 0
            for jid in self._queued_set:
                opts = self._opts_of.get(jid)
                if opts is None or pool in opts:
                    n += 1
            return n

    # ------------------------------------------------------------------
    def _now(self) -> float:
        now = getattr(self.launcher, "now", None)
        return now if now is not None else time.time()

    def configure_queue(self, project: str, user: str, *,
                        priority: int = 0, weight: float = 1.0) -> None:
        with self._lock:
            self._qconf[(project, user)] = QueueConfig(priority, weight)
            w = self._qwin.get((project, user))
            if w is not None:
                w.stale = True      # row priorities embed the old config
            self._dirty_full = True

    # ------------------------------------------------------------------
    def submit(self, job: Job) -> None:
        validate_spec(job.spec)
        with self._lock:
            # resolve (and validate) dependencies before any state change:
            # an unknown parent id must not leave a zombie QUEUED job
            unmet, failed_parent = self._resolve_deps(job)
            self.registry.set_state(job.job_id, JobState.QUEUED)
            self._seq += 1
            self._seq_of[job.job_id] = self._seq
            self._prio_of[job.job_id] = job.spec.priority
            self._queued_at[job.job_id] = self._now()
            if failed_parent is not None:
                self._upstream_fail(job.job_id, failed_parent)
                return
            dl = getattr(job.spec, "deadline", None)
            if dl is not None:
                # fail-fast at admission when the deadline is *provably*
                # infeasible on every pool: the declared duration is a
                # pool-independent lower bound on wall time (retries and
                # checkpoint resumes only add to it), so duration >
                # deadline can never finish in time anywhere
                if job.spec.duration is not None and job.spec.duration > dl:
                    self._fail_infeasible(
                        job, err=(f"deadline {dl}s is infeasible: declared "
                                  f"duration {job.spec.duration}s exceeds "
                                  f"it on every pool"))
                    return
                heapq.heappush(self._timers,
                               (self._queued_at[job.job_id] + dl, 1,
                                job.job_id, -1))
            if self.placement is not None:
                options = self.placement.eligible(job.spec)
                if not options:
                    # no pool can ever fit it: fail fast, don't queue forever
                    self._fail_infeasible(job)
                    return
                self._opts_of[job.job_id] = options
            if unmet:
                # held: not in any queue, so invisible to the candidate
                # scan, the quota count and the backfill shadow-time math
                self._held[job.job_id] = unmet
                for pid in unmet:
                    self._dependents[pid].add(job.job_id)
                self._state_rev += 1
            else:
                self._enqueue(job)
            self._dispatch()

    def adopt_running(self, job: Job) -> None:
        """Re-attach a job whose run survived an engine crash (its
        process-boundary worker kept executing): rebuild the bookkeeping
        ``_launch`` would have created — quota membership, reservation,
        wait clocks, shadow state — without re-launching. The expected
        end is unknown (the original estimate died with the old engine),
        so the pool's backfill conservatively disables until it settles.
        """
        with self._lock:
            jid = job.job_id
            key = job.queue_key
            self._seq += 1
            self._seq_of[jid] = self._seq
            self._prio_of[jid] = job.spec.priority
            self._job_of[jid] = job
            self._active[key].add(jid)
            self._started_at[jid] = self._now()
            # privileged reassignment: the job's true state is externally
            # known (its worker is still executing), not derived by an
            # edge — the registry journals it like any transition
            self.registry.force_state(jid, JobState.RUNNING)
            if job.pool is not None:
                cl = self.pools.get(job.pool)
                if cl is None:
                    job.pool = None
                else:
                    try:
                        cl.reserve(jid, job.spec.resources)
                    except CapacityError:
                        # the pool shrank across the restart and the
                        # adopted set no longer fits: run it unreserved
                        # (pool=None, so settle releases nothing) rather
                        # than kill work that is already executing
                        job.pool = None
                    except Exception:
                        cl.release(jid)
                        raise
            if job.pool is not None:
                self._unknown_ends[job.pool] = \
                    self._unknown_ends.get(job.pool, 0) + 1
                self._end_key[jid] = (job.pool, None)
            self._dirty_full = True
            self._state_rev += 1

    _MISS = object()        # "duration not probed yet" sentinel

    def _ensure_opts(self, job: Job) -> dict:
        """The job's cached pool options, re-deriving (and re-ranking)
        them when the pool set changed since submit (legacy ``cluster=``
        reassignment drops the caches). Empty => nothing fits anymore."""
        opts = self._opts_of.get(job.job_id)
        if opts is None:
            opts = self.placement.eligible(job.spec)
            if opts:
                self._opts_of[job.job_id] = opts
                self._rank_of[job.job_id] = self.placement.rank(
                    job.spec, opts, parent_pools=self._parent_pools(job))
                self._build_dinfo(job.job_id)
                if job.job_id in self._queued_set:
                    self._push_min_charge(job.job_id, opts)
        return opts

    def _build_dinfo(self, job_id: str) -> None:
        """Flatten the job's ranked pool options into the records the
        admission loop iterates: per pool, the live ``used`` dict and
        pre-resolved ``(dim, amount, capacity + eps)`` fit thresholds
        (capacity is immutable, so the epsilon addition happens once per
        job instead of once per candidate visit), the charge item tuple
        the backfill spare check walks, a memoized runtime slot, and —
        only for a gang headed at a node-shaped pool — the (per-pod
        shape, pod count) the packability check needs (None everywhere
        else, so the non-gang hot path pays one ``is None`` test)."""
        opts = self._opts_of[job_id]
        pools = self.pools
        recs = []
        for pname in self._rank_of[job_id]:
            opt = opts[pname]
            cl = pools[pname]
            cap = cl.capacity
            gang = (opt.resources, opt.pods) if opt.pods > 1 and \
                getattr(cl, "node_shape", None) is not None else None
            recs.append([pname, cl.used,
                         tuple((n, amt, cap.get(n, 0.0) + 1e-9)
                               for n, amt in opt.charge.items()),
                         tuple(opt.charge.items()), opt.charge, self._MISS,
                         gang])
        self._dinfo[job_id] = recs

    def _push_min_charge(self, job_id: str, opts: dict) -> None:
        """Feed a live queued job's charges into the per-pool per-dim
        saturation heaps; entries are pruned lazily once the job leaves
        the queues (launched / killed / settled)."""
        for pname, opt in opts.items():
            heaps = self._min_charge.setdefault(pname, {})
            for n, amt in opt.charge.items():
                heapq.heappush(heaps.setdefault(n, []), (amt, job_id))

    def _enqueue(self, job: Job) -> None:
        """Queue a dispatchable job, ranking its eligible pools now — all
        parents are terminal at this point, so dataflow locality (the
        pools holding the parents' output filesets) is known."""
        if self.placement is not None:
            opts = self._ensure_opts(job)
            if not opts:
                self._fail_infeasible(job)
                return              # became infeasible (pool set changed)
            self._rank_of[job.job_id] = self.placement.rank(
                job.spec, opts, parent_pools=self._parent_pools(job))
            self._build_dinfo(job.job_id)
        jid = job.job_id
        key = job.queue_key
        self._queued_set.add(jid)
        self._qlen[key] = self._qlen.get(key, 0) + 1
        self._job_of[jid] = job
        w = self._qwin.get(key)
        if w is None:
            w = self._qwin[key] = _Window()
        if w.stale:
            self._win_refresh(key, w)
        if len(w.rows) < self._maxdepth():
            # normally the tail is empty here (promotion refills the
            # window on every removal); promote defensively in case
            # quota/backfill knobs grew the window since
            self._win_promote(key, w)
            if len(w.rows) < self._maxdepth():
                self._win_append(key, w, jid)
            else:
                self._queues[key].append(jid)
        else:
            self._queues[key].append(jid)       # beyond the window: tail
        self._new_cands.append(jid)
        if self.placement is not None:
            self._push_min_charge(jid, self._opts_of[jid])
        self._state_rev += 1

    def _maxdepth(self) -> int:
        """Window capacity: the deepest any pass can scan one queue."""
        return self.quota_k + (self.backfill_depth if self.backfill else 0)

    def _remove_queued(self, key: tuple, job_id: str) -> None:
        """Remove a job from its queue: an O(window) in-place delete plus
        tail promotion when it sat in the candidate window (the common
        case — launches come from the window), an O(1) tombstone in the
        tail deque otherwise (compacted once the dead outnumber the
        living)."""
        self._queued_set.discard(job_id)
        self._qlen[key] -= 1
        w = self._qwin.get(key)
        if w is not None and job_id in w.ids:
            w.ids.discard(job_id)
            rows = w.rows
            jpos = 2 if self.policy != "fifo" else 1
            removed = None
            for i, row in enumerate(rows):
                if row[jpos] == job_id:
                    removed = row
                    del rows[i]
                    break
            w.per_depth = None
            if w.agg is not None and removed is not None and \
                    removed[jpos + 1] is not None:
                # exact per-pool member counts: a pool no live member is
                # eligible for must stop gating the window certificate
                # (its minima would otherwise suppress skips forever)
                for r in removed[jpos + 1]:
                    ent = w.agg.get(r[0])
                    if ent is not None:
                        ent[3] -= 1
                        if r[5] is self._MISS and ent[2] > 0:
                            ent[2] -= 1
                        if ent[3] <= 0:
                            del w.agg[r[0]]
            dkeys = w.pdur_of.pop(job_id, None)
            if dkeys:
                for pname, dkey in dkeys.items():
                    lst_d = w.pdurs.get(pname)
                    if lst_d:
                        di = bisect_left(lst_d, dkey)
                        if di < len(lst_d) and lst_d[di][3] == job_id:
                            lst_d.pop(di)
            w.muts += 1         # removals only: they stale the minima
            if w.muts >= 64:
                w.stale = True      # restore certificate tightness
            self._win_promote(key, w)
        else:
            tombs = self._tombs.get(key, 0) + 1
            if tombs > 8 and tombs > self._qlen[key]:
                live = self._queued_set
                self._queues[key] = deque(
                    j for j in self._queues[key] if j in live)
                tombs = 0
            self._tombs[key] = tombs
        self._state_rev += 1

    def _win_promote(self, key: tuple, w: _Window) -> None:
        """Refill the window from the queue's tail (skipping tombstones)
        so it again holds the first ``min(live, maxdepth)`` live jobs."""
        tail = self._queues.get(key)
        if not tail:
            return
        live = self._queued_set
        maxdepth = self._maxdepth()
        while len(w.rows) < maxdepth and tail:
            jid = tail.popleft()
            if jid in live:
                self._win_append(key, w, jid)
            else:
                self._tombs[key] = self._tombs.get(key, 0) - 1

    def _win_append(self, key: tuple, w: _Window, jid: str) -> None:
        """Append one job to the window, updating sort-order fastness and
        the single-pool rejection certificate incrementally (minima only
        ever tighten downward here — exact; removals leave them stale
        low, which is the conservative direction)."""
        seq = self._seq_of[jid]
        rows = w.rows
        recs = self._dinfo.get(jid)
        if self.policy == "fifo":
            if rows and rows[-1][0] > seq:
                w.fast = False
            rows.append((seq, jid, recs))
            w.ids.add(jid)
            w.per_depth = None
            return      # certificates are a fair-policy device
        np_ = -(self._qconf[key].priority + self._prio_of.get(jid, 0))
        if rows and (rows[-1][0] != np_ or rows[-1][1] > seq):
            w.fast = False
        rows.append((np_, seq, jid, recs))
        w.ids.add(jid)
        w.per_depth = None
        if recs is None:
            w.agg = None        # unknown member: certificates void
            return
        if w.agg is not None:
            # per-pool certificate minima over every pool any member is
            # eligible for (see the window skips in _dispatch_once).
            # Probe eagerly only when the duration is declared statically
            # (then every shipped launcher's estimate is a pure read);
            # oracle-backed estimates must be drawn at the launcher's own
            # probe points or the draw would see unpinned resources
            static_dur = self._job_of[jid].spec.duration is not None
            dkeys = None
            for r in recs:
                ent = w.agg.get(r[0])
                if ent is None:
                    ent = w.agg[r[0]] = [{}, None, 0, 0]
                ent[3] += 1         # live members eligible on this pool
                mins = ent[0]
                for nm, amt, thr in r[2]:
                    cur = mins.get(nm)
                    if cur is None or amt < cur[0]:
                        mins[nm] = (amt, thr)
                d = r[5]
                if d is self._MISS and static_dur:
                    d = self._probe_duration(jid, r[0])
                    r[5] = d
                if d is self._MISS:
                    ent[2] += 1     # unknown: duration certificates off
                elif d is not None:
                    if ent[1] is None or d < ent[1]:
                        ent[1] = d
                    dkey = (d, np_, seq)
                    insort(w.pdurs.setdefault(r[0], []),
                           dkey + (jid, recs))
                    if dkeys is None:
                        dkeys = {}
                    dkeys[r[0]] = dkey
            if dkeys is not None:
                w.pdur_of[jid] = dkeys

    def _win_refresh(self, key: tuple, w: _Window) -> None:
        """Full rebuild of a window's rows and certificate from its own
        job order (plus tail promotion): runs after config/pool changes
        and periodically to re-tighten removal-staled minima."""
        jpos = 2 if self.policy != "fifo" else 1
        jids = [row[jpos] for row in w.rows]
        w.rows = []
        w.ids = set()
        w.fast = True
        w.per_depth = None
        w.agg = {}
        w.pdurs = {}
        w.pdur_of = {}
        w.stale = False
        for jid in jids:
            self._win_append(key, w, jid)
        self._win_promote(key, w)
        w.muts = 0

    def _parent_pools(self, job: Job) -> set[str]:
        pools = set()
        for pid in job.spec.depends_on or ():
            try:
                parent = self.registry.get(pid)
            except KeyError:
                continue
            if parent.pool:
                pools.add(parent.pool)
        return pools

    def _resolve_deps(self, job: Job) -> tuple[set[str], Optional[str]]:
        """(unmet parent ids, first already-failed parent or None)."""
        unmet: set[str] = set()
        for pid in dict.fromkeys(job.spec.depends_on or ()):
            try:
                parent = self.registry.get(pid)
            except KeyError:
                raise ValueError(
                    f"{job.job_id} depends on unknown job {pid!r}") from None
            if parent.state == JobState.FINISHED:
                continue
            if parent.state in TERMINAL_STATES:
                return set(), pid
            unmet.add(pid)
        return unmet, None

    def kill(self, job_id: str) -> None:
        with self._lock:
            job = self.registry.get(job_id)
            if job.state in TERMINAL_STATES:
                return
            key = job.queue_key
            launched = job_id in self._started_at
            if job_id in self._queued_set:
                self._remove_queued(key, job_id)
            self._unhold(job_id)
            self._backoff.pop(job_id, None)
            self._active[key].discard(job_id)
            # epoch read + terminal write both happen under this lock
            # (every epoch bump is lock-ordered behind it), so the guard
            # pins "kill this incarnation" even against a racing retry
            self.registry.set_state(job_id, JobState.KILLED,
                                    expect_epoch=job.epoch)
            if launched:
                # the runner publishes the terminal event when the job
                # actually stops (virtual-clock pop / worker finalize);
                # settle capacity now so the slot frees immediately
                self._settle(job_id, key)
                self._dispatch()
            else:
                # never reached the runner: publish the terminal event
                # ourselves so handles, monitors and held dependents
                # observe the kill (the handler settles + dispatches)
                self.registry.persist_state(job_id)
                self.bus.publish(TOPIC_CONTAINER_STATUS,
                                 {"job_id": job_id, "status": "KILLED",
                                  "epoch": job.epoch})

    # -- checkpoint-aware preemption ------------------------------------
    def preempt(self, job_id: str) -> bool:
        """Revoke a RUNNING job's reservation and re-queue it to resume
        from its last checkpoint (``RUNNING -> PREEMPTED -> QUEUED``).

        Returns False — job untouched — only when it is not RUNNING or
        the launcher has no ``preempt`` capability. Otherwise the
        preemption commits *before* the checkpoint signal is delivered
        (state + epoch move first, so a cooperative worker observing the
        signal mid-delivery already sees it as real), and the delivery
        itself is best-effort: a worker that completed in the same
        instant loses the race and its terminal event is dropped as
        stale. Fair-share settles the *actual* partial runtime of the
        segment, the reservation is released exactly once (the epoch
        guard drops superseded incarnations' terminal events), and the
        job re-enters its queue with a fresh sequence number and wait
        clock.
        """
        with self._lock:
            try:
                job = self.registry.get(job_id)
            except KeyError:
                return False
            if job.state != JobState.RUNNING or not self._can_preempt:
                return False
            key = job.queue_key
            # transition + epoch bump BEFORE delivering the signal, and
            # atomically under the registry lock: a cooperative worker
            # that observes its flag mid-delivery must already see the
            # preemption as real (epoch moved), or it would misread the
            # raise as spurious and fail the job — and its own
            # epoch-guarded finalize write must serialize against the bump
            try:
                self.registry.mark_preempted(job_id)
            except IllegalTransition:
                # a worker finalized the job (RUNNING -> terminal, under
                # the registry lock alone) between our check and the
                # transition: the completion won — nothing to preempt
                return False
            # best-effort: a worker that completed in the same instant
            # loses the race — its terminal event (stamped with the old
            # epoch) is dropped and the job re-runs from its checkpoint
            self.launcher.preempt(job)
            self._active[key].discard(job_id)
            self._settle_preempted(job_id, key, job)
            self.stats["preempted"] += 1
            # re-queue for a fresh launch: new seq (the tail of its
            # queue), new wait clock; pool ranking re-derives at enqueue
            self.registry.set_state(job_id, JobState.QUEUED)
            self._seq += 1
            self._seq_of[job_id] = self._seq
            self._prio_of[job_id] = job.spec.priority
            self._queued_at[job_id] = self._now()
            self._enqueue(job)
            self._dirty_full = True
            if not self._preempting:
                self._dispatch()    # externally-driven preemption (spot
            return True             # reclaim): relaunch what now fits

    def _settle_preempted(self, job_id: str, key: tuple, job) -> None:
        """Release the preempted segment's reservation and charge
        fair-share with its actual partial runtime. Unlike ``_settle``
        the per-job caches survive — the job is still live and about to
        re-enter its queue."""
        pool_cl, released, started_at = self._release_segment(job_id, job)
        self._state_rev += 1
        if started_at is None:
            return
        self._charge_segment(key, job, pool_cl, released,
                             max(0.0, self._now() - started_at))

    def _release_segment(self, job_id: str, job) -> tuple:
        """Release the job's reservation and shadow-state entry — the
        half of settling shared by terminal settles and preemptions.
        Returns (pool cluster, released charge, started_at)."""
        pool_cl = self.pools.get(job.pool) if job.pool else None
        released = pool_cl.release(job_id) if pool_cl is not None else None
        started_at = self._started_at.pop(job_id, None)
        self._drop_shadow(job_id)
        self._dirty_full = True
        return pool_cl, released, started_at

    def _charge_segment(self, key: tuple, job, pool_cl, released,
                        runtime: float) -> None:
        """Fair-share charge for one runtime segment: the dominant share
        on the pool the job ran on (the released charge when available) —
        THE one formula for terminal and preemption settles alike."""
        if pool_cl is None:
            share = 1.0
        elif released is not None:
            share = pool_cl.dominant_share_charge(released)
        else:
            share = pool_cl.dominant_share(job.spec.resources)
        self._charge_usage(key, (share if share > 0 else 1.0) * runtime)

    def _run_preemption(self) -> bool:
        """One preemption round: find the starved head — the highest
        effective-priority live queue-head whose wait exceeds
        ``starvation_threshold`` and which fits no pool — then preempt
        the lowest-priority / latest-started running jobs whose released
        reservations cover its shortfall on some eligible pool (tried in
        the head's placement rank order). Returns True if victims were
        preempted (the caller re-dispatches)."""
        if self.placement is None:
            return False
        now = self._now()
        jpos = 2 if self.policy != "fifo" else 1
        head = None     # (-eff_priority, seq) of the best starved head
        for key, w in self._qwin.items():
            if self._qlen.get(key, 0) <= 0:
                continue
            if len(self._active[key]) >= self.quota_k:
                continue    # quota-pinned: a launch is impossible anyway
            if w.stale:
                self._win_refresh(key, w)
            # O(1) pre-filter: _queued_at is assigned in seq order, so
            # the first live row in arrival order holds the queue's
            # minimum wait clock — if IT is not starved, nobody here is,
            # and the sorted-candidate walk below is skipped entirely
            # (the common case on every dispatch under steady load)
            oldest_ok = False
            for row in w.rows:
                jid0 = row[jpos]
                if jid0 in self._queued_set:
                    oldest_ok = now - self._queued_at.get(jid0, now) >= \
                        self.starvation_threshold
                    break
            if not oldest_ok:
                continue
            # scan in candidate *sort* order, not arrival order: the
            # queue's policy head is its highest-priority live job, and a
            # starved high-priority job parked behind an older low-prio
            # one must not be hidden by it
            rows = w.rows if self.policy == "fifo" else \
                self._queue_cands(w, len(w.rows))
            for row in rows:
                jid = row[jpos]
                if jid not in self._queued_set:
                    continue
                # only queue heads are starvation candidates: deeper jobs
                # are behind them by policy order anyway
                if now - self._queued_at.get(jid, now) >= \
                        self.starvation_threshold:
                    eff = self._qconf[key].priority + \
                        self._prio_of.get(jid, 0)
                    cand = (-eff, self._seq_of.get(jid, 0), jid, key)
                    if head is None or cand < head:
                        head = cand
                break
        if head is None:
            return False
        neg_prio, _, jid, key = head
        head_prio = -neg_prio
        job = self._job_of[jid]
        recs = self._dinfo.get(jid)
        if recs is None:
            if not self._ensure_opts(job):
                return False
            recs = self._dinfo.get(jid)
            if recs is None:
                return False
        # a head that fits some pool right now is backfill/fairness
        # blocked, not capacity starved: preemption cannot help it
        for rec in recs:
            used_d = rec[1]
            if all(used_d.get(n, 0.0) + amt <= thr
                   for n, amt, thr in rec[2]) and self._packable(jid, rec):
                return False
        for pname in self._rank_of.get(jid, ()):
            cl = self.pools.get(pname)
            if cl is None:
                continue
            charge = self._opts_of[jid][pname].charge
            free = cl.free()
            need = {n: amt - free.get(n, 0.0) for n, amt in charge.items()
                    if amt > free.get(n, 0.0) + 1e-9}
            if not need:
                continue
            victims = self._pick_victims(cl, need, max_priority=head_prio)
            if victims is None:
                continue        # this pool cannot be unblocked: next
            for vid in victims:
                self.preempt(vid)
            return True
        return False

    def _pick_victims(self, cl, need: dict[str, float], *,
                      max_priority: Optional[int] = None,
                      partial: bool = False) -> Optional[list[str]]:
        """The minimal prefix of (lowest effective priority, latest
        started) RUNNING jobs on ``cl`` whose reservations cover every
        dimension of ``need``. When full coverage is impossible, returns
        None — or, with ``partial=True``, every eligible victim (the
        shrink-drain's best effort). ``max_priority`` (exclusive)
        protects equal-or-higher-priority work from being preempted for
        a starved head. This is THE victim-selection policy: starvation
        preemption, spot reclamation drains and pool-shrink drains must
        all pick identically."""
        cands = []
        for vid, res in cl.reservations().items():
            vjob = self._job_of.get(vid)
            if vjob is None or vjob.state != JobState.RUNNING:
                continue
            vprio = self._qconf[vjob.queue_key].priority + \
                self._prio_of.get(vid, 0)
            if max_priority is not None and vprio >= max_priority:
                continue
            cands.append((vprio, -self._started_at.get(vid, 0.0), vid, res))
        cands.sort()
        chosen: list[str] = []
        freed: dict[str, float] = defaultdict(float)
        for _, _, vid, res in cands:
            chosen.append(vid)
            for n, amt in res.items():
                freed[n] += amt
            if all(freed.get(n, 0.0) + 1e-9 >= amt
                   for n, amt in need.items()):
                return chosen
        return chosen if partial else None

    # -- fault tolerance -------------------------------------------------
    def tick(self) -> None:
        """Advance fault-tolerance time at the current runner clock:
        fire due deadline/timeout timers, release due backoff holds,
        then dispatch. Event loops that drive a virtual clock call this
        after every clock advance (terminal events dispatch anyway; this
        covers advances where nothing completed)."""
        with self._lock:
            self._dispatch()

    def next_timer(self) -> Optional[float]:
        """The earliest pending fault-tolerance enforcement point
        (deadline, timeout or backoff release), or None. Virtual-clock
        loops advance to ``min(next completion, next fault, next timer)``
        so backoff holds release and deadlines fire even while nothing
        is completing. May name an already-stale timer entry; firing it
        is a no-op but still makes progress (the entry pops)."""
        with self._lock:
            cands = []
            if self._timers:
                cands.append(self._timers[0][0])
            if self._backoff:
                cands.append(min(self._backoff.values()))
            return min(cands) if cands else None

    def _arm_wall_alarm(self) -> None:
        """Real-clock engines have no event loop calling ``tick()``, so
        a pending backoff hold or deadline/timeout would only fire when
        an unrelated event happened to dispatch: arm a daemon wall-clock
        timer for the earliest enforcement point instead. Virtual-clock
        runs (``launcher.now`` set) advance time themselves and never
        arm one — their traces stay bit-identical. Called at dispatch
        exit (every arming site ends in a dispatch), under the lock."""
        if getattr(self.launcher, "now", None) is not None:
            return
        due = None
        if self._timers:
            due = self._timers[0][0]
        if self._backoff:
            soonest = min(self._backoff.values())
            due = soonest if due is None else min(due, soonest)
        if due is None:
            return
        alarm = self._wall_alarm
        if (alarm is not None and alarm.is_alive()
                and self._wall_alarm_at <= due + 1e-9):
            return              # the armed alarm fires at or before due
        if alarm is not None:
            alarm.cancel()
        t = threading.Timer(max(0.0, due - time.time()),
                            lambda: self._wall_fire(t))
        t.daemon = True
        self._wall_alarm = t
        self._wall_alarm_at = due
        t.start()

    def _wall_fire(self, alarm: threading.Timer) -> None:
        with self._lock:
            if self._wall_alarm is alarm:
                self._wall_alarm = None
        self.tick()

    def _release_backoffs(self, now: float) -> None:
        """Move backoff holds whose release time arrived back into their
        dispatch queues (wait clock restarts at release — the hold is
        penance, not queueing)."""
        due = [jid for jid, t in self._backoff.items() if t <= now + 1e-9]
        for jid in sorted(due, key=lambda j: self._seq_of.get(j, 0)):
            del self._backoff[jid]
            job = self._job_of.get(jid)
            if job is None or job.state != JobState.QUEUED:
                continue        # killed while held (kill pops, but stay safe)
            self._queued_at[jid] = now
            self._enqueue(job)
            self._dirty_full = True
            self._futile_blocked = None

    def _fire_timers(self, now: float) -> None:
        """Enforce due deadline/timeout entries. A timeout fails the
        *incarnation* transient (straggler semantics — the retry budget
        may try it elsewhere); a deadline kills the *job* outright (the
        result is worthless after it, queued or running)."""
        while self._timers and self._timers[0][0] <= now + 1e-9:
            _t, kind, jid, epoch = heapq.heappop(self._timers)
            job = self._job_of.get(jid)
            if job is None:
                try:
                    job = self.registry.get(jid)
                except KeyError:
                    continue
            if job.state in TERMINAL_STATES:
                continue
            if kind == 0:       # per-incarnation timeout
                if job.state != JobState.RUNNING or job.epoch != epoch:
                    continue    # stale: that incarnation already ended
                err = (f"timeout: incarnation exceeded "
                       f"{job.spec.timeout_s}s")
                self.stats["timeouts"] += 1
                fr = getattr(self.launcher, "fail_running", None)
                if callable(fr) and fr(job, err, transient=True):
                    continue    # terminal event handler settles/retries
                self.kill(jid)
                job.error = err
            else:               # absolute deadline
                err = (f"deadline exceeded "
                       f"({job.spec.deadline}s after submit)")
                self._backoff.pop(jid, None)
                self.kill(jid)
                job.error = err
                self.stats["deadline_kills"] += 1

    def _maybe_retry(self, job: Job, key: tuple, msg: dict) -> bool:
        """Decide a FAILED incarnation's fate under the job's retry
        policy: requeue it as a new epoch (True — the caller skips the
        terminal settle and dependent cascade), quarantine a crash loop
        (False, with the registry state refined FAILED -> QUARANTINED so
        the caller settles it as the terminal it is), or let it stay
        FAILED (False). Inert unless the spec opted into a RetryPolicy —
        jobs without one take the exact pre-retry path, so recorded
        decision traces replay bit-identically."""
        policy = getattr(job.spec, "retry", None)
        if policy is None or job.state != JobState.FAILED:
            return False
        jid = job.job_id
        if jid not in self._started_at:
            return False        # never launched (infeasible submit):
                                # retrying can never change the outcome
        transient = bool(msg.get("transient"))
        streak = self.registry.note_failure(jid, transient)
        if not transient:
            self._user_fails[key] += 1
        if not transient and streak >= self.quarantine_threshold:
            # crash loop: the same non-transient failure K times in a row
            # is a bug, not bad luck — park it terminally instead of
            # burning the rest of the budget (FAILED -> QUARANTINED is
            # the transition table's one terminal-refinement edge)
            self.registry.set_state(
                jid, JobState.QUARANTINED,
                error=(f"quarantined after {streak} consecutive "
                       f"failures: {msg.get('error') or job.error}"),
                expect_epoch=job.epoch)
            self.registry.persist_state(jid)
            self.stats["quarantined"] += 1
            return False
        if not transient and policy.retry_on != "any":
            return False        # fatal failure, transient-only budget
        if job.retries >= policy.max_retries:
            return False        # budget exhausted: stays FAILED
        if self.user_failure_budget is not None and not transient and \
                self._user_fails[key] > self.user_failure_budget:
            return False        # the queue's failure budget is spent:
                                # stop feeding its crash loops dispatch
        # requeue as a fresh incarnation: settle the failed segment like
        # a preemption (release the reservation, charge fair-share for
        # the wasted runtime), then epoch-rebirth FAILED -> QUEUED
        now = self._now()
        started = self._started_at.get(jid)
        if started is not None:
            self.stats["retry_wasted_s"] += max(0.0, now - started)
        self._settle_preempted(jid, key, job)
        hold = policy.backoff(job.retries)      # pre-bump retry count
        self.registry.mark_retrying(jid)
        self.stats["retried"] += 1
        self._seq += 1
        self._seq_of[jid] = self._seq
        self._prio_of[jid] = job.spec.priority
        if hold > 0:
            self._backoff[jid] = now + hold
            self._state_rev += 1
        else:
            self._queued_at[jid] = now
            self._enqueue(job)
        self._dirty_full = True
        self._futile_blocked = None
        return True

    def fail_node(self, pool: str, node_idx: int) -> list[str]:
        """Kill one node on ``pool`` (the fault injector's actuator; on a
        real fleet, the health prober's). The node leaves packing and
        capacity, and every job holding a reservation on it fails
        atomically — a gang with one pod there fails whole, because the
        reservation is one unit. Node loss is *transient* (the
        infrastructure broke, not the job), so retry policies requeue
        the victims. Returns the job ids that were failed."""
        with self._lock:
            cl = self.pools[pool]
            residents = cl.fail_node(node_idx)
            self.stats["node_failures"] += 1
            return self._after_node_down(pool, residents, fail=True,
                                         node_idx=node_idx)

    def drain_node(self, pool: str, node_idx: int) -> list[str]:
        """Cordon one node on ``pool``: no new placements land on it,
        residents finish naturally. Returns the resident job ids."""
        with self._lock:
            cl = self.pools[pool]
            residents = cl.drain_node(node_idx)
            return self._after_node_down(pool, residents, fail=False,
                                         node_idx=node_idx)

    def _after_node_down(self, pool: str, residents: list[str], *,
                         fail: bool, node_idx: int) -> list[str]:
        """Shared tail of fail_node/drain_node: capacity shrank, so the
        per-job caches that bake this pool's thresholds are stale (same
        scoped drop resize_pool's shrink path does); on a hard failure
        the residents fail through the launcher so the terminal events
        flow the normal settle/retry path."""
        stale = [jid for jid, opts in self._opts_of.items() if pool in opts]
        for jid in stale:
            self._opts_of.pop(jid, None)
            self._rank_of.pop(jid, None)
            self._dinfo.pop(jid, None)
        for w in self._qwin.values():
            w.stale = True
        self._futile_blocked = None
        self._dirty_full = True
        self._state_rev += 1
        out = []
        if fail:
            fr = getattr(self.launcher, "fail_running", None)
            was = self._dispatching
            self._dispatching = True    # batch: one dispatch at the end
            try:
                for jid in residents:
                    job = self._job_of.get(jid)
                    if job is None or job.state != JobState.RUNNING:
                        continue
                    err = f"node {node_idx} on pool {pool} failed"
                    if callable(fr):
                        if fr(job, err, transient=True):
                            out.append(jid)
                    else:
                        self.kill(jid)
                        job.error = err
                        out.append(jid)
            finally:
                self._dispatching = was
        else:
            out = list(residents)
        self._dispatch()
        return out

    def _unhold(self, job_id: str) -> None:
        """Drop a held job's gating state: O(its parents), using the unmet
        set as the exact index into _dependents."""
        unmet = self._held.pop(job_id, None)
        for pid in unmet or ():
            deps = self._dependents.get(pid)
            if deps is not None:
                deps.discard(job_id)

    def _upstream_fail(self, job_id: str, parent_id: str) -> None:
        """Cascade-cancel a never-launched job whose parent did not
        finish; the published event propagates the cascade transitively."""
        job = self.registry.get(job_id)
        self.registry.set_state(
            job_id, JobState.UPSTREAM_FAILED,
            error=f"upstream job {parent_id} did not finish",
            expect_epoch=job.epoch)
        self.registry.persist_state(job_id)
        self._state_rev += 1
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job_id, "status": "UPSTREAM_FAILED",
                          "upstream": parent_id, "epoch": job.epoch})

    def _release_dependents(self, parent_id: str, status: str) -> None:
        """On a parent's terminal event: enqueue held children whose last
        parent FINISHED, cascade UPSTREAM_FAILED children otherwise."""
        children = self._dependents.pop(parent_id, None)
        if not children:
            return
        for cid in sorted(children):
            unmet = self._held.get(cid)
            if unmet is None:
                continue
            if status == JobState.FINISHED.value:
                unmet.discard(parent_id)
                if not unmet:
                    del self._held[cid]
                    child = self.registry.get(cid)
                    # queue wait starts at eligibility, not submit: the
                    # parent-hold time is dataflow latency, not queueing
                    self._queued_at[cid] = self._now()
                    self._enqueue(child)
            else:
                unmet.discard(parent_id)
                self._unhold(cid)
                self._upstream_fail(cid, parent_id)

    # -- dispatch (non-reentrant) ---------------------------------------
    def _maybe_launch(self, key: Optional[tuple] = None) -> None:
        """Back-compat alias for the dispatch loop."""
        with self._lock:
            self._dispatch()

    def _dispatch(self) -> None:
        if (self._timers or self._backoff) and not self._ticking:
            # fault-tolerance timers ride the dispatch entry point (every
            # clock advance ends in a dispatch): release due backoff
            # holds back into their queues and enforce due deadlines /
            # incarnation timeouts. Guarded non-reentrant — enforcement
            # kills/fails publish terminal events whose handlers dispatch.
            self._ticking = True
            try:
                now = self._now()
                if self._backoff:
                    self._release_backoffs(now)
                if self._timers:
                    self._fire_timers(now)
            finally:
                self._ticking = False
        if self._dispatching:
            # re-entered from a terminal event published inside launch();
            # fold into the outer loop instead of recursing.
            self._dispatch_pending = True
            return
        if not self._dirty_full and self._new_arrivals_unfit():
            # nothing changed since the last (futile-ending) full scan
            # except arrivals that fit no pool right now: a full pass
            # would reject every candidate again — skip it. Safe because
            # rejections are stable under pure arrivals: capacity only
            # changes on launch/terminal (which set _dirty_full), the
            # passage of time only *hardens* the backfill duration test,
            # and fair-share order changes cannot create admissions when
            # there are none to reorder.
            self._maybe_preempt()
            self._publish_snapshot()
            self._arm_wall_alarm()
            return
        self._dispatch_loop()
        self._maybe_preempt()
        self._publish_snapshot()
        self._arm_wall_alarm()

    def _dispatch_loop(self) -> None:
        self._dispatching = True
        try:
            progress = True
            while progress or self._dispatch_pending:
                self._dispatch_pending = False
                progress = self._dispatch_once()
            self._dirty_full = False
            del self._new_cands[:]
        finally:
            self._dispatching = False

    def _maybe_preempt(self) -> None:
        """Starvation-triggered preemption rounds after a dispatch pass:
        each round frees exactly the capacity one starved head needs,
        then re-runs dispatch so it (and anything else the releases
        unblocked) launches. Non-reentrant — the dispatches triggered by
        requeued victims fold into this round instead of recursing."""
        if not self.preemption or not self._can_preempt or self._preempting:
            return
        self._preempting = True
        try:
            while self._run_preemption():
                self._dispatch_loop()
        finally:
            self._preempting = False

    def _new_arrivals_unfit(self) -> bool:
        """True when skipping a full dispatch pass is provably
        decision-identical to running it: every not-yet-scanned arrival
        (a) fails the capacity fit check on all of its pools, and (b)
        cannot perturb the blocked-entry registrations old fit-but-
        backfill-rejected candidates were judged against — either no such
        candidate exists (``_futile_fit_rejects == 0``; rejections of
        never-fitting candidates are immune to blocked-entry changes), or
        the arrival's top-ranked pool was already registered strictly
        before the arrival's own position in the global order, making its
        visit a pure no-op. Checked arrivals are dropped: with no launch
        or terminal in between, capacity cannot have changed under them."""
        if self.placement is None:
            return not self._new_cands   # unconstrained: anything launches
        fb = self._futile_blocked
        if fb is None:
            return False                 # no futile certificate yet
        strict = self._futile_fit_rejects > 0
        if strict and (self.usage_halflife or self.policy == "fifo"):
            # decaying shares shift sort keys between passes (and fifo
            # never records fair keys): the positional check is unsound
            return False
        cands = self._new_cands
        live = self._queued_set
        while cands:
            jid = cands[-1]
            if jid in live:
                recs = self._dinfo.get(jid)
                if not recs:
                    return False
                for rec in recs:
                    used = rec[1]
                    fits = True
                    for n, amt, thr in rec[2]:
                        if used.get(n, 0.0) + amt > thr:
                            fits = False
                            break
                    if fits:
                        return False    # could launch: run the full scan
                if strict:
                    reg = fb.get(recs[0][0])
                    if reg is None:
                        return False    # would register a new blocked pool
                    key = self._job_of[jid].queue_key
                    conf = self._qconf[key]
                    gkey = (-(conf.priority + self._prio_of.get(jid, 0)),
                            self._usage[key] / conf.weight,
                            self._seq_of[jid])
                    if not reg < gkey:
                        return False    # would re-register it earlier
            cands.pop()
        return True

    def _queue_cands(self, w: _Window, depth: int) -> list:
        """The queue's first ``depth`` live entries in candidate sort
        order — a snapshot slice of the incrementally-maintained window
        when queue order equals sort order, a per-depth memoized sort
        otherwise. Always a copy: the window mutates under the pass as
        candidates launch, while a pass iterates its start-of-pass list
        (the pre-incremental semantics)."""
        rows = w.rows
        if w.fast:      # queue order == sort order
            return rows[:depth]
        per = w.per_depth
        if per is None:
            per = w.per_depth = {}
        d = depth if depth < len(rows) else -1   # -1 = full window
        got = per.get(d)
        if got is None:
            got = per[d] = sorted(rows if d < 0 else rows[:depth])
        return got

    def _candidate_heap(self, now: float) -> list:
        """One heap entry per non-empty, non-quota-full queue, keyed so a
        lazy pop-and-refill merge yields candidates in exactly the order
        the old full sort produced: ``(-priority, share, seq)`` under fair
        (share is constant per queue within a pass, so each queue's cached
        ``(-priority, seq)`` list is already globally sorted) and
        ``(seq,)`` under fifo. Entries carry (list, index) so only
        examined candidates are ever materialized; when a queue's
        remaining window is priority-uniform and strictly precedes every
        other stream, the whole window is consumed with no per-item heap
        traffic at all."""
        fifo = self.policy == "fifo"
        bdepth = self.backfill_depth if self.backfill else 0
        quota_k = self.quota_k
        heap = []
        for key, w in list(self._qwin.items()):
            live = self._qlen.get(key, 0)
            if live <= 0:
                continue
            headroom = quota_k - len(self._active[key])
            if headroom <= 0:
                continue
            if w.stale:
                self._win_refresh(key, w)
            depth = min(live, headroom + bdepth)
            if not w.rows:
                continue
            if fifo:
                lst = self._queue_cands(w, depth)
                if not lst:
                    continue
                heap.append((lst[0][0], key, lst, 0))
                continue
            share = self._decayed_usage(key, now) / \
                self._qconf[key].weight
            if w.fast:
                # lazy: the payload is the window itself — the slice is
                # only materialized if the pass actually scans it (until
                # a window is first iterated, its rows can only gain
                # appends at the end, so a later rows[:depth] slice is
                # identical to one taken now)
                r0 = w.rows[0]
                heap.append((r0[0], share, r0[1], key, w, depth, 0))
            else:
                lst = self._queue_cands(w, depth)
                if not lst:
                    continue
                heap.append((lst[0][0], share, lst[0][1], key, lst,
                             depth, 0))
        heapq.heapify(heap)
        return heap

    def _saturated(self) -> bool:
        """No queued job can possibly fit anywhere: on every pool some
        dimension's free capacity is below the smallest charge any of that
        pool's *live* queued jobs carries. The per-dim min-heaps are
        pruned lazily (launched/killed entries pop off the top), so the
        bound tightens as small jobs drain instead of going stale."""
        if not self._min_charge:
            return False
        live = self._queued_set
        for pname, cl in self.pools.items():
            heaps = self._min_charge.get(pname)
            if not heaps:
                continue        # no live job is eligible on this pool
            used = cl.used
            cap = cl.capacity
            blocked_dim = False
            any_live = False
            for n, h in heaps.items():
                while h and h[0][1] not in live:
                    heapq.heappop(h)
                if not h:
                    continue
                any_live = True
                if cap.get(n, 0.0) - used.get(n, 0.0) + 1e-9 < h[0][0]:
                    blocked_dim = True
                    break
            if any_live and not blocked_dim:
                return False    # this pool can still admit its smallest job
        return True

    def _packable(self, jid: str, rec) -> bool:
        """Node-level feasibility on top of the aggregate fit check:
        gangs ask the pool's packer for all pods; single jobs on a
        node-shaped pool ask it for one — aggregate free capacity can be
        fragmented across nodes, and launching on the aggregate alone
        would blow up in ``reserve_gang``. Pools without node accounting
        answer True for single jobs without a cluster call."""
        cl = self.pools[rec[0]]
        if rec[6] is not None:
            return cl.can_pack(rec[6][0], rec[6][1])
        if getattr(cl, "node_shape", None) is None:
            return True
        return cl.can_pack(self._opts_of[jid][rec[0]].resources, 1)

    def _visit(self, key: tuple, jid: str, blocked: dict,
               quota_used: dict, now: float, regkey) -> int:
        """Examine one candidate: 0 = rejected without fitting any pool
        (quota / capacity), 4 = fit some pool but was backfill-rejected,
        1 = launched, -1 = launched and the deployment saturated (stop
        the pass), -2 = convoy (head blocked under backfill-less strict
        ordering, stop the pass). ``regkey`` is the candidate's global
        sort key, recorded on the blocked entry it registers — the futile
        certificate the submit fast path checks new arrivals against.
        Mirrors the pre-incremental scan body decision-for-decision."""
        quota_k = self.quota_k
        used = quota_used.get(key, -1)
        if used < 0:
            used = len(self._active[key])
        if used >= quota_k:
            return 0
        chosen = None
        backfilled = False
        fit_any = False
        if self.placement is not None:
            recs = self._dinfo.get(jid)
            if recs is None:
                # pool set changed under a queued job: re-derive
                opts = self._ensure_opts(self._job_of[jid])
                if not opts:
                    job = self._job_of[jid]
                    self._remove_queued(key, jid)
                    self._fail_infeasible(job)
                    return 0
                recs = self._dinfo[jid]
            for rec in recs:
                used_d = rec[1]
                fits = True
                for n, amt, thr in rec[2]:
                    if used_d.get(n, 0.0) + amt > thr:
                        fits = False
                        break
                if not fits:
                    continue
                if not self._packable(jid, rec):
                    continue    # aggregate fits, pods don't node-pack
                fit_any = True
                pname = rec[0]
                blk = blocked.get(pname)
                if blk is not None:
                    shadow_eps = blk[3]
                    if shadow_eps is None:
                        continue    # no shadow estimate: stay conservative
                    dur = rec[5]
                    if dur is self._MISS:
                        dur = self._probe_duration(jid, pname)
                        rec[5] = dur
                    if dur is not None and now + dur <= shadow_eps:
                        backfilled = True   # ends before the blocked start
                    else:
                        spare = blk[2]
                        ok = True
                        citems = rec[3]
                        for n, amt in citems:
                            if amt > spare.get(n, 0.0) + 1e-9:
                                ok = False
                                break
                        if not ok:
                            continue
                        # this job may still be running at the shadow
                        # time: consume its share of the spare so later
                        # backfill candidates cannot collectively delay
                        # the blocked job
                        for n, amt in citems:
                            spare[n] = spare.get(n, 0.0) - amt
                        backfilled = True
                chosen = pname
                break
            if chosen is None:
                # fits no pool right now: reserve a shadow start on its
                # best-ranked pool (where placement wants it)
                top = recs[0][0]
                if top not in blocked:
                    shadow, spare = self._shadow_time(top, recs[0][4])
                    blocked[top] = [
                        recs[0][4], shadow, spare,
                        shadow + 1e-9 if shadow is not None else None,
                        regkey]
                if not self.backfill:
                    return -2
                return 4 if fit_any else 0
            if backfilled:
                self.stats["backfilled"] += 1
        self._launch(key, self._job_of[jid], chosen, now)
        quota_used[key] = used + 1
        return -1 if self._saturated() else 1

    def _dispatch_once(self) -> bool:
        if self._saturated():
            # nothing fits anywhere: a futile pass with no fit-rejected
            # candidates — a trivially valid certificate for the fast path
            self._futile_blocked = {}
            self._futile_fit_rejects = 0
            return False
        now = self._now()       # one clock read per pass: decay math and
        launched = False        # backfill estimates stay consistent
        # EASY shadow state is per pool: pool -> [blocked_req, shadow,
        # spare, shadow+eps, registrant sort key]; a blocked head
        # throttles only its own preferred pool
        blocked: dict[str, list] = {}
        quota_used: dict[tuple, int] = {}
        heap = self._candidate_heap(now)
        fifo = self.policy == "fifo"
        quota_k = self.quota_k
        live = self._queued_set
        visit = self._visit
        pop = heapq.heappop
        push = heapq.heappush
        fit_rejects = 0
        placement = self.placement
        bf_on = self.backfill
        active = self._active
        MISS = self._MISS
        while heap:
            ent = pop(heap)
            if fifo:
                seq, key, lst, i = ent
                end = len(lst)
                rows_src = lst
            else:
                negprio, share, _, key, payload, depth, i = ent
                if type(payload) is list:
                    lst = payload
                    end = len(lst)
                    rows_src = lst
                else:
                    # lazy fast window: rows gained at most appends since
                    # the heap was built, so rows[:depth] now equals the
                    # pass-start slice — defer the copy until (unless)
                    # the window is actually scanned
                    lst = None
                    end = depth
                    rows_src = payload.rows
            # bulk window: under fair ordering a queue's candidates are
            # consecutive whenever its (priority, share) strictly precedes
            # every other stream — consume the rest of the window with no
            # per-item heap traffic (the common case: shares rarely tie)
            if not fifo and rows_src[end - 1][0] == negprio and \
                    (not heap or (negprio, share) < (heap[0][0],
                                                     heap[0][1])):
                # window-level rejection certificate: for a pure
                # single-pool window, one aggregate check against the
                # blocked head's shadow/spare (or against free capacity)
                # can prove every candidate would be rejected — the
                # minimum charge / minimum duration proofs are monotone
                # in exactly the comparisons each visit would make
                w = self._qwin.get(key)
                if w is not None and bf_on and w.agg:
                    # evaluate the certificate per pool; verdicts:
                    #   1 — some pool could admit a member: scan normally
                    #   2 — every member provably rejected, but an
                    #       unregistered pool remains: the next live
                    #       candidate is visited (it registers its top
                    #       exactly as a full scan would), then the
                    #       certificate is re-evaluated — bounded, since
                    #       each round consumes a candidate
                    #   0 — every pool dead: the window rejects at once,
                    #       modulo duration-qualifiers
                    pools_d = self.pools
                    skip_mode = False
                    while True:
                        dur_alive = None
                        verdict = 0
                        for pname, (mins2, md2, unp2, _c) in \
                                w.agg.items():
                            used2 = pools_d[pname].used
                            fdead = False
                            for nm, (mn, thr) in mins2.items():
                                if used2.get(nm, 0.0) + mn > thr:
                                    fdead = True
                                    break
                            blk = blocked.get(pname)
                            if blk is None:
                                if fdead:
                                    verdict = 2     # rejected; may still
                                    continue        # register this pool
                                verdict = 1         # could admit here
                                break
                            if fdead:
                                continue    # blocked + unfittable: dead
                            se2 = blk[3]
                            if se2 is None:
                                continue    # pool conservatively dead
                            spare2 = blk[2]
                            sdead = False
                            for nm, (mn, _t) in mins2.items():
                                if mn > spare2.get(nm, 0.0) + 1e-9:
                                    sdead = True
                                    break
                            if not sdead:
                                verdict = 1         # spare-path alive
                                break
                            if unp2:
                                verdict = 1         # unknown durations
                                break
                            if md2 is not None and now + md2 <= se2:
                                if dur_alive is None:
                                    dur_alive = []
                                dur_alive.append((pname, se2))
                        if verdict == 1:
                            break           # genuine full scan
                        if verdict == 2:
                            if lst is None:
                                # a visit can launch (and thus mutate
                                # the live window): snapshot first
                                lst = rows_src[:end]
                                rows_src = lst
                            r = None
                            while i < end:
                                row = rows_src[i]
                                jid = row[2]
                                i += 1
                                if jid in live:
                                    r = visit(key, jid, blocked,
                                              quota_used, now,
                                              (row[0], share, row[1]))
                                    break
                            if r == 1 or r == -1:
                                # a duration-qualifier on a still-alive
                                # pool launched (the certificate only
                                # proves non-qualifiers rejected)
                                launched = True
                                if r == -1:
                                    return True     # saturated: stop
                            if r is not None and i < end:
                                continue    # re-evaluate post-register
                            skip_mode = True    # window exhausted
                            dur_alive = None
                            break
                        skip_mode = True
                        break
                    if skip_mode:
                        # may hide fit-but-rejected candidates: keep the
                        # futile certificate conservative
                        fit_rejects += 1
                        if dur_alive is None:
                            continue        # whole window rejects
                        if w.fast:
                            lo = rows_src[i][1]
                            hi = rows_src[end - 1][1]
                            quals = {}
                            for pname, se2 in dur_alive:
                                for dq in w.pdurs.get(pname, ()):
                                    if now + dq[0] > se2:
                                        break       # sorted: rest fail
                                    s2 = dq[2]
                                    if lo <= s2 <= hi and dq[3] in live:
                                        quals[dq[3]] = (dq[1], s2,
                                                        dq[3], dq[4])
                            lst = sorted(quals.values())
                            i = 0
                            end = len(lst)
                if lst is None:
                    lst = rows_src[:end]    # == the pass-start slice
                stop = False
                while i < end:
                    row = lst[i]
                    jid = row[2]
                    i += 1
                    if jid not in live:
                        continue
                    recs = row[3]
                    if recs is None and placement is not None:
                        # pool set changed under the job: slow path
                        r = visit(key, jid, blocked, quota_used, now,
                                  (row[0], share, row[1]))
                        if r == 1:
                            launched = True
                            continue
                        if r == 4:
                            fit_rejects += 1
                            continue
                        if r == -1:
                            launched = True
                            stop = True
                            break
                        if r == -2:
                            stop = True
                            break
                        if quota_used.get(key, 0) >= quota_k:
                            break
                        continue
                    # inlined _visit hot path (same decisions, no call /
                    # dinfo lookup per candidate — recs ride on the row)
                    used = quota_used.get(key, -1)
                    if used < 0:
                        used = len(active[key])
                    if used >= quota_k:
                        if key in quota_used:
                            break   # quota pinned: rest of window skipped
                        continue
                    chosen = None
                    backfilled = False
                    fit_any = False
                    if placement is not None:
                        for rec in recs:
                            used_d = rec[1]
                            fits = True
                            for n, amt, thr in rec[2]:
                                if used_d.get(n, 0.0) + amt > thr:
                                    fits = False
                                    break
                            if not fits:
                                continue
                            if not self._packable(jid, rec):
                                continue    # pods don't node-pack
                            fit_any = True
                            pname = rec[0]
                            blk = blocked.get(pname)
                            if blk is not None:
                                shadow_eps = blk[3]
                                if shadow_eps is None:
                                    continue
                                dur = rec[5]
                                if dur is MISS:
                                    dur = self._probe_duration(jid, pname)
                                    rec[5] = dur
                                if dur is not None and \
                                        now + dur <= shadow_eps:
                                    backfilled = True
                                else:
                                    spare = blk[2]
                                    ok = True
                                    for n, amt in rec[3]:
                                        if amt > spare.get(n, 0.0) + 1e-9:
                                            ok = False
                                            break
                                    if not ok:
                                        continue
                                    for n, amt in rec[3]:
                                        spare[n] = spare.get(n, 0.0) - amt
                                    backfilled = True
                            chosen = pname
                            break
                        if chosen is None:
                            top = recs[0][0]
                            if top not in blocked:
                                shadow, spare0 = self._shadow_time(
                                    top, recs[0][4])
                                blocked[top] = [
                                    recs[0][4], shadow, spare0,
                                    shadow + 1e-9 if shadow is not None
                                    else None,
                                    (row[0], share, row[1])]
                            if not bf_on:
                                stop = True     # convoy
                                break
                            if fit_any:
                                fit_rejects += 1
                            if key in quota_used and \
                                    quota_used[key] >= quota_k:
                                break
                            continue
                        if backfilled:
                            self.stats["backfilled"] += 1
                    self._launch(key, self._job_of[jid], chosen, now)
                    quota_used[key] = used + 1
                    launched = True
                    if self._saturated():
                        stop = True
                        break
                if stop:
                    break
                continue
            # item-level merge (fifo, priority-mixed windows, share ties)
            if lst is None:
                lst = rows_src[:end]        # == the pass-start slice
            row = lst[i]
            jid = row[2] if not fifo else row[1]
            i += 1
            if i < end and quota_used.get(key, -1) < quota_k:
                nxt = lst[i]
                if fifo:
                    push(heap, (nxt[0], key, lst, i))
                else:
                    push(heap, (nxt[0], share, nxt[1], key, lst, end, i))
            if jid not in live:
                continue        # launched/killed by a nested event
            r = visit(key, jid, blocked, quota_used, now,
                      None if fifo else (row[0], share, row[1]))
            if r == 1:
                launched = True
                continue
            if r == 4:
                fit_rejects += 1
                continue
            if r == -1:
                launched = True
                break
            if r == -2:
                break           # convoy: strict order blocks the rest
        if not launched:
            # record the futile certificate: which pools got blocked
            # entries and where in the global order they were registered
            self._futile_blocked = {p: blk[4] for p, blk in blocked.items()}
            self._futile_fit_rejects = fit_rejects
        return launched

    def _launch(self, key: tuple, job: Job, pool: Optional[str] = None,
                now: Optional[float] = None) -> None:
        jid = job.job_id
        self._remove_queued(key, jid)
        self._active[key].add(jid)
        reserved = None
        try:
            if pool is not None:
                opt = self._opts_of[jid][pool]
                cl = self.pools[pool]
                if opt.pods > 1 or \
                        getattr(cl, "node_shape", None) is not None:
                    # gangs reserve atomically (all pods or none); on a
                    # node-shaped pool even single jobs go through the
                    # node packer so the per-node books stay consistent
                    reserved = cl.reserve_gang(jid, opt.resources,
                                               opt.pods)
                    job.gang_pods = opt.pods if opt.pods > 1 else None
                else:
                    reserved = cl.reserve(jid, opt.resources)
                job.pool = pool
                # pin the concrete shape the job got (a per-pool menu
                # entry), so runner billing and observers see what was
                # allocated
                job.spec.resources = dict(opt.resources)
                self.stats["placed_by_pool"][pool] += 1
            if now is None:
                now = self._now()
            self._started_at[jid] = now
            t_s = getattr(job.spec, "timeout_s", None)
            if t_s is not None:
                # per-incarnation runtime limit: stamped with this epoch
                # so a retry/preempt relaunch gets its own fresh timer
                # and the old one expires as a no-op
                heapq.heappush(self._timers,
                               (now + t_s, 0, jid, job.epoch))
            wait = now - self._queued_at.pop(jid, now)
            self.stats["launched"] += 1
            self.stats["wait_count"] += 1
            self.stats["wait_sum"] += wait
            by_key = self.stats["wait_by_key"][key]
            by_key[0] += 1
            by_key[1] += wait
            self.registry.set_state(jid, JobState.LAUNCHING)
            self.launcher.launch(job)
            # feed the pool's incremental shadow state with the runner's
            # expected completion — available only after launch. A runner
            # that completed the job synchronously already settled it
            # (the nested event popped _started_at), so there is nothing
            # to track.
            if pool is not None and jid in self._started_at:
                end = self.launcher.expected_end(jid) \
                    if self._has_end else None
                if end is None:
                    self._unknown_ends[pool] = \
                        self._unknown_ends.get(pool, 0) + 1
                    self._end_key[jid] = (pool, None)
                else:
                    self._lseq += 1
                    insort(self._pool_ends.setdefault(pool, []),
                           (end, self._lseq, jid, reserved))
                    self._end_key[jid] = (pool, (end, self._lseq))
        except Exception as exc:
            self._abort_launch(key, jid, job, pool, exc)
            raise

    def _abort_launch(self, key: tuple, job_id: str, job: Job,
                      pool: Optional[str], exc: BaseException) -> None:
        """Unwind a launch that raised partway: hand back the
        reservation (idempotent — a no-op when reserve itself was what
        raised), drop the half-made bookkeeping, and terminal-ize the
        job as FAILED so it cannot strand in LAUNCHING while holding
        nothing. The caller re-raises; this only restores the books."""
        if pool is not None:
            cl = self.pools.get(pool)
            if cl is not None:
                cl.release(job_id)
        job.pool = None
        job.gang_pods = None
        self._active[key].discard(job_id)
        self._started_at.pop(job_id, None)
        self._drop_shadow(job_id)
        failed = None
        if job.state not in TERMINAL_STATES:
            try:
                if job.state != JobState.LAUNCHING:
                    self.registry.set_state(job_id, JobState.LAUNCHING)
                failed = self.registry.set_state(
                    job_id, JobState.FAILED,
                    error=f"launch aborted: {exc}",
                    expect_epoch=job.epoch)
            except IllegalTransition:
                pass    # a racing transition won; leave its state alone
        self._state_rev += 1
        self._dirty_full = True
        if failed is not None:
            self.registry.persist_state(job_id)
            self.bus.publish(TOPIC_CONTAINER_STATUS,
                             {"job_id": job_id, "status": "FAILED",
                              "epoch": job.epoch})

    def _fail_infeasible(self, job: Job,
                         err: Optional[str] = None) -> None:
        if err is None:
            err = (f"resources "
                   f"{job.spec.pool_resources or job.spec.resources} "
                   f"exceed cluster capacity on every pool "
                   f"({self.placement.explain_infeasible(job.spec)})")
        self.registry.set_state(job.job_id, JobState.LAUNCHING)
        self.registry.set_state(job.job_id, JobState.FAILED, error=err,
                                expect_epoch=job.epoch)
        # never reached a runner, so no worker log exists: make the
        # reason the log, so `acai logs <job>` answers "why did it fail"
        job.outputs.setdefault("log", err)
        self.registry.persist_state(job.job_id)
        self._state_rev += 1
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job.job_id, "status": "FAILED",
                          "epoch": job.epoch})

    # -- EASY backfill ---------------------------------------------------
    def _shadow_time(self, pool: str,
                     blocked_req: dict) -> tuple[Optional[float],
                                                 Optional[dict]]:
        """Earliest time the blocked job fits on ``pool`` (shadow start)
        and the capacity left spare there at that instant after it starts.
        Walks the pool's incrementally-maintained sorted expected-end list
        instead of re-copying and re-sorting every reservation; if any
        running job's end is unknown (the launcher could not estimate it)
        backfill stays conservative (disabled for this round)."""
        cl = self.pools.get(pool)
        if cl is None or self._unknown_ends.get(pool, 0):
            return None, None
        used = cl.used
        free = {n: cap - used[n] for n, cap in cl.capacity.items()}
        for end, _, _, res in self._pool_ends.get(pool, ()):
            for n, amt in res.items():
                if n in free:
                    free[n] += amt
            fits = True
            for n in blocked_req:
                if free.get(n, 0.0) < blocked_req[n] - 1e-9:
                    fits = False
                    break
            if fits:
                spare = {n: free.get(n, 0.0) - blocked_req[n]
                         for n in blocked_req}
                return end, spare
        return None, None

    def _probe_duration(self, jid: str, pool: str) -> Optional[float]:
        """Launcher runtime estimate for the backfill test, memoized into
        the job's dispatch record by the caller (the value is drawn once
        per (job, pool), so the hot path skips the launcher's
        getattr/try-except plumbing on every probe). The estimate is for
        THIS pool: a job that is quick on CPU but pays a TPU startup tax
        must be sized at its TPU runtime when backfilling the TPU pool's
        hole."""
        if not self._has_dur:
            return None
        job = self._job_of[jid]
        if self._dur_takes_pool is None:
            # classify the launcher's signature once, by inspection — a
            # TypeError raised *inside* a pool-aware estimator must not
            # silently demote every future probe to pool-less sizing
            try:
                params = inspect.signature(
                    self.launcher.expected_duration).parameters
                self._dur_takes_pool = "pool" in params or any(
                    p.kind is inspect.Parameter.VAR_KEYWORD
                    for p in params.values())
            except (TypeError, ValueError):
                self._dur_takes_pool = True     # builtins: assume modern
        if self._dur_takes_pool:
            return self.launcher.expected_duration(job, pool=pool)
        return self.launcher.expected_duration(job)

    # -- terminal events -------------------------------------------------
    def _on_container_status(self, msg: dict) -> None:
        status = msg.get("status", "")
        if status not in TERMINAL_STATUS_VALUES:
            return
        with self._lock:
            job_id = msg["job_id"]
            try:
                job = self.registry.get(job_id)
            except KeyError:
                # cross-process event sources (a surviving worker's
                # replayed buffer, a persisted event stream) can name
                # jobs this engine never registered — ignore, don't die
                return
            epoch = msg.get("epoch")
            if epoch is not None and epoch < job.epoch:
                # stale event from a pre-preemption incarnation (e.g. a
                # thread worker that finished after its job was preempted
                # and relaunched): settling it would release — and
                # fair-share-charge — the *new* incarnation's reservation
                return
            key = job.queue_key
            self._active[key].discard(job_id)
            if status == JobState.FAILED.value:
                retried = self._maybe_retry(job, key, msg)
                # decision made either way: lower the retry latch so
                # waiters may trust the registry's FAILED again
                job.retry_pending = False
                if retried:
                    # requeued as a new epoch: not terminal — no
                    # dependent cascade, no terminal settle (the failed
                    # segment was already settled preemption-style
                    # inside _maybe_retry)
                    self._dispatch()
                    return
            if status == JobState.FINISHED.value and \
                    key in self._user_fails:
                self._user_fails.pop(key)   # a success resets the
                                            # queue's failure budget
            self._release_dependents(job_id, status)
            self._settle(job_id, key)
            self._dispatch()

    def _settle(self, job_id: str, key: tuple) -> None:
        """Release capacity on the job's pool, free per-job bookkeeping,
        and charge fair-share usage. Idempotent (a killed virtual job
        later pops off the clock and publishes KILLED again), and
        usage/completed only accrue for jobs that actually launched."""
        job = self.registry.get(job_id)
        pool_cl, released, started_at = self._release_segment(job_id, job)
        self._prio_of.pop(job_id, None)
        self._opts_of.pop(job_id, None)
        self._rank_of.pop(job_id, None)
        self._dinfo.pop(job_id, None)
        self._job_of.pop(job_id, None)
        self._seq_of.pop(job_id, None)
        self._queued_at.pop(job_id, None)
        if self._can_forget:
            # the job is terminal: the launcher may hold restore state
            # (checkpoint progress) for it that no live run will reclaim
            self.launcher.forget(job_id)
        self._settles += 1
        if self._settles % 256 == 0:
            self._compact_min_charge()
        self._state_rev += 1
        if started_at is None:
            return          # never launched (queued kill / infeasible)
        runtime = job.runtime
        if runtime is None:
            runtime = max(0.0, self._now() - started_at)
        # fair-share usage is the dominant share on the pool the job ran
        # on: consuming half the TPU pool weighs like half the CPU pool
        self._charge_segment(key, job, pool_cl, released, runtime)
        self.stats["completed"] += 1

    def _drop_shadow(self, job_id: str) -> None:
        """Drop the job from its pool's incremental EASY shadow state
        (O(log n) locate) — shared by terminal settle and preemption."""
        ek = self._end_key.pop(job_id, None)
        if ek is not None:
            pool_name, sort_key = ek
            if sort_key is None:
                self._unknown_ends[pool_name] = \
                    max(0, self._unknown_ends.get(pool_name, 0) - 1)
            else:
                ends = self._pool_ends.get(pool_name)
                if ends:
                    i = bisect_left(ends, sort_key)
                    if i < len(ends) and ends[i][2] == job_id:
                        ends.pop(i)

    def _compact_min_charge(self) -> None:
        """Periodic sweep of the saturation heaps: lazy pruning only
        removes dead entries when they surface at the top, so a long-lived
        engine occasionally rebuilds heaps that are mostly tombstones."""
        live = self._queued_set
        bound = max(64, 4 * len(live))
        for heaps in self._min_charge.values():
            for n, h in heaps.items():
                if len(h) > bound:
                    kept = [e for e in h if e[1] in live]
                    heapq.heapify(kept)
                    heaps[n] = kept

    # -- fair-share usage with half-life decay ---------------------------
    def _decayed_usage(self, key: tuple,
                       now: Optional[float] = None) -> float:
        """Accumulated usage decayed since its last update; without a
        half-life this is plain accumulation (the pre-decay behaviour)."""
        usage = self._usage[key]
        if self.usage_halflife and usage:
            now = self._now() if now is None else now
            dt = now - self._usage_t.get(key, now)
            if dt > 0:
                usage *= 0.5 ** (dt / self.usage_halflife)
        return usage

    def _charge_usage(self, key: tuple, amount: float) -> None:
        now = self._now()
        self._usage[key] = self._decayed_usage(key, now) + amount
        self._usage_t[key] = now

    def _publish_snapshot(self) -> None:
        """Coalesced scheduler snapshot: skipped when nothing changed
        since the last publish, and rate-limited to one per
        ``snapshot_interval`` runner-clock seconds when configured."""
        if not self.pools:
            return
        if self._state_rev == self._pub_rev:
            return
        now = self._now()
        if self.snapshot_interval and \
                now - self._pub_t < self.snapshot_interval:
            self.stats["snapshots_skipped"] += 1
            return
        self._pub_rev = self._state_rev
        self._pub_t = now
        self.stats["snapshots"] += 1
        self.bus.publish(TOPIC_SCHEDULER, {
            "now": now,
            "utilization": self.utilization(),
            "pools": sorted(self.pools),
            "queued": sum(self._qlen.values()),
            "held": len(self._held),
            "active": sum(len(a) for a in self._active.values()),
            "preempted": self.stats["preempted"],
        })

    # ------------------------------------------------------------------
    def queue_depth(self, project: str, user: str) -> int:
        with self._lock:
            return self._qlen.get((project, user), 0)

    def active_count(self, project: str, user: str) -> int:
        with self._lock:
            return len(self._active[(project, user)])

    def held_count(self) -> int:
        """Jobs held out of dispatch on unmet declared dependencies."""
        with self._lock:
            return len(self._held)

    def utilization(self) -> dict[str, float]:
        """Per-dimension utilization; in a multi-pool deployment keys are
        namespaced ``"<pool>/<dim>"`` (the single default pool keeps the
        flat legacy keys)."""
        pools = self.pools
        if not pools:
            return {}
        if len(pools) == 1 and "default" in pools:
            return pools["default"].utilization()
        return {f"{pname}/{dim}": u
                for pname in sorted(pools)
                for dim, u in pools[pname].utilization().items()}

    def pool_utilization(self) -> dict[str, dict[str, float]]:
        """{pool: {dim: utilization}} across the deployment."""
        return {pname: cl.utilization() for pname, cl in self.pools.items()}

    def mean_queue_wait(self) -> float:
        n = self.stats["wait_count"]
        return self.stats["wait_sum"] / n if n else 0.0

    # -- quorum / straggler mitigation ----------------------------------
    def run_until_quorum(self, job_ids: list[str], frac: float = 0.95,
                         kill_stragglers: bool = True) -> dict:
        """Advance the virtual runner until ``frac`` of jobs are terminal
        (the paper waits for 95 % of profiling jobs to cope with
        stragglers). Remaining stragglers are optionally killed.
        Only meaningful with a VirtualRunner launcher."""
        need = int(frac * len(job_ids) + 0.999999)
        done = lambda: [j for j in job_ids
                        if self.registry.get(j).state in TERMINAL_STATES]
        while len(done()) < need and self.launcher.pending() > 0:
            self.launcher.step()
        finished = done()
        stragglers = [j for j in job_ids
                      if self.registry.get(j).state not in TERMINAL_STATES]
        if kill_stragglers:
            for j in stragglers:
                self.kill(j)
        return {"finished": finished, "stragglers": stragglers,
                "virtual_time": getattr(self.launcher, "now", None)}

    def run_to_completion(self) -> None:
        """Drain the runner completely (virtual clock or thread pool)."""
        while self.launcher.pending() > 0:
            self.launcher.step()
