"""Cluster-capacity scheduler (ACAI §3.3.1–§3.3.2, scaled to shared
heterogeneous capacity).

The seed engine was a per-(project, user) FIFO with a quota of at most
``quota_k`` jobs in LAUNCHING|RUNNING per tuple. That quota survives, but
admission is now gated on finite capacity *pools* — one ``Cluster`` per
accelerator family, chosen per job by the ``Placement`` layer
(``core/engine/placement.py``): a job launches only when its resource
charge fits some eligible pool, reserved on launch and released on
terminal events. A single ``cluster=`` degenerates to one pool named
"default" (the homogeneous deployment); a job no pool can ever satisfy
fails fast at submit instead of queuing forever. Across queues the
scheduler orders work by

  1. priority      — queue priority + per-job priority, higher first;
  2. fair share    — accumulated dominant-share x runtime per queue,
                     divided by the queue's weight, lower first (DRF-style);
  3. submit order  — FIFO tie-break.

When the head candidate fits none of its pools, EASY backfill lets later
(smaller) jobs launch into the capacity hole as long as they provably do
not delay the blocked job *on its preferred pool*: either they finish
before the blocked job's shadow start time there (computed from that
pool's running jobs' expected completions), or they fit into the capacity
that remains spare on that pool after the blocked job starts. Shadow
state is per pool — a blocked head on the TPU pool never throttles CPU
dispatch, and a flexible job whose best pool is blocked simply takes its
next-ranked pool. With ``policy="fifo"`` the scheduler degrades to a
strict global-submission-order convoy (the benchmark baseline).

Dependency gating (the pipeline SDK's dataflow layer): a job whose
``spec.depends_on`` names unfinished parents is *held* — QUEUED in the
registry but absent from every dispatch queue, so it never enters the
candidate scan, the quota count, or the backfill shadow-time math. Parent
terminal events release it (all parents FINISHED -> enqueued) or cascade
it (any parent FAILED/KILLED -> terminal UPSTREAM_FAILED, published on the
bus so the cascade propagates transitively and handles/monitors wake).

Fair-share usage optionally decays with a configurable half-life
(``usage_halflife``, in runner-clock seconds) so past consumption stops
penalizing a queue forever.

Dispatch is iterative and non-reentrant: runners that publish a terminal
``container_status`` synchronously from inside ``launch`` (instant local
jobs) re-enter the scheduler through the bus; a guard flag folds those
re-entries into the outer dispatch loop instead of recursing, so a fast job
can neither double-launch nor miscount quota/capacity. All entry points
are locked for the ThreadPoolRunner's worker threads.

The paper's 95 % profiling quorum (§4.2.2) stays a first-class
straggler-mitigation policy.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict, deque
from typing import Optional

from repro.core.engine.cluster import Cluster
from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_SCHEDULER)
from repro.core.engine.lifecycle import (TERMINAL_STATES,
                                         TERMINAL_STATUS_VALUES, JobState)
from repro.core.engine.placement import Placement
from repro.core.engine.registry import Job, JobRegistry


class QueueConfig:
    """Per-(project, user) scheduling knobs."""

    def __init__(self, priority: int = 0, weight: float = 1.0):
        self.priority = priority
        self.weight = max(weight, 1e-9)


class Scheduler:
    def __init__(self, registry: JobRegistry, launcher, bus: EventBus,
                 quota_k: int = 2, *, cluster: Optional[Cluster] = None,
                 placement: Optional[Placement] = None,
                 policy: str = "fair", backfill: bool = True,
                 backfill_depth: int = 100,
                 usage_halflife: Optional[float] = None):
        if policy not in ("fair", "fifo"):
            raise ValueError(f"unknown policy {policy!r}")
        if cluster is not None and placement is not None:
            raise ValueError("pass cluster= or placement=, not both")
        self.registry = registry
        self.launcher = launcher
        self.bus = bus
        self.quota_k = quota_k
        self.policy = policy
        self.backfill = backfill and policy == "fair"
        self.backfill_depth = backfill_depth
        self.usage_halflife = usage_halflife
        self._queues: dict[tuple, deque[str]] = defaultdict(deque)
        self._active: dict[tuple, set[str]] = defaultdict(set)
        self._qconf: dict[tuple, QueueConfig] = defaultdict(QueueConfig)
        self._usage: dict[tuple, float] = defaultdict(float)
        self._usage_t: dict[tuple, float] = {}
        # dependency gating: held job -> unmet parent ids, and the reverse
        # index parent -> held children released/cascaded on its terminal
        self._held: dict[str, set[str]] = {}
        self._dependents: dict[str, set[str]] = defaultdict(set)
        self._seq_of: dict[str, int] = {}
        self._seq = 0
        # dispatch-scan caches: priority, eligible pool options and pool
        # ranking per queued job, plus per-pool per-dim lower bounds on any
        # eligible job's charge (monotone min) so a saturated deployment
        # short-circuits the scan entirely.
        self._prio_of: dict[str, int] = {}
        self._opts_of: dict[str, dict] = {}       # job -> {pool: PoolOption}
        self._rank_of: dict[str, list[str]] = {}  # job -> pools best-first
        self._min_charge: dict[str, dict[str, float]] = {}  # pool -> dim min
        self._queued_at: dict[str, float] = {}
        self._started_at: dict[str, float] = {}
        self._lock = threading.RLock()
        self._dispatching = False
        self._dispatch_pending = False
        # running aggregates (not per-job lists): a long-lived platform
        # schedules millions of jobs, so metrics must stay O(queues)
        self.stats = {"launched": 0, "completed": 0, "backfilled": 0,
                      "wait_count": 0, "wait_sum": 0.0,
                      "wait_by_key": defaultdict(lambda: [0, 0.0]),
                      "placed_by_pool": defaultdict(int)}
        self.placement: Optional[Placement] = None
        if placement is not None:
            self.placement = placement
        elif cluster is not None:
            self.placement = Placement({cluster.name or "default": cluster})
        bus.subscribe(TOPIC_CONTAINER_STATUS, self._on_container_status)

    # -- pools ----------------------------------------------------------
    @property
    def pools(self) -> dict[str, Cluster]:
        return self.placement.pools if self.placement is not None else {}

    @property
    def cluster(self) -> Optional[Cluster]:
        """The sole pool's cluster in a homogeneous deployment (legacy
        single-cluster callers); None when capacity-unconstrained or
        genuinely multi-pool."""
        pools = self.pools
        if len(pools) == 1:
            return next(iter(pools.values()))
        return None

    @cluster.setter
    def cluster(self, cl: Optional[Cluster]) -> None:
        with self._lock:
            self.placement = None if cl is None else \
                Placement({cl.name or "default": cl})
            # the pool set changed: every cached eligibility/ranking is
            # stale (they name pools that may no longer exist) — drop
            # them; _ensure_opts re-derives lazily per job
            self._min_charge = {}
            self._opts_of = {}
            self._rank_of = {}

    # ------------------------------------------------------------------
    def _now(self) -> float:
        now = getattr(self.launcher, "now", None)
        return now if now is not None else time.time()

    def configure_queue(self, project: str, user: str, *,
                        priority: int = 0, weight: float = 1.0) -> None:
        with self._lock:
            self._qconf[(project, user)] = QueueConfig(priority, weight)

    # ------------------------------------------------------------------
    def submit(self, job: Job) -> None:
        with self._lock:
            # resolve (and validate) dependencies before any state change:
            # an unknown parent id must not leave a zombie QUEUED job
            unmet, failed_parent = self._resolve_deps(job)
            self.registry.set_state(job.job_id, JobState.QUEUED)
            self._seq += 1
            self._seq_of[job.job_id] = self._seq
            self._prio_of[job.job_id] = job.spec.priority
            self._queued_at[job.job_id] = self._now()
            if failed_parent is not None:
                self._upstream_fail(job.job_id, failed_parent)
                return
            if self.placement is not None:
                options = self.placement.eligible(job.spec)
                if not options:
                    # no pool can ever fit it: fail fast, don't queue forever
                    self._fail_infeasible(job)
                    return
                self._opts_of[job.job_id] = options
                for pname, opt in options.items():
                    mc = self._min_charge.setdefault(pname, {})
                    for n, amt in opt.charge.items():
                        mc[n] = min(mc.get(n, amt), amt)
            if unmet:
                # held: not in any queue, so invisible to the candidate
                # scan, the quota count and the backfill shadow-time math
                self._held[job.job_id] = unmet
                for pid in unmet:
                    self._dependents[pid].add(job.job_id)
            else:
                self._enqueue(job)
            self._dispatch()

    def _ensure_opts(self, job: Job) -> dict:
        """The job's cached pool options, re-deriving (and re-ranking)
        them when the pool set changed since submit (legacy ``cluster=``
        reassignment drops the caches). Empty => nothing fits anymore."""
        opts = self._opts_of.get(job.job_id)
        if opts is None:
            opts = self.placement.eligible(job.spec)
            if opts:
                self._opts_of[job.job_id] = opts
                for pname, opt in opts.items():
                    mc = self._min_charge.setdefault(pname, {})
                    for n, amt in opt.charge.items():
                        mc[n] = min(mc.get(n, amt), amt)
                self._rank_of[job.job_id] = self.placement.rank(
                    job.spec, opts, parent_pools=self._parent_pools(job))
        return opts

    def _enqueue(self, job: Job) -> None:
        """Queue a dispatchable job, ranking its eligible pools now — all
        parents are terminal at this point, so dataflow locality (the
        pools holding the parents' output filesets) is known."""
        if self.placement is not None:
            opts = self._ensure_opts(job)
            if not opts:
                self._fail_infeasible(job)
                return              # became infeasible (pool set changed)
            self._rank_of[job.job_id] = self.placement.rank(
                job.spec, opts, parent_pools=self._parent_pools(job))
        self._queues[job.queue_key].append(job.job_id)

    def _parent_pools(self, job: Job) -> set[str]:
        pools = set()
        for pid in job.spec.depends_on or ():
            try:
                parent = self.registry.get(pid)
            except KeyError:
                continue
            if parent.pool:
                pools.add(parent.pool)
        return pools

    def _resolve_deps(self, job: Job) -> tuple[set[str], Optional[str]]:
        """(unmet parent ids, first already-failed parent or None)."""
        unmet: set[str] = set()
        for pid in dict.fromkeys(job.spec.depends_on or ()):
            try:
                parent = self.registry.get(pid)
            except KeyError:
                raise ValueError(
                    f"{job.job_id} depends on unknown job {pid!r}") from None
            if parent.state == JobState.FINISHED:
                continue
            if parent.state in TERMINAL_STATES:
                return set(), pid
            unmet.add(pid)
        return unmet, None

    def kill(self, job_id: str) -> None:
        with self._lock:
            job = self.registry.get(job_id)
            if job.state in TERMINAL_STATES:
                return
            key = job.queue_key
            launched = job_id in self._started_at
            if job_id in self._queues[key]:
                self._queues[key].remove(job_id)
            self._unhold(job_id)
            self._active[key].discard(job_id)
            self.registry.set_state(job_id, JobState.KILLED)
            if launched:
                # the runner publishes the terminal event when the job
                # actually stops (virtual-clock pop / worker finalize);
                # settle capacity now so the slot frees immediately
                self._settle(job_id, key)
                self._dispatch()
            else:
                # never reached the runner: publish the terminal event
                # ourselves so handles, monitors and held dependents
                # observe the kill (the handler settles + dispatches)
                self.registry.persist_state(job_id)
                self.bus.publish(TOPIC_CONTAINER_STATUS,
                                 {"job_id": job_id, "status": "KILLED"})

    def _unhold(self, job_id: str) -> None:
        """Drop a held job's gating state: O(its parents), using the unmet
        set as the exact index into _dependents."""
        unmet = self._held.pop(job_id, None)
        for pid in unmet or ():
            deps = self._dependents.get(pid)
            if deps is not None:
                deps.discard(job_id)

    def _upstream_fail(self, job_id: str, parent_id: str) -> None:
        """Cascade-cancel a never-launched job whose parent did not
        finish; the published event propagates the cascade transitively."""
        self.registry.set_state(
            job_id, JobState.UPSTREAM_FAILED,
            error=f"upstream job {parent_id} did not finish")
        self.registry.persist_state(job_id)
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job_id, "status": "UPSTREAM_FAILED",
                          "upstream": parent_id})

    def _release_dependents(self, parent_id: str, status: str) -> None:
        """On a parent's terminal event: enqueue held children whose last
        parent FINISHED, cascade UPSTREAM_FAILED children otherwise."""
        children = self._dependents.pop(parent_id, None)
        if not children:
            return
        for cid in sorted(children):
            unmet = self._held.get(cid)
            if unmet is None:
                continue
            if status == JobState.FINISHED.value:
                unmet.discard(parent_id)
                if not unmet:
                    del self._held[cid]
                    child = self.registry.get(cid)
                    # queue wait starts at eligibility, not submit: the
                    # parent-hold time is dataflow latency, not queueing
                    self._queued_at[cid] = self._now()
                    self._enqueue(child)
            else:
                unmet.discard(parent_id)
                self._unhold(cid)
                self._upstream_fail(cid, parent_id)

    # -- dispatch (non-reentrant) ---------------------------------------
    def _maybe_launch(self, key: Optional[tuple] = None) -> None:
        """Back-compat alias for the dispatch loop."""
        with self._lock:
            self._dispatch()

    def _dispatch(self) -> None:
        if self._dispatching:
            # re-entered from a terminal event published inside launch();
            # fold into the outer loop instead of recursing.
            self._dispatch_pending = True
            return
        self._dispatching = True
        try:
            progress = True
            while progress or self._dispatch_pending:
                self._dispatch_pending = False
                progress = self._dispatch_once()
        finally:
            self._dispatching = False
        self._publish_snapshot()

    def _candidates(self) -> list[str]:
        """Queue-head slices ordered by (priority, fair share, FIFO)."""
        out = []
        for key, q in self._queues.items():
            if not q:
                continue
            headroom = self.quota_k - len(self._active[key])
            if headroom <= 0:
                continue
            depth = min(len(q), max(headroom, 0)
                        + (self.backfill_depth if self.backfill else 0))
            slice_ = list(q)[:depth]
            conf = self._qconf[key]
            share = self._decayed_usage(key) / conf.weight
            for jid in slice_:
                prio = conf.priority + self._prio_of.get(jid, 0)
                out.append((key, jid, prio, share))
        if self.policy == "fifo":
            out.sort(key=lambda c: self._seq_of[c[1]])
        else:
            out.sort(key=lambda c: (-c[2], c[3], self._seq_of[c[1]]))
        return [(key, jid) for key, jid, _, _ in out]

    def _saturated(self) -> bool:
        """No queued job can possibly fit anywhere: on every pool some
        dimension's free capacity is below the smallest charge any of that
        pool's eligible jobs carries."""
        if not self._min_charge:
            return False
        for pname, cl in self.pools.items():
            mc = self._min_charge.get(pname)
            if not mc:
                continue        # no job was ever eligible on this pool
            free = cl.free()
            if not any(free.get(n, 0.0) + 1e-9 < amt
                       for n, amt in mc.items()):
                return False    # this pool can still admit its smallest job
        return True

    def _dispatch_once(self) -> bool:
        if self._saturated():
            return False
        launched = False
        # EASY shadow state is per pool: pool -> [blocked_req, shadow,
        # spare]; a blocked head throttles only its own preferred pool
        blocked: dict[str, list] = {}
        quota_used: dict[tuple, int] = {}
        for key, job_id in self._candidates():
            if job_id not in self._queues[key]:
                continue        # launched/killed by a nested event
            used = quota_used.get(key, len(self._active[key]))
            if used >= self.quota_k:
                continue
            job = self.registry.get(job_id)
            chosen = None
            backfilled = False
            if self.placement is not None:
                opts = self._ensure_opts(job)
                if not opts:
                    # pool set changed under a queued job, nothing fits
                    self._queues[key].remove(job_id)
                    self._fail_infeasible(job)
                    continue
                for pname in self._rank_of.get(job_id, ()):
                    opt = opts[pname]
                    if not self.pools[pname].fits_charge(opt.charge):
                        continue
                    blk = blocked.get(pname)
                    if blk is not None:
                        ok, via_spare = self._can_backfill(
                            job, pname, opt.charge, blk[1], blk[2])
                        if not ok:
                            continue
                        if via_spare:
                            # this job may still be running at the shadow
                            # time: consume its share of the spare so later
                            # backfill candidates cannot collectively delay
                            # the blocked job
                            for n, amt in opt.charge.items():
                                blk[2][n] = blk[2].get(n, 0.0) - amt
                        backfilled = True
                    chosen = pname
                    break
                if chosen is None:
                    # fits no pool right now: reserve a shadow start on
                    # its best-ranked pool (where placement wants it)
                    top = self._rank_of[job_id][0]
                    if top not in blocked:
                        shadow, spare = self._shadow_time(
                            top, opts[top].charge)
                        blocked[top] = [opts[top].charge, shadow, spare]
                    if not self.backfill:
                        break   # convoy: strict order blocks the rest
                    continue
                if backfilled:
                    self.stats["backfilled"] += 1
            self._launch(key, job, chosen)
            quota_used[key] = used + 1
            launched = True
            if self._saturated():
                break
        return launched

    def _launch(self, key: tuple, job: Job,
                pool: Optional[str] = None) -> None:
        self._queues[key].remove(job.job_id)
        self._active[key].add(job.job_id)
        if pool is not None:
            opt = self._opts_of[job.job_id][pool]
            self.pools[pool].reserve(job.job_id, opt.resources)
            job.pool = pool
            # pin the concrete shape the job got (a per-pool menu entry),
            # so runner billing and observers see what was allocated
            job.spec.resources = dict(opt.resources)
            self.stats["placed_by_pool"][pool] += 1
        now = self._now()
        self._started_at[job.job_id] = now
        wait = now - self._queued_at.pop(job.job_id, now)
        self.stats["launched"] += 1
        self.stats["wait_count"] += 1
        self.stats["wait_sum"] += wait
        by_key = self.stats["wait_by_key"][key]
        by_key[0] += 1
        by_key[1] += wait
        self.registry.set_state(job.job_id, JobState.LAUNCHING)
        self.launcher.launch(job)

    def _fail_infeasible(self, job: Job) -> None:
        err = (f"resources {job.spec.pool_resources or job.spec.resources} "
               f"exceed cluster capacity on every pool "
               f"({self.placement.explain_infeasible(job.spec)})")
        self.registry.set_state(job.job_id, JobState.LAUNCHING)
        self.registry.set_state(job.job_id, JobState.FAILED, error=err)
        self.registry.persist_state(job.job_id)
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job.job_id, "status": "FAILED"})

    # -- EASY backfill ---------------------------------------------------
    def _shadow_time(self, pool: str,
                     blocked_req: dict) -> tuple[Optional[float],
                                                 Optional[dict]]:
        """Earliest time the blocked job fits on ``pool`` (shadow start)
        and the capacity left spare there at that instant after it starts.
        Requires the launcher to expose expected completion times;
        otherwise backfill stays conservative (disabled for this round)."""
        cl = self.pools.get(pool)
        if cl is None or not hasattr(self.launcher, "expected_end"):
            return None, None
        ends = []
        for jid, res in cl.reservations().items():
            end = self.launcher.expected_end(jid)
            if end is None:
                return None, None
            ends.append((end, res))
        ends.sort(key=lambda e: e[0])
        free = cl.free()
        for end, res in ends:
            for n, amt in res.items():
                if n in free:
                    free[n] += amt
            if all(free.get(n, 0.0) >= blocked_req[n] - 1e-9
                   for n in blocked_req):
                spare = {n: free.get(n, 0.0) - blocked_req[n]
                         for n in blocked_req}
                return end, spare
        return None, None

    def _can_backfill(self, job: Job, pool: str, charge: dict,
                      shadow: Optional[float],
                      spare: Optional[dict]) -> tuple[bool, bool]:
        """(admit, via_spare): admit if the job provably cannot delay the
        blocked head on ``pool`` — it ends before the shadow start, or it
        fits into the capacity still spare once the head starts
        (``via_spare``). The duration estimate is for THIS pool: a job
        that is quick on CPU but pays a TPU startup tax must be sized at
        its TPU runtime when backfilling the TPU pool's hole."""
        if shadow is None:
            return False, False
        dur = None
        if hasattr(self.launcher, "expected_duration"):
            try:
                dur = self.launcher.expected_duration(job, pool=pool)
            except TypeError:   # legacy runner without the pool kwarg
                dur = self.launcher.expected_duration(job)
        if dur is not None and self._now() + dur <= shadow + 1e-9:
            return True, False  # finishes before the blocked job starts
        return all(amt <= spare.get(n, 0.0) + 1e-9
                   for n, amt in charge.items()), True

    # -- terminal events -------------------------------------------------
    def _on_container_status(self, msg: dict) -> None:
        status = msg.get("status", "")
        if status not in TERMINAL_STATUS_VALUES:
            return
        with self._lock:
            job_id = msg["job_id"]
            job = self.registry.get(job_id)
            key = job.queue_key
            self._active[key].discard(job_id)
            self._release_dependents(job_id, status)
            self._settle(job_id, key)
            self._dispatch()

    def _settle(self, job_id: str, key: tuple) -> None:
        """Release capacity on the job's pool, free per-job bookkeeping,
        and charge fair-share usage. Idempotent (a killed virtual job
        later pops off the clock and publishes KILLED again), and
        usage/completed only accrue for jobs that actually launched."""
        job = self.registry.get(job_id)
        pool_cl = self.pools.get(job.pool) if job.pool else None
        released = pool_cl.release(job_id) if pool_cl is not None else None
        started_at = self._started_at.pop(job_id, None)
        self._prio_of.pop(job_id, None)
        self._opts_of.pop(job_id, None)
        self._rank_of.pop(job_id, None)
        self._seq_of.pop(job_id, None)
        self._queued_at.pop(job_id, None)
        if started_at is None:
            return          # never launched (queued kill / infeasible)
        runtime = job.runtime
        if runtime is None:
            runtime = max(0.0, self._now() - started_at)
        # fair-share usage is the dominant share on the pool the job ran
        # on: consuming half the TPU pool weighs like half the CPU pool
        share = pool_cl.dominant_share(released or job.spec.resources) \
            if pool_cl is not None else 1.0
        self._charge_usage(key, (share if share > 0 else 1.0) * runtime)
        self.stats["completed"] += 1

    # -- fair-share usage with half-life decay ---------------------------
    def _decayed_usage(self, key: tuple,
                       now: Optional[float] = None) -> float:
        """Accumulated usage decayed since its last update; without a
        half-life this is plain accumulation (the pre-decay behaviour)."""
        usage = self._usage[key]
        if self.usage_halflife and usage:
            now = self._now() if now is None else now
            dt = now - self._usage_t.get(key, now)
            if dt > 0:
                usage *= 0.5 ** (dt / self.usage_halflife)
        return usage

    def _charge_usage(self, key: tuple, amount: float) -> None:
        now = self._now()
        self._usage[key] = self._decayed_usage(key, now) + amount
        self._usage_t[key] = now

    def _publish_snapshot(self) -> None:
        if not self.pools:
            return
        self.bus.publish(TOPIC_SCHEDULER, {
            "now": self._now(),
            "utilization": self.utilization(),
            "pools": sorted(self.pools),
            "queued": sum(len(q) for q in self._queues.values()),
            "held": len(self._held),
            "active": sum(len(a) for a in self._active.values()),
        })

    # ------------------------------------------------------------------
    def queue_depth(self, project: str, user: str) -> int:
        with self._lock:
            return len(self._queues[(project, user)])

    def active_count(self, project: str, user: str) -> int:
        with self._lock:
            return len(self._active[(project, user)])

    def held_count(self) -> int:
        """Jobs held out of dispatch on unmet declared dependencies."""
        with self._lock:
            return len(self._held)

    def utilization(self) -> dict[str, float]:
        """Per-dimension utilization; in a multi-pool deployment keys are
        namespaced ``"<pool>/<dim>"`` (the single default pool keeps the
        flat legacy keys)."""
        pools = self.pools
        if not pools:
            return {}
        if len(pools) == 1 and "default" in pools:
            return pools["default"].utilization()
        return {f"{pname}/{dim}": u
                for pname in sorted(pools)
                for dim, u in pools[pname].utilization().items()}

    def pool_utilization(self) -> dict[str, dict[str, float]]:
        """{pool: {dim: utilization}} across the deployment."""
        return {pname: cl.utilization() for pname, cl in self.pools.items()}

    def mean_queue_wait(self) -> float:
        n = self.stats["wait_count"]
        return self.stats["wait_sum"] / n if n else 0.0

    # -- quorum / straggler mitigation ----------------------------------
    def run_until_quorum(self, job_ids: list[str], frac: float = 0.95,
                         kill_stragglers: bool = True) -> dict:
        """Advance the virtual runner until ``frac`` of jobs are terminal
        (the paper waits for 95 % of profiling jobs to cope with
        stragglers). Remaining stragglers are optionally killed.
        Only meaningful with a VirtualRunner launcher."""
        need = int(frac * len(job_ids) + 0.999999)
        done = lambda: [j for j in job_ids
                        if self.registry.get(j).state in TERMINAL_STATES]
        while len(done()) < need and self.launcher.pending() > 0:
            self.launcher.step()
        finished = done()
        stragglers = [j for j in job_ids
                      if self.registry.get(j).state not in TERMINAL_STATES]
        if kill_stragglers:
            for j in stragglers:
                self.kill(j)
        return {"finished": finished, "stragglers": stragglers,
                "virtual_time": getattr(self.launcher, "now", None)}

    def run_to_completion(self) -> None:
        """Drain the runner completely (virtual clock or thread pool)."""
        while self.launcher.pending() > 0:
            self.launcher.step()
