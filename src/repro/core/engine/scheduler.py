"""Job scheduler (ACAI §3.3.1): per-(project, user) FIFO queues with a quota
of at most k jobs in LAUNCHING|RUNNING per tuple, plus the paper's 95 %
profiling quorum as a first-class straggler-mitigation policy (§4.2.2).
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import Optional

from repro.core.engine.events import EventBus, TOPIC_CONTAINER_STATUS
from repro.core.engine.lifecycle import (ACTIVE_STATES, TERMINAL_STATES,
                                         JobState)
from repro.core.engine.registry import Job, JobRegistry


class Scheduler:
    def __init__(self, registry: JobRegistry, launcher, bus: EventBus,
                 quota_k: int = 2):
        self.registry = registry
        self.launcher = launcher
        self.bus = bus
        self.quota_k = quota_k
        self._queues: dict[tuple, deque[str]] = defaultdict(deque)
        self._active: dict[tuple, set[str]] = defaultdict(set)
        bus.subscribe(TOPIC_CONTAINER_STATUS, self._on_container_status)

    # ------------------------------------------------------------------
    def submit(self, job: Job) -> None:
        self.registry.set_state(job.job_id, JobState.QUEUED)
        self._queues[job.queue_key].append(job.job_id)
        self._maybe_launch(job.queue_key)

    def kill(self, job_id: str) -> None:
        job = self.registry.get(job_id)
        if job.state in TERMINAL_STATES:
            return
        key = job.queue_key
        if job_id in self._queues[key]:
            self._queues[key].remove(job_id)
        self._active[key].discard(job_id)
        self.registry.set_state(job_id, JobState.KILLED)
        self._maybe_launch(key)

    # ------------------------------------------------------------------
    def _maybe_launch(self, key: tuple) -> None:
        q = self._queues[key]
        while q and len(self._active[key]) < self.quota_k:
            job_id = q.popleft()
            job = self.registry.get(job_id)
            self._active[key].add(job_id)
            self.registry.set_state(job_id, JobState.LAUNCHING)
            self.launcher.launch(job)

    def _on_container_status(self, msg: dict) -> None:
        status = msg.get("status", "")
        if status in {s.value for s in TERMINAL_STATES}:
            job = self.registry.get(msg["job_id"])
            key = job.queue_key
            if msg["job_id"] in self._active[key]:
                self._active[key].discard(msg["job_id"])
                self._maybe_launch(key)

    # ------------------------------------------------------------------
    def queue_depth(self, project: str, user: str) -> int:
        return len(self._queues[(project, user)])

    def active_count(self, project: str, user: str) -> int:
        return len(self._active[(project, user)])

    # -- quorum / straggler mitigation ----------------------------------
    def run_until_quorum(self, job_ids: list[str], frac: float = 0.95,
                         kill_stragglers: bool = True) -> dict:
        """Advance the virtual runner until ``frac`` of jobs are terminal
        (the paper waits for 95 % of profiling jobs to cope with
        stragglers). Remaining stragglers are optionally killed.
        Only meaningful with a VirtualRunner launcher."""
        need = int(frac * len(job_ids) + 0.999999)
        done = lambda: [j for j in job_ids
                        if self.registry.get(j).state in TERMINAL_STATES]
        while len(done()) < need and self.launcher.pending() > 0:
            self.launcher.step()
        finished = done()
        stragglers = [j for j in job_ids
                      if self.registry.get(j).state not in TERMINAL_STATES]
        if kill_stragglers:
            for j in stragglers:
                self.kill(j)
        return {"finished": finished, "stragglers": stragglers,
                "virtual_time": getattr(self.launcher, "now", None)}

    def run_to_completion(self) -> None:
        """Drain the virtual runner completely."""
        while self.launcher.pending() > 0:
            self.launcher.step()
