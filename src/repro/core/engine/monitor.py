"""Job monitor + log server (ACAI §4.2): subscribes to all bus topics,
keeps per-job latest status, progress stage and log tail; the dashboard's
WebSocket feed becomes the ``watch`` API. With the capacity scheduler it
also records cluster-utilization snapshots (``scheduler_metrics`` topic),
so queue pressure and capacity holes are observable over (virtual) time."""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Optional

from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_JOB_PROGRESS, TOPIC_SCHEDULER)
from repro.core.engine.lifecycle import TERMINAL_STATUS_VALUES as \
    _TERMINAL_STATUS


class JobMonitor:
    def __init__(self, bus: EventBus, *, registry=None,
                 max_samples: int = 10_000):
        # with a registry attached, terminal checks fall back to the
        # job's registry state — a job that went terminal before this
        # monitor subscribed (recovered engine, cross-process handle)
        # still resolves instead of hanging its waiters
        self.registry = registry
        self.status: dict[str, str] = {}  # guarded-by: _lock
        self.stage: dict[str, str] = {}  # guarded-by: _lock
        self.events: dict[str, list[dict]] = defaultdict(list)  # guarded-by: _lock
        self.cluster_samples: list[dict] = []  # guarded-by: _lock
        self.max_samples = max_samples
        # running aggregates at ingest: the sample buffer is trimmed, so
        # peak/mean must not be recomputed from it. samples_seen counts
        # every snapshot ever received (the scheduler coalesces them
        # behind a change gate + snapshot_interval, so cadence is a
        # deployment knob worth observing), and last_sample_at is the
        # runner-clock time of the freshest one
        self._peak: dict[str, float] = {}  # guarded-by: _lock
        self._util_sum: dict[str, float] = defaultdict(float)  # guarded-by: _lock
        self._util_n = 0  # guarded-by: _lock
        self.samples_seen = 0  # guarded-by: _lock
        self.last_sample_at: Optional[float] = None  # guarded-by: _lock
        # handlers run on whichever thread publishes (worker finalize,
        # virtual-clock step, scheduler snapshot), so every mutable map
        # and aggregate above is guarded; never publish from under it —
        # the bus is synchronous and would re-enter the handlers
        self._lock = threading.RLock()  # acailint: lock(forbid: publish)
        # JobHandle.wait blocks on this instead of polling: any terminal
        # container_status wakes every waiter, each re-checks its own
        # job. Lock order: _lock may be taken under the cv (the wait
        # predicate), so notifiers must NEVER hold _lock when taking the
        # cv — release first, then notify
        self._terminal_cv = threading.Condition()
        bus.subscribe(TOPIC_CONTAINER_STATUS, self._on_status)
        bus.subscribe(TOPIC_JOB_PROGRESS, self._on_progress)
        bus.subscribe(TOPIC_SCHEDULER, self._on_scheduler)

    def _on_status(self, msg: dict) -> None:
        status = msg.get("status", "")
        terminal = status in _TERMINAL_STATUS
        with self._lock:
            if terminal and self.registry is not None:
                # handlers run in subscription order: the scheduler
                # (first) may have already retried this FAILED
                # incarnation — the registry epoch moved past the
                # message's, so caching the terminal here would wake
                # waiters on a job that is alive again. Keep the event
                # for watch(), drop the status.
                try:
                    job = self.registry.get(msg["job_id"])
                except KeyError:
                    job = None
                if job is not None and \
                        int(msg.get("epoch", job.epoch)) < job.epoch:
                    self.events[msg["job_id"]].append(msg)
                    return
                if job is not None:
                    # accepted terminal: the retry decision (if any) is
                    # made — backstop for engines with no scheduler
                    # subscribed
                    job.retry_pending = False
            self.status[msg["job_id"]] = status
            self.events[msg["job_id"]].append(msg)
        # notify with _lock released: the wait predicate takes _lock
        # under the cv, so notifying while holding _lock would deadlock
        if terminal:
            with self._terminal_cv:
                self._terminal_cv.notify_all()

    def record_status(self, job_id: str, status: str,
                      overwrite: bool = True) -> None:
        """Seed the cached status map directly (crash recovery replays
        terminal outcomes before any bus traffic exists). With
        ``overwrite=False`` an already-cached status wins — the replay
        of older records must not clobber a fresher worker result."""
        with self._lock:
            if overwrite:
                self.status[job_id] = status
            else:
                self.status.setdefault(job_id, status)

    def is_terminal(self, job_id: str) -> bool:
        with self._lock:
            if self.status.get(job_id, "") in _TERMINAL_STATUS:
                return True
        if self.registry is not None:
            try:
                job = self.registry.get(job_id)
            except KeyError:
                return False
            state = job.state.value
            if state in _TERMINAL_STATUS and not job.retry_pending:
                # cache it so the wait predicate stays cheap and watch()
                # consumers see a consistent status map
                with self._lock:
                    self.status.setdefault(job_id, state)
                return True
        return False

    def wait_terminal(self, job_id: str,
                      timeout: Optional[float] = None) -> bool:
        """Block until ``job_id`` publishes a terminal container_status
        (True) or the timeout elapses (False). Event-driven: used by
        JobHandle.wait for runners that complete on worker threads."""
        with self._terminal_cv:
            return self._terminal_cv.wait_for(
                lambda: self.is_terminal(job_id), timeout)

    def _on_progress(self, msg: dict) -> None:
        with self._lock:
            self.stage[msg["job_id"]] = msg.get("stage", "")
            self.events[msg["job_id"]].append(msg)

    def _on_scheduler(self, msg: dict) -> None:
        with self._lock:
            self.cluster_samples.append(msg)
            self.samples_seen += 1
            self.last_sample_at = msg.get("now", self.last_sample_at)
            util = msg.get("utilization", {})
            if util:
                self._util_n += 1
                for dim, u in util.items():
                    self._peak[dim] = max(self._peak.get(dim, 0.0), u)
                    self._util_sum[dim] += u
            if len(self.cluster_samples) > self.max_samples:
                del self.cluster_samples[:len(self.cluster_samples) // 2]

    def watch(self, job_id: str) -> list[dict]:
        with self._lock:
            return list(self.events[job_id])

    # -- utilization over (virtual) time --------------------------------
    def peak_utilization(self) -> dict[str, float]:
        with self._lock:
            return dict(self._peak)

    def mean_utilization(self) -> dict[str, float]:
        with self._lock:
            if not self._util_n:
                return {}
            return {d: v / self._util_n
                    for d, v in self._util_sum.items()}

    def utilization_summary(self) -> tuple[bool, dict[str, float],
                                           dict[str, float]]:
        """``(has samples, peak, mean)`` in one lock hold, so both
        aggregates come from the same ingest point — the dashboard must
        not interleave its reads with a concurrent ``_on_scheduler``."""
        with self._lock:
            has = bool(self.cluster_samples)
            peak = dict(self._peak)
            mean = {} if not self._util_n else \
                {d: v / self._util_n for d, v in self._util_sum.items()}
        return has, peak, mean

    def utilization_by_pool(self) -> dict[str, dict[str, dict[str, float]]]:
        """``{pool: {dim: {"mean": m, "peak": p}}}`` — multi-pool
        snapshots namespace utilization keys as ``"<pool>/<dim>"``; flat
        keys (single default pool) land under ``"default"``."""
        with self._lock:
            mean = {} if not self._util_n else \
                {d: v / self._util_n for d, v in self._util_sum.items()}
            out: dict[str, dict[str, dict[str, float]]] = {}
            for key, peak in self._peak.items():
                pool, _, dim = key.rpartition("/")
                out.setdefault(pool or "default", {})[dim or key] = {
                    "mean": mean.get(key, 0.0), "peak": peak}
            return out
