"""Job monitor + log server (ACAI §4.2): subscribes to both bus topics,
keeps per-job latest status, progress stage and log tail; the dashboard's
WebSocket feed becomes the ``watch`` API."""
from __future__ import annotations

from collections import defaultdict

from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_JOB_PROGRESS)


class JobMonitor:
    def __init__(self, bus: EventBus):
        self.status: dict[str, str] = {}
        self.stage: dict[str, str] = {}
        self.events: dict[str, list[dict]] = defaultdict(list)
        bus.subscribe(TOPIC_CONTAINER_STATUS, self._on_status)
        bus.subscribe(TOPIC_JOB_PROGRESS, self._on_progress)

    def _on_status(self, msg: dict) -> None:
        self.status[msg["job_id"]] = msg.get("status", "")
        self.events[msg["job_id"]].append(msg)

    def _on_progress(self, msg: dict) -> None:
        self.stage[msg["job_id"]] = msg.get("stage", "")
        self.events[msg["job_id"]].append(msg)

    def watch(self, job_id: str) -> list[dict]:
        return list(self.events[job_id])
