from repro.core.acai import AcaiEngine, AcaiPlatform, AcaiProject
