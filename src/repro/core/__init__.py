from repro.core.acai import AcaiEngine, AcaiPlatform, AcaiProject
from repro.core.engine.handle import (JobFailedError, JobHandle,
                                      UpstreamFailedError, wait_all)
from repro.core.engine.pipeline import Pipeline, Stage
from repro.core.engine.registry import JobSpec

__all__ = ["AcaiEngine", "AcaiPlatform", "AcaiProject", "JobFailedError",
           "JobHandle", "UpstreamFailedError", "wait_all", "Pipeline",
           "Stage", "JobSpec"]
