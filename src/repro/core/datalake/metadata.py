"""Metadata store (ACAI §3.2.3, §4.5.1).

Key-value attributes on files, file sets and jobs, with the paper's query
surface: equality match, range queries (e.g. time ranges, `precision>0.5`),
and max/min queries. The paper hosts this on MongoDB with per-key indexes;
we keep an in-process document store with the same behaviour — per-key
inverted/sorted indexes, JSON persistence, predefined indexed keys that
users may update (e.g. every job has ``training_loss``).
"""
from __future__ import annotations

import bisect
import json
import threading
import time
from pathlib import Path
from typing import Any, Iterable, Optional

PREDEFINED_KEYS = ("creator", "create_time", "kind", "training_loss",
                   "precision", "model")


class MetadataStore:
    def __init__(self, root: str | Path):
        Path(root).mkdir(parents=True, exist_ok=True)
        self._path = Path(root) / "metadata.json"
        # job agents on ThreadPoolRunner workers put() concurrently
        self._lock = threading.RLock()
        self._docs: dict[str, dict[str, Any]] = {}
        # key -> sorted [(value, artifact_id)]
        self._index: dict[str, list[tuple[Any, str]]] = {}
        if self._path.exists():
            self._docs = json.loads(self._path.read_text())
            for aid, doc in self._docs.items():
                for k, v in doc.items():
                    self._index_add(k, v, aid)

    def _save(self) -> None:
        self._path.write_text(json.dumps(self._docs))

    # ------------------------------------------------------------------
    def _index_add(self, key: str, value: Any, aid: str) -> None:
        if value is None:
            return
        idx = self._index.setdefault(key, [])
        bisect.insort(idx, (value, aid))

    def _index_remove(self, key: str, value: Any, aid: str) -> None:
        idx = self._index.get(key, [])
        i = bisect.bisect_left(idx, (value, aid))
        if i < len(idx) and idx[i] == (value, aid):
            idx.pop(i)

    # ------------------------------------------------------------------
    def register(self, artifact_id: str, kind: str, **attrs: Any) -> None:
        """Called at file upload / fileset creation / job completion."""
        doc = {k: None for k in PREDEFINED_KEYS}
        doc.update({"kind": kind, "create_time": time.time()})
        doc.update(attrs)
        self.put(artifact_id, **doc)

    def put(self, artifact_id: str, **attrs: Any) -> None:
        with self._lock:
            doc = self._docs.setdefault(artifact_id, {})
            for k, v in attrs.items():
                if k in doc and doc[k] is not None:
                    self._index_remove(k, doc[k], artifact_id)
                doc[k] = v
                self._index_add(k, v, artifact_id)
            self._save()

    def tag(self, artifact_id: str, tag: str) -> None:
        with self._lock:
            doc = self._docs.setdefault(artifact_id, {})
            tags = doc.setdefault("tags", [])
            if tag not in tags:
                tags.append(tag)
            self._save()

    def get(self, artifact_id: str) -> dict[str, Any]:
        return dict(self._docs.get(artifact_id, {}))

    # -- queries ---------------------------------------------------------
    def find(self, *, tags: Optional[Iterable[str]] = None,
             **conditions: Any) -> list[str]:
        """Equality + range query.

        Conditions: ``key=value`` (equality), ``key=("range", lo, hi)``,
        ``key=(">", x)``, ``key=("<", x)``. Returns matching artifact ids.
        """
        result: Optional[set[str]] = None
        for key, cond in conditions.items():
            idx = self._index.get(key, [])
            if isinstance(cond, tuple):
                op = cond[0]
                if op == "range":
                    lo, hi = cond[1], cond[2]
                elif op == ">":
                    lo, hi = cond[1], float("inf")
                elif op == "<":
                    lo, hi = float("-inf"), cond[1]
                else:
                    raise ValueError(f"bad condition {cond}")
                i = bisect.bisect_right(idx, (lo, "￿"))
                j = bisect.bisect_left(idx, (hi, ""))
                hits = {aid for _, aid in idx[i:j]}
            else:
                i = bisect.bisect_left(idx, (cond, ""))
                j = bisect.bisect_right(idx, (cond, "￿"))
                hits = {aid for _, aid in idx[i:j]}
            result = hits if result is None else (result & hits)
        if tags:
            tagged = {aid for aid, doc in self._docs.items()
                      if set(tags) <= set(doc.get("tags", []))}
            result = tagged if result is None else (result & tagged)
        if result is None:
            result = set(self._docs)
        return sorted(result)

    def find_max(self, key: str, **conditions: Any) -> Optional[str]:
        ids = set(self.find(**conditions))
        idx = self._index.get(key, [])
        for _value, aid in reversed(idx):
            if aid in ids:
                return aid
        return None

    def find_min(self, key: str, **conditions: Any) -> Optional[str]:
        ids = set(self.find(**conditions))
        for _value, aid in self._index.get(key, []):
            if aid in ids:
                return aid
        return None
