"""Inter-job data caching (ACAI §7.1.2 — paper future work, implemented).

Every job normally starts by downloading its input fileset; when
consecutive jobs consume the same fileset VERSION, the materialized files
can be reused. The cache is keyed on the resolved fileset ref
(name:version — immutable by construction, so reuse is always safe), with
LRU eviction on a byte budget."""
from __future__ import annotations

import shutil
from collections import OrderedDict
from pathlib import Path


class FilesetCache:
    def __init__(self, root: str | Path, max_bytes: int = 1 << 30):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.max_bytes = max_bytes
        self._entries: OrderedDict[str, int] = OrderedDict()  # ref -> bytes
        self.hits = 0
        self.misses = 0

    def _dir_for(self, ref: str) -> Path:
        return self.root / ref.replace("/", "_").replace(":", "@")

    def materialize(self, filesets, ref: str, dest_dir: str | Path) -> bool:
        """Fill dest_dir with the fileset's files; returns True on a cache
        hit (files hard-copied from the cache instead of the lake)."""
        resolved = filesets.resolve(ref).ref
        cdir = self._dir_for(resolved)
        dest = Path(dest_dir)
        if resolved in self._entries:
            self._entries.move_to_end(resolved)
            shutil.copytree(cdir, dest, dirs_exist_ok=True)
            self.hits += 1
            return True
        self.misses += 1
        filesets.materialize(resolved, cdir)
        size = sum(p.stat().st_size for p in cdir.rglob("*") if p.is_file())
        self._entries[resolved] = size
        self._evict()
        shutil.copytree(cdir, dest, dirs_exist_ok=True)
        return False

    def _evict(self) -> None:
        while sum(self._entries.values()) > self.max_bytes \
                and len(self._entries) > 1:
            ref, _ = self._entries.popitem(last=False)
            shutil.rmtree(self._dir_for(ref), ignore_errors=True)

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "bytes": sum(self._entries.values()),
                "entries": len(self._entries)}
