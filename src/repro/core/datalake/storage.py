"""Versioned object storage (ACAI §3.2.1, §4.4.1–4.4.3).

The paper stores each user file as an S3 object and keeps the hierarchy +
version table in MySQL; we keep the same split locally: payload bytes live in
a content-addressed blob directory (the "S3"), while the hierarchy, version
table and upload sessions are a JSON-persisted catalog (the "MySQL").
Semantics preserved:

  * every version is immutable; version numbers are sequential with no gaps;
  * the latest version is used when none is specified; ``name@v`` pins one;
  * batch uploads are transactional **upload sessions** (pending ->
    committed | aborted), crash-safe via persisted session state;
  * uploads/downloads go "directly to S3": callers receive a blob path
    ("presigned URL") and the server only records completion events.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from pathlib import Path
from typing import Iterable, Optional


class DataLakeError(RuntimeError):
    pass


@dataclasses.dataclass
class FileVersion:
    path: str
    version: int
    blob: str          # content hash
    size: int
    created_at: float
    creator: str = ""


def parse_ref(ref: str) -> tuple[str, Optional[int]]:
    """'/data/train.json@2' -> ('/data/train.json', 2)."""
    if "@" in ref:
        path, v = ref.rsplit("@", 1)
        return path, int(v)
    return ref, None


class Storage:
    """One project's versioned file store."""

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.blob_dir = self.root / "blobs"
        self.blob_dir.mkdir(parents=True, exist_ok=True)
        self._catalog_path = self.root / "catalog.json"
        self._lock = threading.Lock()   # the paper's server-side lock
        self._files: dict[str, list[FileVersion]] = {}
        self._sessions: dict[str, dict] = {}
        self._session_ctr = 0
        self._load()

    # -- persistence ---------------------------------------------------
    def _load(self) -> None:
        if self._catalog_path.exists():
            raw = json.loads(self._catalog_path.read_text())
            self._files = {p: [FileVersion(**v) for v in vs]
                           for p, vs in raw["files"].items()}
            self._sessions = raw["sessions"]
            self._session_ctr = raw["session_ctr"]

    def _save(self) -> None:
        raw = {"files": {p: [dataclasses.asdict(v) for v in vs]
                         for p, vs in self._files.items()},
               "sessions": self._sessions,
               "session_ctr": self._session_ctr}
        tmp = self._catalog_path.with_suffix(".tmp")
        tmp.write_text(json.dumps(raw))
        os.replace(tmp, self._catalog_path)

    # -- blobs ("S3") --------------------------------------------------
    def _put_blob(self, data: bytes) -> str:
        h = hashlib.sha256(data).hexdigest()
        p = self.blob_dir / h
        if not p.exists():
            tmp = p.with_suffix(".tmp-%d" % os.getpid())
            tmp.write_bytes(data)
            os.replace(tmp, p)
        return h

    def _get_blob(self, blob: str) -> bytes:
        p = self.blob_dir / blob
        if not p.exists():
            raise DataLakeError(f"missing blob {blob}")
        return p.read_bytes()

    def blob_path(self, path: str, version: Optional[int] = None) -> Path:
        """'presigned URL': direct filesystem path to the payload."""
        fv = self.resolve(path, version)
        return self.blob_dir / fv.blob

    # -- single-file API -----------------------------------------------
    def upload(self, path: str, data: bytes, creator: str = "") -> FileVersion:
        sid = self.begin_session([path], creator)
        self.session_put(sid, path, data)
        return self.commit_session(sid)[0]

    def download(self, ref: str) -> bytes:
        path, version = parse_ref(ref)
        return self._get_blob(self.resolve(path, version).blob)

    def resolve(self, path: str, version: Optional[int] = None) -> FileVersion:
        vs = self._files.get(path)
        if not vs:
            raise DataLakeError(f"no such file {path}")
        if version is None:
            return vs[-1]
        for v in vs:
            if v.version == version:
                return v
        raise DataLakeError(f"no version {version} of {path}")

    def exists(self, path: str) -> bool:
        return path in self._files

    def list_files(self, prefix: str = "/") -> list[str]:
        return sorted(p for p in self._files if p.startswith(prefix))

    def versions(self, path: str) -> list[int]:
        return [v.version for v in self._files.get(path, [])]

    # -- upload sessions (transactional batch upload, §4.4.3) -----------
    def begin_session(self, paths: Iterable[str], creator: str = "") -> str:
        with self._lock:
            self._session_ctr += 1
            sid = f"session-{self._session_ctr}"
            self._sessions[sid] = {
                "state": "pending", "creator": creator,
                "files": {p: None for p in paths},   # path -> blob once uploaded
                "started_at": time.time(),
            }
            self._save()
            return sid

    def session_put(self, sid: str, path: str, data: bytes) -> None:
        # distinct destination per file: content-addressing guarantees
        # asynchronous uploads never overwrite each other's blobs — but the
        # catalog save must still be serialized across concurrent agents
        blob = self._put_blob(data)
        with self._lock:
            sess = self._session(sid, "pending")
            if path not in sess["files"]:
                raise DataLakeError(f"{path} not declared in session {sid}")
            sess["files"][path] = [blob, len(data)]
            self._save()

    def commit_session(self, sid: str) -> list[FileVersion]:
        """Allocate sequential version numbers; only fully-uploaded sessions
        commit, so failed uploads never occupy version numbers."""
        with self._lock:
            sess = self._session(sid, "pending")
            missing = [p for p, b in sess["files"].items() if b is None]
            if missing:
                raise DataLakeError(
                    f"session {sid} incomplete, missing {missing}")
            out = []
            now = time.time()
            for path, (blob, size) in sess["files"].items():
                vs = self._files.setdefault(path, [])
                nxt = vs[-1].version + 1 if vs else 1
                fv = FileVersion(path=path, version=nxt, blob=blob,
                                 size=size, created_at=now,
                                 creator=sess["creator"])
                vs.append(fv)
                out.append(fv)
            sess["state"] = "committed"
            self._save()
            return out

    def abort_session(self, sid: str) -> None:
        with self._lock:
            sess = self._session(sid, "pending")
            sess["state"] = "aborted"
            sess["files"] = {}
            self._save()

    def session_state(self, sid: str) -> str:
        if sid not in self._sessions:
            raise DataLakeError(f"no session {sid}")
        return self._sessions[sid]["state"]

    def _session(self, sid: str, want_state: str) -> dict:
        sess = self._sessions.get(sid)
        if sess is None:
            raise DataLakeError(f"no session {sid}")
        if sess["state"] != want_state:
            raise DataLakeError(
                f"session {sid} is {sess['state']}, wanted {want_state}")
        return sess
