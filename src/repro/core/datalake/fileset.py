"""File sets (ACAI §3.2.2): versioned named lists of (file, version) refs.

Spec grammar supported by ``FileSetManager.create``:
  '/data/train.json'        latest version of a file
  '/data/train.json@2'      pinned file version
  '/@HotpotQA'              every file of the latest version of set HotpotQA
  '/@HotpotQA:1'            ... of set version 1
  '/validation/@HotpotQA'   subset: files under a directory within a set
  '/data/train.json@HotpotQA:1'  the version of that file referenced by the set

Creation from other sets records a fileset-creation dependency edge in the
provenance graph (merge / update / subset — §3.2.2 examples).
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Optional, TYPE_CHECKING

from repro.core.datalake.storage import DataLakeError, Storage

if TYPE_CHECKING:
    from repro.core.datalake.provenance import ProvenanceGraph


@dataclasses.dataclass
class FileSetVersion:
    name: str
    version: int
    files: dict[str, int]         # path -> file version
    created_at: float
    creator: str = ""

    @property
    def ref(self) -> str:
        return f"{self.name}:{self.version}"


def parse_set_ref(ref: str) -> tuple[str, Optional[int]]:
    """'HotpotQA:1' -> ('HotpotQA', 1); 'HotpotQA' -> ('HotpotQA', None)."""
    if ":" in ref:
        name, v = ref.rsplit(":", 1)
        return name, int(v)
    return ref, None


class FileSetManager:
    def __init__(self, storage: Storage,
                 provenance: "Optional[ProvenanceGraph]" = None):
        self.storage = storage
        self.provenance = provenance
        self._path = storage.root / "filesets.json"
        # job agents on ThreadPoolRunner workers create sets concurrently
        self._lock = threading.RLock()
        self._sets: dict[str, list[FileSetVersion]] = {}
        if self._path.exists():
            raw = json.loads(self._path.read_text())
            self._sets = {n: [FileSetVersion(**v) for v in vs]
                          for n, vs in raw.items()}

    def _save(self) -> None:
        self._path.write_text(json.dumps(
            {n: [dataclasses.asdict(v) for v in vs]
             for n, vs in self._sets.items()}))

    # ------------------------------------------------------------------
    def resolve(self, ref: str) -> FileSetVersion:
        name, version = parse_set_ref(ref)
        vs = self._sets.get(name)
        if not vs:
            raise DataLakeError(f"no such file set {name}")
        if version is None:
            return vs[-1]
        for v in vs:
            if v.version == version:
                return v
        raise DataLakeError(f"no version {version} of file set {name}")

    def exists(self, name: str) -> bool:
        return name in self._sets

    def list_sets(self) -> list[str]:
        return sorted(self._sets)

    # ------------------------------------------------------------------
    def _expand_spec(self, spec: str) -> tuple[dict[str, int], list[str]]:
        """Expand one spec string -> ({path: version}, [source fileset refs])."""
        deps: list[str] = []
        if "@" in spec:
            prefix, ref = spec.split("@", 1)
            # '@Set' or '@Set:1' possibly with a path prefix filter
            if self.exists(parse_set_ref(ref)[0]):
                fsv = self.resolve(ref)
                deps.append(fsv.ref)
                if prefix in ("", "/"):
                    return dict(fsv.files), deps
                # subset filter: '/validation/@Set' or a single file
                sub = {p: v for p, v in fsv.files.items()
                       if p.startswith(prefix) or p == prefix.rstrip("/")}
                if not sub:
                    raise DataLakeError(
                        f"{prefix!r} matches nothing in file set {ref}")
                return sub, deps
            # plain '@<int>' version pin
            path, version = prefix, int(ref)
            fv = self.storage.resolve(path, version)
            return {fv.path: fv.version}, deps
        fv = self.storage.resolve(spec)
        return {fv.path: fv.version}, deps

    def create(self, name: str, specs: list[str],
               creator: str = "") -> FileSetVersion:
        """Create (or new-version) a file set from spec strings. Later specs
        override earlier ones for the same path (the paper's update example).
        A file set cannot contain two versions of the same file by
        construction. Dependencies to source sets are recorded."""
        with self._lock:
            files: dict[str, int] = {}
            deps: list[str] = []
            for spec in specs:
                got, d = self._expand_spec(spec)
                files.update(got)
                deps.extend(d)
            vs = self._sets.setdefault(name, [])
            prev = vs[-1] if vs else None
            fsv = FileSetVersion(name=name, version=(prev.version + 1 if prev
                                                     else 1),
                                 files=files, created_at=time.time(),
                                 creator=creator)
            vs.append(fsv)
            self._save()
        if self.provenance is not None:
            self.provenance.add_fileset(fsv.ref)
            seen = set()
            for dep in deps:
                if dep != fsv.ref and dep not in seen:
                    seen.add(dep)
                    self.provenance.add_creation_edge(
                        src=dep, dst=fsv.ref, creator=creator)
        return fsv

    # convenience wrappers matching the paper's examples ----------------
    def merge(self, name: str, set_refs: list[str], creator: str = ""):
        return self.create(name, [f"/@{r}" for r in set_refs], creator)

    def update(self, name: str, extra_specs: list[str], creator: str = ""):
        return self.create(name, [f"/@{name}"] + extra_specs, creator)

    def subset(self, name: str, src_ref: str, prefix: str,
               creator: str = ""):
        return self.create(name, [f"{prefix}@{src_ref}"], creator)

    # ------------------------------------------------------------------
    def materialize(self, ref: str, dest_dir) -> list[str]:
        """Download a file set's files into dest_dir as unversioned files
        (what the job agent does before running a job)."""
        from pathlib import Path
        fsv = self.resolve(ref)
        dest = Path(dest_dir)
        out = []
        for path, version in sorted(fsv.files.items()):
            data = self.storage._get_blob(
                self.storage.resolve(path, version).blob)
            local = dest / path.lstrip("/")
            local.parent.mkdir(parents=True, exist_ok=True)
            local.write_bytes(data)
            out.append(str(local))
        return out
