"""Provenance graph (ACAI §3.2.4, §4.5.2).

A DAG where nodes are file-set versions and edges are actions — job
executions or file-set creations. The paper hosts this on Neo4j storing only
ids (metadata lives in the metadata server); we mirror that split with a
``networkx.MultiDiGraph`` and the same three query APIs: whole graph,
trace-forward one edge, trace-backward one edge (plus transitive closures
used by the dashboard's interactive tracing and workflow replay).

Edge direction follows dataflow: input fileset --(job)--> output fileset,
source fileset --(creation)--> derived fileset.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Optional

import networkx as nx


class ProvenanceGraph:
    def __init__(self, root: str | Path):
        self._path = Path(root) / "provenance.json"
        # job agents on ThreadPoolRunner workers add edges concurrently
        self._lock = threading.RLock()
        self.g = nx.MultiDiGraph()
        if self._path.exists():
            raw = json.loads(self._path.read_text())
            self.g.add_nodes_from(raw["nodes"])
            for u, v, data in raw["edges"]:
                self.g.add_edge(u, v, **data)

    def _save(self) -> None:
        raw = {"nodes": list(self.g.nodes),
               "edges": [(u, v, d) for u, v, d in self.g.edges(data=True)]}
        self._path.write_text(json.dumps(raw))

    # ------------------------------------------------------------------
    def add_fileset(self, fileset_ref: str) -> None:
        with self._lock:
            self.g.add_node(fileset_ref)
            self._save()

    def add_job_edge(self, *, src: Optional[str], dst: str, job_id: str,
                     creator: str = "") -> None:
        """input fileset --(job execution)--> output fileset."""
        with self._lock:
            self.g.add_node(dst)
            if src is not None:
                self.g.add_node(src)
                self.g.add_edge(src, dst, action="job", job_id=job_id,
                                creator=creator)
            self._save()

    def add_dependency_edge(self, *, src_job: str, dst_job: str,
                            pipeline: str = "",
                            src_fileset: Optional[str] = None,
                            dst_fileset: Optional[str] = None) -> None:
        """Declared DAG edge from the pipeline SDK: recorded at submit
        time, before either job runs, so lineage reflects the *declared*
        dataflow (JobSpec.depends_on) and not just observed reads/writes.
        Nodes are job ids (fileset-version nodes are added later by the
        runner when outputs actually materialize)."""
        with self._lock:
            self.g.add_node(src_job)
            self.g.add_node(dst_job)
            self.g.add_edge(src_job, dst_job, action="pipeline_dep",
                            pipeline=pipeline, src_fileset=src_fileset,
                            dst_fileset=dst_fileset)
            self._save()

    def dependency_edges(self, pipeline: Optional[str] = None) \
            -> list[tuple[str, str, dict]]:
        """All declared DAG edges, optionally filtered by pipeline name."""
        with self._lock:
            return [(u, v, d) for u, v, d in self.g.edges(data=True)
                    if d.get("action") == "pipeline_dep"
                    and (pipeline is None or d.get("pipeline") == pipeline)]

    def add_creation_edge(self, *, src: str, dst: str,
                          creator: str = "") -> None:
        with self._lock:
            self.g.add_node(src)
            self.g.add_node(dst)
            self.g.add_edge(src, dst, action="fileset_creation",
                            creator=creator)
            self._save()

    # -- the three paper APIs -------------------------------------------
    def whole_graph(self) -> dict:
        return {"nodes": list(self.g.nodes),
                "edges": [(u, v, d) for u, v, d in self.g.edges(data=True)]}

    def forward(self, fileset_ref: str) -> list[tuple[str, dict]]:
        """One edge forward: filesets derived from this one."""
        return [(v, d) for _, v, d in self.g.out_edges(fileset_ref,
                                                       data=True)]

    def backward(self, fileset_ref: str) -> list[tuple[str, dict]]:
        """One edge backward: filesets this one was derived from."""
        return [(u, d) for u, _, d in self.g.in_edges(fileset_ref,
                                                      data=True)]

    # -- transitive helpers (dashboard tracing, workflow replay §7.1.3) --
    def ancestors(self, fileset_ref: str) -> list[str]:
        return sorted(nx.ancestors(self.g, fileset_ref))

    def descendants(self, fileset_ref: str) -> list[str]:
        return sorted(nx.descendants(self.g, fileset_ref))

    def lineage_jobs(self, fileset_ref: str) -> list[str]:
        """Every job id on any path into this fileset (reproduction
        recipe, oldest first)."""
        anc = set(self.ancestors(fileset_ref)) | {fileset_ref}
        sub = self.g.subgraph(anc)
        jobs = []
        for _u, _v, d in sub.edges(data=True):
            if d.get("action") == "job":
                jobs.append(d["job_id"])
        return jobs

    def replay_order(self, fileset_ref: str) -> list[str]:
        """Topological order of ancestor filesets (workflow replay)."""
        anc = set(self.ancestors(fileset_ref)) | {fileset_ref}
        return list(nx.topological_sort(self.g.subgraph(anc)))

    def is_dag(self) -> bool:
        return nx.is_directed_acyclic_graph(self.g)
