"""Mamba-2 (SSD) block: in_proj -> causal depthwise conv -> selective state
space (chunk-parallel scan) -> gated RMSNorm -> out_proj.

Recurrence per head (state N x P, P = head_dim, scalar decay per head):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T
    y_t = C_t^T h_t + D * x_t
Chunked jnp path mirrors the Pallas kernel in ``repro.kernels.mamba2_ssd``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.blocks import _dense_init


def init_mamba_layer(cfg: ArchConfig, key):
    """Projections are kept SEPARATE (z/x/B/C/dt) rather than one fused
    in_proj: tensor-parallel sharding of the fused matrix would put the
    split boundaries off shard boundaries and force per-layer reshards
    (DESIGN.md §5). z/x columns shard over the model axis (head-aligned);
    B/C/dt are small and stay replicated."""
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.d_inner(d)
    nh = mc.n_heads(d)
    gn = mc.n_groups * mc.d_state
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[0], (nh,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "z_proj": _dense_init(ks[1], (d, di)),
        "x_proj": _dense_init(ks[2], (d, di)),
        "B_proj": _dense_init(ks[3], (d, gn)),
        "C_proj": _dense_init(ks[4], (d, gn)),
        "dt_proj": _dense_init(ks[5], (d, nh)),
        "conv_x": 0.1 * jax.random.normal(ks[6], (mc.d_conv, di),
                                          jnp.float32),
        "conv_b_x": jnp.zeros((di,), jnp.float32),
        "conv_BC": 0.1 * jax.random.normal(ks[7], (mc.d_conv, 2 * gn),
                                           jnp.float32),
        "conv_b_BC": jnp.zeros((2 * gn,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),   # inverse softplus
        "gate_norm": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[1], (di, d), fan_in=di),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv via shifted adds. x: (B, S, C); w: (W, C).

    state: (B, W-1, C) previous inputs for decode. Returns (y, new_state).
    """
    wlen = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : wlen - 1])
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)        # (B, S+W-1, C)
    y = sum(xp[:, i: i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(wlen))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(wlen - 1):]
    return jax.nn.silu(y), new_state


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 256):
    """Chunk-parallel SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,)<0;
    B,C: (B,S,G,N); D: (H,). Returns y (B,S,H,P). fp32 internals."""
    f32 = jnp.float32
    b, s, h, p_ = x.shape
    g, n = B.shape[2], B.shape[3]
    reps = h // g
    nc = max(s // chunk, 1)
    c = s // nc

    la = dt.astype(f32) * A.astype(f32)[None, None, :]       # (B,S,H) <= 0
    xr = (x.astype(f32) * dt.astype(f32)[..., None])          # dt-weighted input
    Bh = jnp.repeat(B.astype(f32), reps, axis=2)              # (B,S,H,N)
    Ch = jnp.repeat(C.astype(f32), reps, axis=2)

    def to_chunks(a, feat):
        return a.reshape(b, nc, c, h, feat).transpose(1, 0, 3, 2, 4)
    xc = to_chunks(xr, p_)                                    # (nc,B,H,C,P)
    bc = to_chunks(Bh, n)
    cc = to_chunks(Ch, n)
    lac = la.reshape(b, nc, c, h).transpose(1, 0, 3, 2)       # (nc,B,H,C)
    cum = jnp.cumsum(lac, axis=-1)                            # inclusive
    tot = cum[..., -1:]

    def body(state, xs):
        xcb, bcb, ccb, cumb, totb = xs
        # inter-chunk: y += C_t exp(cum_t) . h0
        cd = ccb * jnp.exp(cumb)[..., None]
        y = jnp.einsum("bhcn,bhnp->bhcp", cd, state)
        # intra-chunk pairs j <= t, decay exp(cum_t - cum_j); half-shift for
        # numerical safety of the factorization
        cs = ccb * jnp.exp(cumb - 0.5 * totb)[..., None]
        bs_ = bcb * jnp.exp(0.5 * totb - cumb)[..., None]
        att = jnp.einsum("bhcn,bhjn->bhcj", cs, bs_)
        idx = jnp.arange(cumb.shape[-1])
        mask = idx[:, None] >= idx[None, :]
        att = att * mask[None, None]
        y = y + jnp.einsum("bhcj,bhjp->bhcp", att, xcb)
        # state: h' = exp(tot) h0 + sum_j exp(tot - cum_j) B_j (dt_j x_j)^T
        bd = bcb * jnp.exp(totb - cumb)[..., None]
        state = jnp.exp(totb)[..., None] * state \
            + jnp.einsum("bhcn,bhcp->bhnp", bd, xcb)
        return state, y

    state0 = jnp.zeros((b, h, n, p_), f32)
    _, ys = jax.lax.scan(body, state0, (xc, bc, cc, cum, tot))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, p_)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype)


def ssd_recurrent(x, dt, A, B, C, D, state):
    """Single-token decode. x: (B,1,H,P); state: (B,H,N,P)."""
    f32 = jnp.float32
    xt = x.astype(f32)[:, 0] * dt.astype(f32)[:, 0, :, None]   # (B,H,P)
    g = B.shape[2]
    reps = x.shape[2] // g
    bt = jnp.repeat(B.astype(f32)[:, 0], reps, axis=1)          # (B,H,N)
    ct = jnp.repeat(C.astype(f32)[:, 0], reps, axis=1)
    a = jnp.exp(dt.astype(f32)[:, 0] * A.astype(f32)[None])     # (B,H)
    state = a[..., None, None] * state + jnp.einsum("bhn,bhp->bhnp", bt, xt)
    y = jnp.einsum("bhn,bhnp->bhp", ct, state) \
        + x.astype(f32)[:, 0] * D.astype(f32)[None, :, None]
    return y[:, None].astype(x.dtype), state


def mamba_block(p, x, cfg: ArchConfig, *, state=None):
    """state: (ssm_state, conv_state) for decode, else None."""
    mc = cfg.mamba
    b, s, d = x.shape
    di = mc.d_inner(d)
    nh = mc.n_heads(d)
    gn = mc.n_groups * mc.d_state
    cd = x.dtype

    z = x @ p["z_proj"].astype(cd)
    xs_ = x @ p["x_proj"].astype(cd)
    BC = jnp.concatenate([x @ p["B_proj"].astype(cd),
                          x @ p["C_proj"].astype(cd)], axis=-1)
    dt_raw = x @ p["dt_proj"].astype(cd)
    conv_state = None if state is None else state[1]
    cs_x = None if conv_state is None else conv_state[..., :di]
    cs_bc = None if conv_state is None else conv_state[..., di:]
    xs_, ncs_x = _causal_conv(xs_, p["conv_x"], p["conv_b_x"], cs_x)
    BC, ncs_bc = _causal_conv(BC, p["conv_BC"], p["conv_b_BC"], cs_bc)
    new_conv_state = jnp.concatenate([ncs_x, ncs_bc], axis=-1)
    B, C = jnp.split(BC, [gn], axis=-1)
    xs_ = xs_.reshape(b, s, nh, mc.head_dim)
    B = B.reshape(b, s, mc.n_groups, mc.d_state)
    C = C.reshape(b, s, mc.n_groups, mc.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if state is None:
        y = ssd_chunked(xs_, dt, A, B, C, p["D"], chunk=mc.chunk)
        new_ssm = None
    else:
        y, new_ssm = ssd_recurrent(xs_, dt, A, B, C, p["D"], state[0])
    y = y.reshape(b, s, di)
    # gated RMSNorm (Mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True)
                            + 1e-5) * p["gate_norm"]).astype(cd)
    out = y @ p["out_proj"].astype(cd)
    new_state = None if state is None else (new_ssm, new_conv_state)
    return out, new_state
