"""Core transformer blocks: norms, RoPE, GQA attention (chunked online-softmax
XLA path + pluggable Pallas path), SwiGLU MLP, GShard-style MoE.

All blocks are pure functions over param pytrees (dicts of jnp arrays).
Params live in fp32; forward casts to ``compute_dtype`` at block entry.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, dtype) / jnp.sqrt(fan_in)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, key=None):
    if not cfg.parametric_norm:
        return {"_np": jnp.zeros((0,), jnp.float32)}  # non-parametric sentinel
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32)}


def apply_norm(p, x, cfg: ArchConfig):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm" or not cfg.parametric_norm:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        if cfg.parametric_norm and "scale" in p:
            y = y * p["scale"] + p["bias"]
    else:
        y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True)
                              + cfg.norm_eps)
        y = y * p["scale"]
    return y.astype(dtype)


def rms_head_norm(x, scale, eps=1e-6):
    """qk-norm: RMS norm over the head dim (per head)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (y * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_table(seq_len: int, head_dim: int, theta: float):
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.outer(t, freqs)                       # (S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (S, D/2) or (..., S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:   # (S, half) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:               # (B, S, half) e.g. decode positions
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, key, d_src: Optional[int] = None):
    """d_src: K/V source dim (cross-attention reads from vision states)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    d_src = d_src or d
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(k1, (d, cfg.n_heads * hd)),
        "wk": _dense_init(k2, (d_src, cfg.n_kv_heads * hd)),
        "wv": _dense_init(k3, (d_src, cfg.n_kv_heads * hd)),
        "wo": _dense_init(k4, (cfg.n_heads * hd, d), fan_in=cfg.n_heads * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _gqa_expand(k, n_heads):
    """(B, S, KV, D) -> (B, S, H, D) by repeating groups."""
    b, s, kv, d = k.shape
    if kv == n_heads:
        return k
    reps = n_heads // kv
    return jnp.repeat(k, reps, axis=2)


def chunked_causal_attention(q, k, v, *, chunk: int = 512,
                             logit_dtype=jnp.float32):
    """Online-softmax causal attention, scanning KV chunks (flash-style,
    O(S*chunk) live memory). q,k,v: (B, S, H, D) (kv already GQA-expanded).

    Baseline schedule computes every (q, kv-chunk) pair and masks above the
    diagonal (2x score-FLOP waste vs causal optimum; see EXPERIMENTS.md §Perf
    for the tournament schedule that removes it on the hot cells).
    """
    b, s, h, d = q.shape
    scale = d ** -0.5
    nc = max(s // chunk, 1)
    chunk = s // nc
    qf = jnp.swapaxes(q, 1, 2) * scale            # (B, H, S, D)
    kc = jnp.swapaxes(k, 1, 2).reshape(b, h, nc, chunk, d)
    vc = jnp.swapaxes(v, 1, 2).reshape(b, h, nc, chunk, d)
    kc = jnp.moveaxis(kc, 2, 0)                   # (nc, B, H, C, D)
    vc = jnp.moveaxis(vc, 2, 0)
    q_pos = jnp.arange(s)

    def body(carry, xs):
        m, l, o = carry
        kb, vb, idx = xs
        # score blocks materialize at logit_dtype (fp32 default; bf16 under
        # §Perf A8 — running stats below are ALWAYS fp32)
        sc = jnp.einsum("bhqd,bhkd->bhqk", qf, kb,
                        preferred_element_type=logit_dtype)
        k_pos = idx * chunk + jnp.arange(chunk)
        mask = q_pos[:, None] >= k_pos[None, :]
        scf = jnp.where(mask[None, None], sc.astype(jnp.float32), -jnp.inf)
        m_new = jnp.maximum(m, scf.max(-1))
        p = jnp.exp(scf - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        o = o * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, o), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    o0 = jnp.zeros((b, h, s, d), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0),
                                (kc, vc, jnp.arange(nc)))
    o = o / jnp.maximum(l, 1e-37)[..., None]
    return jnp.swapaxes(o, 1, 2).astype(q.dtype)   # (B, S, H, D)


def full_causal_attention(q, k, v):
    """Reference O(S^2)-memory attention (tests / tiny shapes)."""
    b, s, h, d = q.shape
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                    preferred_element_type=jnp.float32) * d ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token decode: q (B, 1, H, D) vs UNEXPANDED GQA cache
    (B, Skv, KV, D); first ``cache_len`` positions valid; softmax fp32.

    Grouped einsums instead of jnp.repeat head expansion: the repeat op
    breaks GSPMD partitioning of a sequence-sharded cache (it fell back to
    full 17 GB cache all-gathers per layer on qwen3-32b decode — §Perf C).
    """
    b, _, h, d = q.shape
    skv, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, d)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                    preferred_element_type=jnp.float32) * d ** -0.5
    valid = jnp.arange(skv)[None, :] < cache_len[:, None]    # (B, Skv)
    sc = jnp.where(valid[:, None, None, None, :], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return o.reshape(b, 1, h, d)


def attention_block(p, x, cfg: ArchConfig, *, rope=None, positions=None,
                    kv_cache=None, cache_len=None, kv_src=None,
                    causal=True, attn_impl="xla", seq_axis=None):
    """Full attention sub-block: proj -> rope -> (qk-norm) -> attn -> out proj.

    kv_cache: None for train/prefill; (k, v) of shape (B, Skv, KV, D) for
    decode (returns updated cache). kv_src: cross-attention source states.
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    cd = x.dtype
    src = kv_src if kv_src is not None else x
    q = (x @ p["wq"].astype(cd)).reshape(b, s, cfg.n_heads, hd)
    k = (src @ p["wk"].astype(cd)).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    v = (src @ p["wv"].astype(cd)).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"].astype(cd))
        k = rms_head_norm(k, p["k_norm"].astype(cd))
    if rope is not None and kv_src is None:
        cos, sin = rope
        if positions is not None:        # decode: per-token positions
            cos = jnp.take(cos, positions, axis=0)   # (B, 1, half)
            sin = jnp.take(sin, positions, axis=0)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if kv_cache is not None:             # decode step
        kc, vc = kv_cache
        idx = cache_len                   # (B,) insert position
        kc = _cache_insert(kc, k, idx)
        vc = _cache_insert(vc, v, idx)
        new_cache = (kc, vc)
        o = decode_attention(q, kc.astype(cd), vc.astype(cd),
                             cache_len + 1)
    elif kv_src is not None:             # cross attention (not causal)
        kq = _gqa_expand(k, cfg.n_heads)
        vq = _gqa_expand(v, cfg.n_heads)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kq,
                        preferred_element_type=jnp.float32) * hd ** -0.5
        pr = jax.nn.softmax(sc, axis=-1).astype(cd)
        o = jnp.einsum("bhqk,bkhd->bqhd", pr, vq)
    else:                                 # train / prefill, causal
        kq = _gqa_expand(k, cfg.n_heads)
        vq = _gqa_expand(v, cfg.n_heads)
        if attn_impl == "pallas":
            from repro.kernels import ops as kops
            o = kops.flash_attention(q, kq, vq, causal=True)
        elif attn_impl == "pallas-interpret":
            from repro.kernels import ops as kops
            o = kops.flash_attention(q, kq, vq, causal=True, interpret=True)
        elif attn_impl == "xla-bf16-logits" and s > 1024:
            # §Perf A8: materialize per-chunk score blocks in bf16 (the
            # online-softmax running stats stay fp32); on TPU the Pallas
            # kernel keeps scores in VMEM entirely — this is the XLA-path
            # approximation of that traffic saving
            o = chunked_causal_attention(q, kq, vq,
                                         logit_dtype=jnp.bfloat16)
        elif s <= 1024:
            o = full_causal_attention(q, kq, vq)
        else:
            o = chunked_causal_attention(q, kq, vq)
    out = o.reshape(b, s, cfg.n_heads * hd) @ p["wo"].astype(cd)
    return out, new_cache


CACHE_INSERT_IMPL = "onehot"   # onehot | scatter  (§Perf C3)


def _cache_insert(cache, new, idx):
    """Insert new (B, 1, KV, D) at per-batch position idx into
    (B, S, KV, D).

    "onehot" rewrites the whole cache (read+write of every byte — simple,
    always partitionable); "scatter" writes only B rows via jnp scatter
    (cheaper HBM traffic IF GSPMD partitions it against the sharded seq
    dim — measured per cell in §Perf)."""
    if CACHE_INSERT_IMPL == "scatter":
        b = cache.shape[0]
        return cache.at[jnp.arange(b), idx].set(
            new[:, 0].astype(cache.dtype), mode="drop")
    s = cache.shape[1]
    onehot = (jnp.arange(s)[None, :] == idx[:, None]).astype(cache.dtype)
    return cache * (1 - onehot)[:, :, None, None] + \
        onehot[:, :, None, None] * new.astype(cache.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": _dense_init(k1, (cfg.d_model, d_ff)),
            "w_up": _dense_init(k2, (cfg.d_model, d_ff)),
            "w_down": _dense_init(k3, (d_ff, cfg.d_model), fan_in=d_ff)}


def mlp_block(p, x):
    cd = x.dtype
    g = jax.nn.silu(x @ p["w_gate"].astype(cd))
    u = x @ p["w_up"].astype(cd)
    return (g * u) @ p["w_down"].astype(cd)


# ---------------------------------------------------------------------------
# MoE (GShard-style capacity-based dense dispatch)
# ---------------------------------------------------------------------------

def init_moe(cfg: ArchConfig, key):
    m = cfg.moe
    d = cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": _dense_init(k1, (d, m.n_experts)),
        "w_gate": _dense_init(k2, (m.n_experts, d, m.d_ff_expert)),
        "w_up": _dense_init(k3, (m.n_experts, d, m.d_ff_expert)),
        "w_down": _dense_init(k4, (m.n_experts, m.d_ff_expert, d),
                              fan_in=m.d_ff_expert),
    }
    if m.n_shared_experts:
        p["shared"] = init_mlp(cfg, k5, d_ff=m.n_shared_experts * m.d_ff_shared)
    return p


def _moe_local(x, router, wg, wu, wd, cfg: ArchConfig, e0, n_local: int,
               mesh_axes: tuple, shared_w=None):
    """Per-device MoE core: local routing + local scatter into THIS device's
    expert buffer + local expert GEMMs + gather-back; partial outputs are
    psum'd over the model axis (the only EP collective: activation-sized).

    x: (B_loc, S, D) local tokens; wg/wu/wd: (n_local, d, ff) local experts;
    e0: first local expert id (traced); mesh_axes: (model_axis?, all_axes)
    — empty tuples outside shard_map (single-device path, e0=0,
    n_local=E).
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    cd = x.dtype
    xt = x.reshape(t, d)
    logits = (xt @ router.astype(cd)).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)       # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(int(m.capacity_factor * m.top_k * t / m.n_experts), 4)

    # position of each (token, choice) within its GLOBAL expert queue —
    # identical on every model shard (replicated routing compute)
    onehot = (gate_idx.reshape(t * m.top_k)[:, None] ==
              jnp.arange(m.n_experts)[None, :])               # (T*k, E)
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    pos_in_expert = jnp.where(onehot, pos, 0).max(-1)         # (T*k,)
    keep = pos_in_expert < capacity
    gid = gate_idx.reshape(t * m.top_k)

    # local scatter: only (token, choice) pairs routed to THIS device's
    # experts land in the buffer; everything else is OOB-dropped
    local_ok = keep & (gid >= e0) & (gid < e0 + n_local)
    dest = jnp.where(local_ok, (gid - e0) * capacity + pos_in_expert,
                     n_local * capacity)
    updates = jnp.broadcast_to(xt[:, None, :], (t, m.top_k, d)) \
        .reshape(t * m.top_k, d)
    buf = jnp.zeros((n_local * capacity, d), cd)
    buf = buf.at[dest].add(updates, mode="drop")
    bufE = buf.reshape(n_local, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bufE, wg.astype(cd))) \
        * jnp.einsum("ecd,edf->ecf", bufE, wu.astype(cd))
    ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(cd))         # (E_loc,C,D)

    yflat = ye.reshape(n_local * capacity, d)
    ygath = yflat.at[dest].get(mode="fill", fill_value=0)     # (T*k, D)
    w = (gate_vals.reshape(t * m.top_k)
         * local_ok.astype(jnp.float32)).astype(cd)
    y = (ygath * w[:, None]).reshape(t, m.top_k, d).sum(1)

    model_axis, all_axes = mesh_axes
    if shared_w is not None:
        # fused shared expert: this device's ff slice contributes a partial
        # sum that rides the EP psum below (one collective, not two)
        sg, su, sd_ = shared_w
        hs = jax.nn.silu(xt @ sg.astype(cd)) * (xt @ su.astype(cd))
        y = y + hs @ sd_.astype(cd)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)                       # EP combine

    # load-balance aux loss (Switch style), replicated across the mesh
    me = probs.mean(0)
    ce = onehot.reshape(t, m.top_k, m.n_experts).astype(
        jnp.float32).sum(1).mean(0) * m.top_k
    aux = m.router_aux_coef * m.n_experts * jnp.sum(me * ce)
    if all_axes:
        aux = jax.lax.pmean(aux, all_axes)
    return y.reshape(b, s, d), aux


def moe_block(p, x, cfg: ArchConfig, *, capacity: Optional[int] = None):
    """Top-k capacity MoE. Returns (y, aux_loss).

    On a mesh: expert-parallel shard_map — experts shard over the model
    axis, tokens stay on their data shard, dispatch scatter/gather is
    device-local, and the only collective is an activation-sized psum.
    (The GShard dense-dispatch einsum costs O(T*E*C*D) MXU FLOPs —
    measured 200x the expert GEMMs on olmoe — and GSPMD cannot partition a
    scatter indexed on the sharded expert dim without replicating the
    buffers; the explicit shard_map path avoids both. See DESIGN.md §5.)
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import current_rules

    m = cfg.moe
    rules = current_rules()
    mesh = rules.mesh if rules else None
    use_shard_map = False
    if mesh is not None and "model" in mesh.axis_names:
        model_size = int(mesh.shape["model"])
        batch_axes = rules.table.get("batch", ())
        bsz = 1
        for a in batch_axes:
            bsz *= int(mesh.shape[a])
        use_shard_map = (m.n_experts % model_size == 0
                         and x.shape[0] % bsz == 0 and model_size > 1)

    if not use_shard_map:
        y, aux = _moe_local(x, p["router"], p["w_gate"], p["w_up"],
                            p["w_down"], cfg, 0, m.n_experts, (None, ()))
        if m.n_shared_experts:
            y = y + mlp_block(p["shared"], x)
        return y, aux

    n_local = m.n_experts // model_size
    b_ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    fuse = bool(m.n_shared_experts and m.fuse_shared)

    if fuse:
        def body(xl, router, wg, wu, wd, sg, su, sd_):
            e0 = jax.lax.axis_index("model") * n_local
            return _moe_local(xl, router, wg, wu, wd, cfg, e0, n_local,
                              ("model", mesh.axis_names),
                              shared_w=(sg, su, sd_))

        y, aux = shard_map(
            body, mesh=mesh,
            in_specs=(P(b_ax, None, None), P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None),
                      P(None, "model"), P(None, "model"),
                      P("model", None)),
            out_specs=(P(b_ax, None, None), P()),
            check_rep=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"],
          p["shared"]["w_gate"], p["shared"]["w_up"],
          p["shared"]["w_down"])
        return y, aux

    def body(xl, router, wg, wu, wd):
        e0 = jax.lax.axis_index("model") * n_local
        return _moe_local(xl, router, wg, wu, wd, cfg, e0, n_local,
                          ("model", mesh.axis_names))

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(b_ax, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(b_ax, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if m.n_shared_experts:
        y = y + mlp_block(p["shared"], x)
    return y, aux
