"""LM wrapper: embedding, block stack, head, loss, prefill/decode entries."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models import transformer as T
from repro.sharding import constrain


def init_params(cfg: ArchConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    if cfg.n_codebooks:
        embed = jax.random.normal(k1, (cfg.n_codebooks, cfg.vocab_size, d),
                                  jnp.float32) * 0.02
    else:
        embed = jax.random.normal(k1, (cfg.vocab_size, d), jnp.float32) * 0.02
    params = {"embed": embed, "final_norm": B.init_norm(cfg)}
    params.update(T.init_stack(cfg, k2))
    if not cfg.tie_embeddings:
        if cfg.n_codebooks:
            params["lm_head"] = B._dense_init(
                k3, (d, cfg.n_codebooks * cfg.vocab_size), fan_in=d)
        else:
            params["lm_head"] = B._dense_init(k3, (d, cfg.vocab_size),
                                              fan_in=d)
    return params


def make_ctx(cfg: ArchConfig, seq_len: int, mode: str, *,
             attn_impl: str = "xla", remat: Optional[str] = "full",
             vision=None, cache_len=None, compute_dtype=jnp.bfloat16) -> dict:
    ctx = {"mode": mode, "attn_impl": attn_impl, "remat": remat,
           "compute_dtype": compute_dtype}
    if not cfg.attention_free:
        hd = cfg.resolved_head_dim
        ctx["rope"] = B.rope_table(seq_len, hd, cfg.rope_theta)
    if vision is not None:
        ctx["vision"] = vision
    if cache_len is not None:
        ctx["cache_len"] = cache_len
        ctx["positions"] = cache_len[:, None]
    return ctx


def embed_tokens(params, tokens, cfg: ArchConfig, compute_dtype):
    if cfg.n_codebooks:
        # tokens (B, S, K) -> sum_k embed[k][tokens[..., k]]
        return jnp.einsum("bskv,kvd->bsd",
                          jax.nn.one_hot(tokens, cfg.vocab_size,
                                         dtype=compute_dtype),
                          params["embed"].astype(compute_dtype))
    return jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)


def lm_logits(params, x, cfg: ArchConfig):
    xf = B.apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    logits = xf @ w.astype(xf.dtype)
    if cfg.n_codebooks:
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    return logits


def forward(params, tokens, cfg: ArchConfig, ctx: dict, states=None):
    """Returns (logits, aux, new_states)."""
    cd = ctx.get("compute_dtype", jnp.bfloat16)
    x = embed_tokens(params, tokens, cfg, cd)
    x = constrain(x, ("batch", None, None))
    x, aux, new_states = T.apply_stack(params, x, cfg, ctx, states)
    logits = lm_logits(params, x, cfg)
    return logits, aux, new_states


def loss_fn(params, batch, cfg: ArchConfig, ctx: dict):
    """Next-token CE. batch: tokens (B,S[,K]) + labels (B,S[,K]),
    labels[t] = target for position t (-100 = ignore)."""
    logits, aux, _ = forward(params, batch["tokens"], cfg, ctx)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    ntok = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / ntok
    metrics = {"loss": loss, "aux_loss": aux, "ntokens": ntok}
    return loss + aux, metrics


def prefill(params, tokens, cfg: ArchConfig, ctx: dict):
    """Forward over the prompt; returns last-position logits.

    (Cache export for chained decode lives in serve/decode.py; the dry-run
    prefill program is logits-only, which matches a scoring/prefill step.)"""
    logits, aux, _ = forward(params, tokens, cfg, ctx)
    return logits[:, -1]


def decode_step(params, tokens, states, cache_len, cfg: ArchConfig,
                ctx: dict):
    """One-token decode. tokens (B,1[,K]); states from init_decode_state.
    Returns (logits (B,1[,K],V), new_states)."""
    logits, _, new_states = forward(params, tokens, cfg, ctx, states)
    return logits, new_states
