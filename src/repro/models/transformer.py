"""Unified stacked model over heterogeneous block types.

Layouts (keeps HLO size ~one layer body regardless of depth):
  uniform : one ``lax.scan`` over all (stacked-param) layers
            -> dense, moe, rwkv archs
  periodic: outer scan over periods of [inner scan of k homogeneous layers +
            one special layer], + trailing inner layers
            -> vlm   (4 dense + 1 cross-attn) x 8
            -> hybrid(5 mamba + 1 *shared* attn block) x 13 + 3 mamba

Decode state is a pytree with the same stacking as the params, threaded
through the scans as xs/ys.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.sharding import constrain

# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def build_layout(cfg: ArchConfig) -> dict:
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        periods = cfg.n_layers // k
        trailing = cfg.n_layers - periods * k
        return {"kind": "periodic", "periods": periods, "inner_n": k - 1,
                "inner_block": "dense", "single_block": "cross_attn",
                "trailing": trailing}
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        periods = cfg.n_layers // k
        trailing = cfg.n_layers - periods * k
        return {"kind": "periodic", "periods": periods, "inner_n": k - 1,
                "inner_block": "mamba", "single_block": "shared_attn",
                "trailing": trailing}
    block = {"ssm": "rwkv"}.get(cfg.family)
    if block is None:
        block = "moe" if cfg.moe is not None else "dense"
    return {"kind": "uniform", "block": block, "n": cfg.n_layers}


# ---------------------------------------------------------------------------
# single-layer init / forward
# ---------------------------------------------------------------------------

def init_layer(block: str, cfg: ArchConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    if block == "dense" or block == "shared_attn":
        return {"attn": B.init_attention(cfg, k1),
                "mlp": B.init_mlp(cfg, k2),
                "ln1": B.init_norm(cfg), "ln2": B.init_norm(cfg)}
    if block == "moe":
        return {"attn": B.init_attention(cfg, k1),
                "moe": B.init_moe(cfg, k2),
                "ln1": B.init_norm(cfg), "ln2": B.init_norm(cfg)}
    if block == "cross_attn":
        return {"attn": B.init_attention(cfg, k1, d_src=cfg.vision_dim),
                "mlp": B.init_mlp(cfg, k2),
                "ln1": B.init_norm(cfg), "ln2": B.init_norm(cfg),
                "gate_attn": jnp.zeros((), jnp.float32),
                "gate_mlp": jnp.zeros((), jnp.float32)}
    if block == "rwkv":
        return {"tm": R.init_rwkv_layer(cfg, k1),
                "ln1": B.init_norm(cfg), "ln2": B.init_norm(cfg)}
    if block == "mamba":
        return {"m": M.init_mamba_layer(cfg, k1),
                "ln1": B.init_norm(cfg)}
    raise ValueError(block)


def layer_fwd(block: str, p, x, cfg: ArchConfig, ctx: dict,
              state=None, collect_kv: bool = False):
    """Returns (x, new_state, aux, kv_out)."""
    aux = jnp.zeros((), jnp.float32)
    kv_out = None
    decode = ctx["mode"] == "decode"
    if block in ("dense", "moe", "shared_attn"):
        h = B.apply_norm(p["ln1"], x, cfg)
        kv_cache = state if decode else None
        o, new_cache = B.attention_block(
            p["attn"], h, cfg, rope=ctx.get("rope"),
            positions=ctx.get("positions"),
            kv_cache=kv_cache, cache_len=ctx.get("cache_len"),
            attn_impl=ctx.get("attn_impl", "xla"))
        x = x + o
        h = B.apply_norm(p["ln2"], x, cfg)
        if block == "moe":
            y, aux = B.moe_block(p["moe"], h, cfg)
        else:
            y = B.mlp_block(p["mlp"], h)
        x = x + y
        new_state = new_cache if decode else None
        x = constrain(x, ("batch", None, None))
        return x, new_state, aux, kv_out
    if block == "cross_attn":
        h = B.apply_norm(p["ln1"], x, cfg)
        if decode:
            kv, vv = state          # precomputed vision K/V
            hd = cfg.resolved_head_dim
            b_, s_, _ = h.shape
            q = (h @ p["attn"]["wq"].astype(h.dtype)).reshape(
                b_, s_, cfg.n_heads, hd)
            if cfg.qk_norm:
                q = B.rms_head_norm(q, p["attn"]["q_norm"].astype(h.dtype))
            kq = B._gqa_expand(kv.astype(h.dtype), cfg.n_heads)
            vq = B._gqa_expand(vv.astype(h.dtype), cfg.n_heads)
            sc = jnp.einsum("bqhd,bkhd->bhqk", q, kq,
                            preferred_element_type=jnp.float32) * hd ** -0.5
            pr = jax.nn.softmax(sc, -1).astype(h.dtype)
            o = jnp.einsum("bhqk,bkhd->bqhd", pr, vq)
            o = o.reshape(b_, s_, cfg.n_heads * hd) @ \
                p["attn"]["wo"].astype(h.dtype)
            new_state = state
        else:
            o, _ = B.attention_block(p["attn"], h, cfg,
                                     kv_src=ctx["vision"].astype(h.dtype))
            new_state = None
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * o
        h = B.apply_norm(p["ln2"], x, cfg)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * B.mlp_block(p["mlp"], h)
        x = constrain(x, ("batch", None, None))
        return x, new_state, aux, None
    if block == "rwkv":
        h = B.apply_norm(p["ln1"], x, cfg)
        if decode:
            wkv, tm_last, cm_last = state
            o, new_wkv = R.rwkv_time_mix(p["tm"], h, cfg, state=wkv,
                                         last_x=tm_last)
            new_tm_last = h[:, -1:]
            x = x + o
            h2 = B.apply_norm(p["ln2"], x, cfg)
            x = x + R.rwkv_channel_mix(p["tm"], h2, last_x=cm_last)
            new_state = (new_wkv, new_tm_last, h2[:, -1:])
        else:
            o, _ = R.rwkv_time_mix(p["tm"], h, cfg)
            x = x + o
            h2 = B.apply_norm(p["ln2"], x, cfg)
            x = x + R.rwkv_channel_mix(p["tm"], h2)
            new_state = None
        x = constrain(x, ("batch", None, None))
        return x, new_state, aux, None
    if block == "mamba":
        h = B.apply_norm(p["ln1"], x, cfg)
        o, new_state = M.mamba_block(p["m"], h, cfg, state=state)
        x = x + o
        x = constrain(x, ("batch", None, None))
        return x, new_state, aux, None
    raise ValueError(block)


# ---------------------------------------------------------------------------
# stacked init
# ---------------------------------------------------------------------------

def _stack_init(block: str, cfg: ArchConfig, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_layer(block, cfg, k))(keys)


def init_stack(cfg: ArchConfig, key):
    layout = build_layout(cfg)
    if layout["kind"] == "uniform":
        return {"layers": _stack_init(layout["block"], cfg, key, layout["n"])}
    k1, k2, k3, k4 = jax.random.split(key, 4)
    periods, inner_n = layout["periods"], layout["inner_n"]
    inner = jax.vmap(lambda k: _stack_init(layout["inner_block"], cfg, k,
                                           inner_n))(
        jax.random.split(k1, periods))
    out = {"layers": {"inner": inner,
                      "trailing": _stack_init(layout["inner_block"], cfg, k2,
                                              max(layout["trailing"], 1))}}
    if layout["single_block"] == "cross_attn":
        out["layers"]["single"] = _stack_init("cross_attn", cfg, k3, periods)
    else:   # hybrid: ONE shared attn block
        out["shared_block"] = init_layer("shared_attn", cfg, k4)
    return out


# ---------------------------------------------------------------------------
# stacked forward
# ---------------------------------------------------------------------------

def _maybe_remat(fn, ctx):
    pol = ctx.get("remat")
    if ctx["mode"] != "train" or pol in (None, "none"):
        return fn
    if pol == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _scan_layers(block: str, stacked, x, cfg, ctx, states=None,
                 collect_kv=False):
    """Scan homogeneous stacked layers. Returns (x, aux, new_states, kvs)."""
    decode = ctx["mode"] == "decode"

    if decode:
        def body(carry, xs):
            x, aux = carry
            p, st = xs
            x, new_st, a, _ = layer_fwd(block, p, x, cfg, ctx, st)
            return (x, aux + a), new_st
        (x, aux), new_states = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (stacked, states))
        return x, aux, new_states, None

    def body(carry, p):
        x, aux = carry
        x, _, a, kv = layer_fwd(block, p, x, cfg, ctx, None, collect_kv)
        return (x, aux + a), kv
    body = _maybe_remat(body, ctx)
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 stacked)
    return x, aux, None, kvs


def apply_stack(params, x, cfg: ArchConfig, ctx: dict, states=None):
    """Run all layers. states: decode-state pytree or None.

    Returns (x, aux, new_states)."""
    layout = build_layout(cfg)
    if layout["kind"] == "uniform":
        x, aux, new_states, _ = _scan_layers(
            layout["block"], params["layers"], x, cfg, ctx,
            None if states is None else states["layers"])
        return x, aux, (None if states is None else {"layers": new_states})

    periods = layout["periods"]
    inner_block = layout["inner_block"]
    single_block = layout["single_block"]
    decode = ctx["mode"] == "decode"
    shared_p = params.get("shared_block")
    aux0 = jnp.zeros((), jnp.float32)

    if decode:
        def outer(carry, xs):
            x, aux = carry
            if single_block == "cross_attn":
                (inner_p, single_p), (inner_st, single_st) = xs
            else:
                inner_p, (inner_st, single_st) = xs
                single_p = shared_p
            x, a1, new_inner_st, _ = _scan_layers(
                inner_block, inner_p, x, cfg, ctx, inner_st)
            x, new_single_st, a2, _ = layer_fwd(
                single_block, single_p, x, cfg, ctx, single_st)
            return (x, aux + a1 + a2), (new_inner_st, new_single_st)

        if single_block == "cross_attn":
            xs = ((params["layers"]["inner"], params["layers"]["single"]),
                  (states["inner"], states["single"]))
        else:
            xs = (params["layers"]["inner"],
                  (states["inner"], states["single"]))
        (x, aux), new_sts = jax.lax.scan(outer, (x, aux0), xs)
        new_states = {"inner": new_sts[0], "single": new_sts[1]}
        if layout["trailing"]:
            x, a3, new_tr, _ = _scan_layers(
                inner_block, params["layers"]["trailing"], x, cfg, ctx,
                states["trailing"])
            aux = aux + a3
            new_states["trailing"] = new_tr
        else:
            new_states["trailing"] = states["trailing"]
        return x, aux, new_states

    def outer(carry, xs):
        x, aux = carry
        if single_block == "cross_attn":
            inner_p, single_p = xs
        else:
            inner_p, single_p = xs, shared_p
        x, a1, _, _ = _scan_layers(inner_block, inner_p, x, cfg, ctx)
        x, _, a2, _ = layer_fwd(single_block, single_p, x, cfg, ctx)
        return (x, aux + a1 + a2), None

    if single_block == "cross_attn":
        xs = (params["layers"]["inner"], params["layers"]["single"])
    else:
        xs = params["layers"]["inner"]
    (x, aux), _ = jax.lax.scan(outer, (x, aux0), xs)
    if layout["trailing"]:
        x, a3, _, _ = _scan_layers(inner_block,
                                   params["layers"]["trailing"], x, cfg, ctx)
        aux = aux + a3
    return x, aux, None


# ---------------------------------------------------------------------------
# decode-state init
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, buffer_len: int,
                      dtype=jnp.bfloat16, vision=None, params=None):
    """Zeroed decode state (cache buffers) for the whole stack."""
    hd = cfg.resolved_head_dim
    layout = build_layout(cfg)

    def attn_state():
        shape = (batch, buffer_len, cfg.n_kv_heads, hd)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    def rwkv_state():
        h = cfg.d_model // cfg.rwkv.head_dim
        return (jnp.zeros((batch, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim),
                          jnp.float32),
                jnp.zeros((batch, 1, cfg.d_model), dtype),
                jnp.zeros((batch, 1, cfg.d_model), dtype))

    def mamba_state():
        mc = cfg.mamba
        nh = mc.n_heads(cfg.d_model)
        conv_ch = mc.d_inner(cfg.d_model) + 2 * mc.n_groups * mc.d_state
        return (jnp.zeros((batch, nh, mc.d_state, mc.head_dim), jnp.float32),
                jnp.zeros((batch, mc.d_conv - 1, conv_ch), dtype))

    def cross_state(single_p):
        # precompute vision K/V from params (requires params + vision)
        b_, nv, _ = vision.shape
        k = (vision @ single_p["attn"]["wk"].astype(vision.dtype)).reshape(
            b_, nv, cfg.n_kv_heads, hd)
        v = (vision @ single_p["attn"]["wv"].astype(vision.dtype)).reshape(
            b_, nv, cfg.n_kv_heads, hd)
        return (k.astype(dtype), v.astype(dtype))

    def stack_states(maker, n):
        one = maker()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if layout["kind"] == "uniform":
        maker = {"dense": attn_state, "moe": attn_state,
                 "rwkv": rwkv_state}.get(layout["block"], attn_state)
        return {"layers": stack_states(maker, layout["n"])}

    periods, inner_n = layout["periods"], layout["inner_n"]
    inner_maker = mamba_state if layout["inner_block"] == "mamba" \
        else attn_state
    inner = stack_states(lambda: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (inner_n,) + a.shape), inner_maker()),
        periods)
    if layout["single_block"] == "cross_attn":
        singles = jax.vmap(cross_state)(params["layers"]["single"])
    else:
        singles = stack_states(attn_state, periods)
    trailing = stack_states(inner_maker, max(layout["trailing"], 1))
    return {"inner": inner, "single": singles, "trailing": trailing}
