"""RWKV-6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

Parallel (train/prefill) path uses a chunked GLA-style formulation in pure
jnp (the Pallas kernel in ``repro.kernels.rwkv6`` is the TPU-native version);
decode path carries per-layer state ((B,H,K,V) wkv state + last token).

Recurrence per head (K = V = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t in (0,1)^K data-dependent (decay LoRA), u a learned per-channel
bonus ("first-token" weight).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.blocks import _dense_init


def init_rwkv_layer(cfg: ArchConfig, key):
    r = cfg.rwkv
    d = cfg.d_model
    ks = jax.random.split(key, 16)
    # 5 token-shift mixing coefficients (r,k,v,w,g) + base mix for lora input
    return {
        "mu": 0.5 * jnp.ones((6, d), jnp.float32),   # x-base + r,k,v,w,g
        "shift_lora_a": _dense_init(ks[0], (5, d, r.lora_shift)),
        "shift_lora_b": jnp.zeros((5, r.lora_shift, d), jnp.float32),
        "decay_lora_a": _dense_init(ks[1], (d, r.lora_decay)),
        "decay_lora_b": jnp.zeros((r.lora_decay, d), jnp.float32),
        "decay_base": -6.0 * jnp.ones((d,), jnp.float32),
        "bonus_u": jnp.zeros((d,), jnp.float32),
        "wr": _dense_init(ks[2], (d, d)),
        "wk": _dense_init(ks[3], (d, d)),
        "wv": _dense_init(ks[4], (d, d)),
        "wg": _dense_init(ks[5], (d, d)),
        "wo": _dense_init(ks[6], (d, d)),
        "ln_x": jnp.ones((d,), jnp.float32),   # per-head group norm scale
        # channel mix
        "cm_mu": 0.5 * jnp.ones((2, d), jnp.float32),
        "cm_wk": _dense_init(ks[7], (d, cfg.d_ff)),
        "cm_wv": _dense_init(ks[8], (cfg.d_ff, d), fan_in=cfg.d_ff),
        "cm_wr": _dense_init(ks[9], (d, d)),
    }


def _token_shift(x, last=None):
    """shift right by one along seq; ``last`` (B,1,D) fills position 0."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _ddlerp(p, x, xs):
    """RWKV6 data-dependent token-shift interpolation.

    Returns the five mixed inputs (r,k,v,w,g): each
        x + (xs - x) * (mu_i + lora_i(x + (xs - x) * mu_x))
    """
    dx = xs - x
    base = x + dx * p["mu"][0].astype(x.dtype)
    # 5 branches unrolled (tiny LoRA matmuls)
    outs = []
    for i in range(5):
        lora = jnp.tanh(base @ p["shift_lora_a"][i].astype(x.dtype)) \
            @ p["shift_lora_b"][i].astype(x.dtype)
        mix = p["mu"][i + 1].astype(x.dtype) + lora
        outs.append(x + dx * mix)
    return outs


def _decay(p, xw):
    """per-token decay w_t in (0,1)^D (log-space).  Returns log(w_t) <= 0."""
    lora = jnp.tanh(xw @ p["decay_lora_a"].astype(xw.dtype)) \
        @ p["decay_lora_b"].astype(xw.dtype)
    # (B, S, D), <= 0
    return -jnp.exp((p["decay_base"].astype(jnp.float32)
                     + lora.astype(jnp.float32)))


def _group_norm_heads(x, scale, n_heads, eps=1e-5):
    """GroupNorm over each head's channels. x: (B, S, D)."""
    b, s, d = x.shape
    hx = x.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = hx.mean(-1, keepdims=True)
    var = ((hx - mu) ** 2).mean(-1, keepdims=True)
    hx = (hx - mu) * jax.lax.rsqrt(var + eps)
    return (hx.reshape(b, s, d) * scale).astype(x.dtype)


def wkv6_chunked(r, k, v, logw, u, *, chunk: int = 128):
    """Chunk-parallel WKV6 scan (GLA-style), pure jnp.

    r,k,v: (B, S, H, K); logw: (B, S, H, K) (log decay, <=0); u: (H, K).
    Returns y: (B, S, H, K).  fp32 internals.
    """
    b, s, h, dk = r.shape
    nc = max(s // chunk, 1)
    c = s // nc
    f32 = jnp.float32
    r_, k_, v_, lw = (a.astype(f32).reshape(b, nc, c, h, dk).transpose(1, 0, 3, 2, 4)
                      for a in (r, k, v, logw))   # (nc, B, H, C, K)

    # within-chunk cumulative log decay, exclusive: q_i = sum_{j<i} logw_j
    cum = jnp.cumsum(lw, axis=3)
    cum_excl = cum - lw                       # (nc,B,H,C,K)
    total = cum[:, :, :, -1:, :]              # (nc,B,H,1,K) full-chunk decay

    def body(state, xs):
        rc, kc, vc, ce, tot, lwc = xs          # each (B,H,C,K) etc.
        # inter-chunk: y_inter = (r * exp(ce)) @ state   (ce <= 0: stable)
        rd = rc * jnp.exp(ce)
        y_inter = jnp.einsum("bhck,bhkv->bhcv", rd, state)
        # intra-chunk scores: A_ij = sum_k r_ik k_jk exp(ce_i - cum_j), j<i.
        # ce_i - cum_j <= 0 pairwise, but the factorization exp(ce)*exp(-cum)
        # can overflow alone -> shift both exponents by tot/2 (bounds each
        # factor's exponent by |tot|/2).
        rds = rc * jnp.exp(ce - 0.5 * tot)
        ki = kc * jnp.exp(0.5 * tot - (ce + lwc))   # k_j * exp(tot/2 - cum_j)
        att = jnp.einsum("bhck,bhjk->bhcj", rds, ki)
        idx = jnp.arange(rc.shape[2])
        mask = idx[:, None] > idx[None, :]
        att = att * mask[None, None]
        # diagonal: bonus u term  y_i += (r_i . (u * k_i)) v_i
        diag = jnp.einsum("bhck,bhck->bhc", rc, kc * u.astype(f32)[None, :, None, :])
        y = y_inter + jnp.einsum("bhcj,bhjv->bhcv", att, vc) \
            + diag[..., None] * vc
        # state update: S' = diag(exp(tot)) S + sum_j exp(tot - cum_j) k_j v_j
        kdec = kc * jnp.exp(tot - (ce + lwc))
        state = jnp.exp(tot).transpose(0, 1, 3, 2) * state \
            + jnp.einsum("bhck,bhcv->bhkv", kdec, vc)
        return state, y

    state0 = jnp.zeros((b, h, dk, dk), f32)
    _, ys = jax.lax.scan(body, state0, (r_, k_, v_, cum_excl, total, lw))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dk)
    return y.astype(r.dtype)


def wkv6_recurrent(r, k, v, logw, u, state):
    """Single-token decode. r,k,v,logw: (B, 1, H, K); state (B,H,K,V)."""
    f32 = jnp.float32
    rt, kt, vt, lwt = (a.astype(f32)[:, 0] for a in (r, k, v, logw))  # (B,H,K)
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    y = jnp.einsum("bhk,bhkv->bhv", rt, state + u.astype(f32)[None, :, :, None] * kv)
    state = jnp.exp(lwt)[..., None] * state + kv
    return y[:, None].astype(r.dtype), state


def rwkv_time_mix(p, x, cfg: ArchConfig, *, state=None, last_x=None):
    """Time-mix sub-block. state: (wkv_state, last_token) for decode."""
    r_cfg = cfg.rwkv
    b, s, d = x.shape
    h = d // r_cfg.head_dim
    cd = x.dtype
    xs = _token_shift(x, last_x)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xs)
    r = (xr @ p["wr"].astype(cd)).reshape(b, s, h, r_cfg.head_dim)
    k = (xk @ p["wk"].astype(cd)).reshape(b, s, h, r_cfg.head_dim)
    v = (xv @ p["wv"].astype(cd)).reshape(b, s, h, r_cfg.head_dim)
    g = jax.nn.silu(xg @ p["wg"].astype(cd))
    logw = _decay(p, xw).reshape(b, s, h, r_cfg.head_dim)
    u = p["bonus_u"].reshape(h, r_cfg.head_dim)

    if state is None:
        y = wkv6_chunked(r, k, v, logw.astype(jnp.float32), u,
                         chunk=r_cfg.chunk)
        new_state = None
    else:
        y, new_wkv = wkv6_recurrent(r, k, v, logw, u, state)
        new_state = new_wkv
    y = y.reshape(b, s, d)
    y = _group_norm_heads(y, p["ln_x"].astype(jnp.float32), h)
    out = (y * g) @ p["wo"].astype(cd)
    return out, new_state


def rwkv_channel_mix(p, x, *, last_x=None):
    cd = x.dtype
    xs = _token_shift(x, last_x)
    dx = xs - x
    xk = x + dx * p["cm_mu"][0].astype(cd)
    xr = x + dx * p["cm_mu"][1].astype(cd)
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(cd)))
    return jax.nn.sigmoid(xr @ p["cm_wr"].astype(cd)) * (k @ p["cm_wv"].astype(cd))
