from repro.models import blocks, mamba, model, rwkv, transformer
