"""Gradient compression with error feedback (distributed-optimization trick).

Two pieces:
  * ``compress``/``decompress`` + ``error_feedback``: bf16 or int8
    (per-tensor scale) gradient quantization with residual carry-over, so
    the optimizer sees what a compressed all-reduce would deliver.
  * ``compressed_psum``: a shard_map building block performing the actual
    low-precision all-reduce on a real mesh axis (used by the pod-DP path;
    validated in tests on a host-device mesh).
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp


def compress(g, kind: Literal["bf16", "int8"] = "bf16"):
    if kind == "bf16" or g.size == 0:
        return g.astype(jnp.bfloat16), None
    # int8: symmetric per-tensor scale
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q, scale, dtype=jnp.float32):
    if scale is None:
        return q.astype(dtype)
    return q.astype(dtype) * scale


def compress_grads_with_feedback(grads, residuals, kind="bf16"):
    """Returns (compressed-then-decompressed grads, new residuals).

    Error feedback: residual_t+1 = g + residual_t - Q(g + residual_t); the
    quantization error re-enters the next step instead of being lost.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = compress(g32, kind)
        deq = decompress(q, scale)
        return deq, g32 - deq

    out = jax.tree.map(one, grads, residuals)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_r = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_r


def init_residuals(grads_shape):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)


def compressed_psum(x, axis_name: str, kind: Literal["bf16", "int8"] = "bf16"):
    """All-reduce in low precision inside shard_map: quantize locally,
    all-gather the compressed shards, dequantize + sum in fp32.

    Halves (bf16) or quarters (int8) the bytes on the wire vs fp32 psum at
    the cost of an all-gather layout; on slow inter-pod links this is the
    standard trade (1-bit Adam / DALL-E bf16-allreduce lineage).
    """
    q, scale = compress(x, kind)
    qs = jax.lax.all_gather(q, axis_name)            # (n, ...) compressed
    if scale is not None:
        scales = jax.lax.all_gather(scale, axis_name)
        return jnp.sum(qs.astype(jnp.float32)
                       * scales.reshape((-1,) + (1,) * x.ndim), axis=0)
    return jnp.sum(qs.astype(jnp.float32), axis=0)
