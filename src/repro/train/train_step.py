"""Train-step factory: loss -> (micro-batched) grads -> compression hook ->
AdamW update. The returned function is pure and jit/pjit-able; the launcher
binds shardings."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.train import compression as C
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "full"              # none | full | dots
    attn_impl: str = "xla"           # xla | pallas | pallas-interpret
    grad_compression: Optional[str] = None    # None | bf16 | int8
    compute_dtype: str = "bfloat16"
    # cast params once per step BEFORE the layer scan: FSDP gathers then
    # move bf16 instead of fp32 master shards (halves gather bytes)
    param_stream_dtype: Optional[str] = None   # None | bfloat16
    # store params in bf16 with fp32 masters inside the optimizer state
    # (production mixed precision; gathers/matmuls stream bf16 natively)
    master_weights: bool = False


def make_loss_fn(cfg: ArchConfig, tcfg: TrainConfig):
    cd = jnp.bfloat16 if tcfg.compute_dtype == "bfloat16" else jnp.float32

    def loss_fn(params, batch):
        if tcfg.param_stream_dtype == "bfloat16":
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
        seq = batch["tokens"].shape[1]
        ctx = M.make_ctx(cfg, seq, "train", attn_impl=tcfg.attn_impl,
                         remat=tcfg.remat, vision=batch.get("vision"),
                         compute_dtype=cd)
        return M.loss_fn(params, batch, cfg, ctx)

    return loss_fn


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig,
                    ocfg: OptimizerConfig):
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        k = tcfg.microbatches
        micro = jax.tree.map(
            lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]), batch)

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc,
                               {"loss": loss, "grads": grads})
            return acc, metrics

        zero = {"loss": jnp.zeros((), jnp.float32),
                "grads": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)}
        acc, metrics = jax.lax.scan(body, zero, micro)
        grads = jax.tree.map(lambda g: g / k, acc["grads"])
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return acc["loss"] / k, metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if tcfg.grad_compression:
            grads, new_res = C.compress_grads_with_feedback(
                grads, opt_state["residuals"], tcfg.grad_compression)
        params, new_opt, opt_metrics = adamw_update(
            ocfg, params, grads,
            {k: v for k, v in opt_state.items() if k != "residuals"})
        if tcfg.grad_compression:
            new_opt["residuals"] = new_res
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, new_opt, metrics

    return train_step


def make_opt_state(params, tcfg: TrainConfig):
    state = init_opt_state(params, master_weights=tcfg.master_weights)
    if tcfg.grad_compression:
        state["residuals"] = C.init_residuals(params)
    return state
