"""AdamW + global-norm clipping + cosine schedule, from scratch (no optax).

Optimizer state is a pytree shaped like the params; ``opt_state_specs``
derives ZeRO-1 sharding (first moments/second moments additionally sharded
over the data axis when a dimension divides evenly) — the classic
distributed-optimizer memory saving.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params, master_weights: bool = False):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    state = {"mu": zeros,
             "nu": jax.tree.map(jnp.zeros_like, zeros),
             "step": jnp.zeros((), jnp.int32)}
    if master_weights:
        # params live in bf16 (collectives/matmuls stream bf16); the fp32
        # truth lives here, sharded like the moments (ZeRO)
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptimizerConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics). With a "master" entry
    in opt_state the update is computed on the fp32 masters and params are
    re-emitted at their storage dtype (bf16 mixed-precision training)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    masters = opt_state.get("master")
    base = masters if masters is not None else params

    def upd(p, out_dtype, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:                       # decoupled WD on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new32 = p.astype(jnp.float32) - lr * delta
        return new32.astype(out_dtype), new32, mu, nu

    dtypes = jax.tree.map(lambda p: p.dtype, params)
    out = jax.tree.map(upd, base, dtypes, grads, opt_state["mu"],
                       opt_state["nu"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    new_params = pick(0)
    new_state = {"mu": pick(2), "nu": pick(3), "step": step}
    if masters is not None:
        new_state["master"] = pick(1)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of the optimizer state
# ---------------------------------------------------------------------------

def opt_state_specs(param_specs, param_shapes, rules=None,
                    zero: bool = True):
    """Derive opt-state PartitionSpecs. With ``zero`` and a 'data' axis in
    the rules, moments get one additional dim sharded over data (ZeRO-1)."""
    from repro.sharding.rules import current_rules
    rules = rules or current_rules()
    zero_axes = rules.table.get("zero", ()) if (rules and zero) else ()
    zero_size = 1
    if rules and zero_axes:
        zero_size = int(rules.mesh.shape[zero_axes[0]])

    def one(spec, shape):
        if not zero_axes or zero_size <= 1 or shape is None:
            return spec
        flat_axes = []
        for entry in spec:
            flat_axes.extend(entry if isinstance(entry, tuple) else [entry])
        if zero_axes[0] in flat_axes:      # FSDP params: already data-sharded
            return spec
        parts = list(spec) + [None] * (len(shape.shape) - len(spec))
        for i, (ax, dim) in enumerate(zip(parts, shape.shape)):
            if ax is None and dim % zero_size == 0 and dim >= zero_size:
                parts[i] = zero_axes[0]
                return P(*parts)
        return spec

    moment_specs = jax.tree.map(one, param_specs, param_shapes,
                                is_leaf=lambda x: isinstance(x, P))
    return {"mu": moment_specs, "nu": moment_specs, "step": P()}
