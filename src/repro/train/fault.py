"""Fault tolerance: checkpoint/restart supervision + straggler watchdog.

``TrainSupervisor`` wraps a step function with (a) periodic checkpointing
through the data lake, (b) automatic restore-and-continue on failures
(injectable for tests; on a real pod this is the coordinator restart path),
and (c) a step-time watchdog implementing the paper's straggler policy at
training-step granularity (a step slower than ``straggler_factor`` x the
running median is flagged; on real fleets the launcher would reschedule the
slow host — here we record + expose the signal).

Scheduler preemption ties in here: a checkpoint-aware preemption
(``Scheduler.preempt``) delivers a cooperative signal through the
runner's ``Job.preempt_flag``; ``preemption_hook(job)`` turns that flag
into the ``JobPreempted`` the supervisor (or the agent) already handles,
so a preempted training job stops at a step boundary with its latest
checkpoint saved and the relaunch restores via elastic restore instead
of restarting from step 0. ``JobPreempted`` itself lives in
``core/engine/lifecycle.py`` (the engine must recognize it without
importing the jax-backed train stack) and is re-exported here for
backwards compatibility.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional

from repro.core.engine.lifecycle import (  # noqa: F401 (re-exports)
    JobPreempted, TransientJobError)
from repro.train.checkpoints import CheckpointManager


def preemption_hook(job) -> Callable[[int], None]:
    """A ``TrainSupervisor.run(failure_hook=...)`` adapter for the
    engine's cooperative checkpoint signal: raises ``JobPreempted`` at
    the next step boundary once the scheduler preempts ``job``. The
    preemption-capable runners treat the raise as a hand-back (the job
    re-queues and resumes from its last checkpoint), not a failure.

    Create the hook at the *start* of each incarnation (inside the job
    fn): it captures the incarnation's epoch, so a worker superseded by
    a relaunch still observes its preemption even though the relaunch
    installed a fresh (unset) ``preempt_flag`` on the shared Job —
    polling the flag alone would race that replacement and miss the
    signal."""
    epoch0 = getattr(job, "epoch", 0)

    def hook(step: int) -> None:
        flag = getattr(job, "preempt_flag", None)
        if getattr(job, "epoch", 0) != epoch0 or \
                (flag is not None and flag.is_set()):
            exc = JobPreempted(
                f"{job.job_id} preempted at step {step}")
            # external (scheduler-driven) preemptions must propagate out
            # of the supervisor — the process hands capacity back and the
            # *relaunch* restores; restarting in-process would keep the
            # revoked reservation busy
            exc.external = True
            raise exc
    return hook


def gang_resize_hook(job) -> Callable[[int], None]:
    """A ``failure_hook`` adapter for elastic gang shrink-to-k.

    When the scheduler shrinks a resizable gang (``Scheduler.shrink_gang``
    lowers ``job.gang_pods`` without preempting), the training process
    keeps its reservation — it just lost pods. The right reaction is an
    *in-process* re-mesh: raise a non-external ``JobPreempted`` so
    ``TrainSupervisor.run`` restores the latest checkpoint onto the
    shrunken mesh (``CheckpointManager.restore`` reshards onto any mesh)
    and continues, rather than handing the surviving capacity back.

    The hook tracks the last width it acted on, so each shrink fires
    exactly once; compose with :func:`preemption_hook` when the job also
    needs the hand-back path::

        pre, res = preemption_hook(job), gang_resize_hook(job)
        def hook(step):
            pre(step); res(step)
    """
    state = {"w": getattr(job, "gang_pods", None)}

    def hook(step: int) -> None:
        w = getattr(job, "gang_pods", None)
        if w is not None and state["w"] is not None and w < state["w"]:
            state["w"] = w
            raise JobPreempted(
                f"{job.job_id} gang resized to {w} pods at step {step}")
        state["w"] = w
    return hook


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    checkpoints: int = 0
    straggler_steps: list = dataclasses.field(default_factory=list)
    final_step: int = 0


class TrainSupervisor:
    def __init__(self, ckpt: CheckpointManager, *, save_every: int = 10,
                 straggler_factor: float = 3.0, max_restarts: int = 10):
        self.ckpt = ckpt
        self.save_every = save_every
        self.straggler_factor = straggler_factor
        self.max_restarts = max_restarts

    def run(self, step_fn: Callable, state: dict, n_steps: int,
            batch_fn: Callable[[int], dict],
            failure_hook: Optional[Callable[[int], None]] = None,
            time_fn: Callable[[], float] = time.perf_counter,
            ) -> tuple[dict, SupervisorReport]:
        """state: {"params":..., "opt":..., "step": int}."""
        report = SupervisorReport()
        step_times: list[float] = []
        step = state["step"]
        while step < n_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)       # may raise JobPreempted
                t0 = time_fn()
                params, opt, metrics = step_fn(state["params"],
                                               state["opt"], batch_fn(step))
                dt = time_fn() - t0
                state = {"params": params, "opt": opt, "step": step + 1}
                report.steps_run += 1
                if len(step_times) >= 3:
                    med = statistics.median(step_times)
                    if dt > self.straggler_factor * med:
                        report.straggler_steps.append(step)
                step_times.append(dt)
                step += 1
                if step % self.save_every == 0 or step == n_steps:
                    self.ckpt.save(step, state["params"], state["opt"],
                                   extra={"loss": float(metrics["loss"])})
                    report.checkpoints += 1
            except JobPreempted as e:
                if getattr(e, "external", False):
                    raise   # scheduler preemption: hand back the slot;
                            # the relaunch restores from the checkpoint
                report.restarts += 1
                if report.restarts > self.max_restarts:
                    raise
                restored, ck_step = self._restore_or_initial(state)
                state = restored
                step = ck_step
        report.final_step = step
        return state, report

    def _restore_or_initial(self, template_state):
        last = self.ckpt.latest_step()
        if last is None:
            return {"params": template_state["params"],
                    "opt": template_state["opt"], "step": 0}, 0
        st, step = self.ckpt.restore({"params": template_state["params"],
                                      "opt": template_state["opt"]})
        return {"params": st["params"], "opt": st["opt"], "step": step}, step
