"""Fault tolerance: checkpoint/restart supervision + straggler watchdog.

``TrainSupervisor`` wraps a step function with (a) periodic checkpointing
through the data lake, (b) automatic restore-and-continue on failures
(injectable for tests; on a real pod this is the coordinator restart path),
and (c) a step-time watchdog implementing the paper's straggler policy at
training-step granularity (a step slower than ``straggler_factor`` x the
running median is flagged; on real fleets the launcher would reschedule the
slow host — here we record + expose the signal)."""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional

from repro.train.checkpoints import CheckpointManager


class JobPreempted(RuntimeError):
    """Simulated node failure / preemption."""


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    checkpoints: int = 0
    straggler_steps: list = dataclasses.field(default_factory=list)
    final_step: int = 0


class TrainSupervisor:
    def __init__(self, ckpt: CheckpointManager, *, save_every: int = 10,
                 straggler_factor: float = 3.0, max_restarts: int = 10):
        self.ckpt = ckpt
        self.save_every = save_every
        self.straggler_factor = straggler_factor
        self.max_restarts = max_restarts

    def run(self, step_fn: Callable, state: dict, n_steps: int,
            batch_fn: Callable[[int], dict],
            failure_hook: Optional[Callable[[int], None]] = None,
            time_fn: Callable[[], float] = time.perf_counter,
            ) -> tuple[dict, SupervisorReport]:
        """state: {"params":..., "opt":..., "step": int}."""
        report = SupervisorReport()
        step_times: list[float] = []
        step = state["step"]
        while step < n_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)       # may raise JobPreempted
                t0 = time_fn()
                params, opt, metrics = step_fn(state["params"],
                                               state["opt"], batch_fn(step))
                dt = time_fn() - t0
                state = {"params": params, "opt": opt, "step": step + 1}
                report.steps_run += 1
                if len(step_times) >= 3:
                    med = statistics.median(step_times)
                    if dt > self.straggler_factor * med:
                        report.straggler_steps.append(step)
                step_times.append(dt)
                step += 1
                if step % self.save_every == 0 or step == n_steps:
                    self.ckpt.save(step, state["params"], state["opt"],
                                   extra={"loss": float(metrics["loss"])})
                    report.checkpoints += 1
            except JobPreempted:
                report.restarts += 1
                if report.restarts > self.max_restarts:
                    raise
                restored, ck_step = self._restore_or_initial(state)
                state = restored
                step = ck_step
        report.final_step = step
        return state, report

    def _restore_or_initial(self, template_state):
        last = self.ckpt.latest_step()
        if last is None:
            return {"params": template_state["params"],
                    "opt": template_state["opt"], "step": 0}, 0
        st, step = self.ckpt.restore({"params": template_state["params"],
                                      "opt": template_state["opt"]})
        return {"params": st["params"], "opt": st["opt"], "step": step}, step
