"""Pipeline parallelism (GPipe schedule) over a mesh "stage" axis.

Across pods the inter-pod ICI links are the slow dimension, so the right
parallelism across them is pipelining: each pod (or pod-slice) holds a
contiguous block of layers and microbatch activations flow stage-to-stage
via ``jax.lax.ppermute`` inside ``shard_map``.

``pipeline_apply`` runs the canonical schedule: with S stages and M
microbatches, T = M + S - 1 ticks; stage s computes microbatch t-s at tick
t; activations hop one stage per tick (bubble fraction (S-1)/T). The layer
stack must be expressible as S identical-signature stage functions over
stacked per-stage params — exactly the shape of our scan-over-layers
models.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                   axis: str = "stage", n_microbatches: int):
    """Run x through S pipelined stages.

    stage_fn(params_slice, activation) -> activation; stage_params: pytree
    stacked on a leading S dim (sharded P(axis, ...)); x: (batch, ...)
    with batch % n_microbatches == 0. Returns stage_fn applied S times.
    """
    s = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0
    mb = b // n_microbatches
    micro = x.reshape((n_microbatches, mb) + x.shape[1:])

    def body(params_local, micro_local):
        # params_local: (1, ...) this stage's slice; micro_local: the full
        # microbatch stream (replicated across stages)
        params_here = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        ticks = n_microbatches + s - 1

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            feed = micro_local[jnp.minimum(t, n_microbatches - 1)]
            cur = jnp.where(sid == 0, feed, buf)
            y = stage_fn(params_here, cur)
            # last stage commits its result for microbatch t-(S-1)
            out_idx = t - (s - 1)
            commit = (sid == s - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                commit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            # hop: stage i -> i+1 (ring permute; the wraparound value into
            # stage 0 is ignored — stage 0 always reads the feed)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s) for i in range(s)])
            return (nxt, outs), None

        buf0 = jnp.zeros_like(micro_local[0])
        outs0 = jnp.zeros_like(micro_local)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(ticks))
        # every stage returns outs; only the last stage's is real — take it
        # via a psum of masked values (others contribute zeros)
        outs = jnp.where(sid == s - 1, outs, 0)
        return jax.lax.psum(outs, axis)

    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),      # params stage-sharded, micro replicated
        out_specs=P(),
        check_rep=False,
    )(stage_params, micro)
    return out.reshape((b,) + out.shape[2:])


def sequential_apply(stage_fn: Callable, stage_params, x):
    """Reference: the same stages applied serially (oracle for tests)."""
    def body(carry, p):
        return stage_fn(p, carry), None
    y, _ = jax.lax.scan(body, x, stage_params)
    return y
