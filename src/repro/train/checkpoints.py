"""Fault-tolerant, datalake-versioned checkpoints with elastic restore.

Checkpoints are ACAI filesets ("<run>-ckpt" versions), written through a
transactional upload session (a crashed save never becomes a visible
version) with provenance edges from the training job. Restore reshards onto
ANY mesh: arrays are saved unsharded-logical (global shape) and re-placed
with the target mesh's NamedShardings — elastic scaling across restarts.
"""
from __future__ import annotations

import io
import json
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.acai import AcaiProject


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _unflatten_like(template, flat: dict[str, Any], cast: bool = False):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for path, tmpl_leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaf = flat[key]
        if cast and hasattr(tmpl_leaf, "dtype"):
            leaf = np.asarray(leaf).astype(tmpl_leaf.dtype)
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def _np_savable(v) -> np.ndarray:
    """npz cannot hold bf16; widen to fp32 (dtype restored from template)."""
    arr = np.asarray(v)
    if arr.dtype == jnp.bfloat16:
        arr = arr.astype(np.float32)
    return arr


class CheckpointManager:
    def __init__(self, project: AcaiProject, run_name: str,
                 keep: int = 3):
        self.project = project
        self.run = run_name
        self.keep = keep

    @property
    def fileset(self) -> str:
        return f"{self.run}-ckpt"

    # ------------------------------------------------------------------
    def save(self, step: int, params, opt_state=None,
             extra: Optional[dict] = None, job_id: Optional[str] = None,
             input_fileset: Optional[str] = None) -> str:
        state = {"params": params}
        if opt_state is not None:
            state["opt"] = opt_state
        flat = _flatten(state)
        buf = io.BytesIO()
        np.savez(buf, **{k: _np_savable(v) for k, v in flat.items()})
        manifest = {"step": step, "keys": sorted(flat),
                    "extra": extra or {}}
        storage = self.project.storage
        paths = [f"/{self.fileset}/state.npz", f"/{self.fileset}/manifest.json"]
        sid = storage.begin_session(paths, creator="trainer")
        storage.session_put(sid, paths[0], buf.getvalue())
        storage.session_put(sid, paths[1], json.dumps(manifest).encode())
        fvs = storage.commit_session(sid)
        fsv = self.project.filesets.create(
            self.fileset, [f"{fv.path}@{fv.version}" for fv in fvs],
            creator="trainer")
        self.project.metadata.register(fsv.ref, kind="checkpoint",
                                       step=step, run=self.run,
                                       **(extra or {}))
        if job_id is not None:
            src = None
            if input_fileset:
                src = self.project.filesets.resolve(input_fileset).ref
            self.project.provenance.add_job_edge(src=src, dst=fsv.ref,
                                                 job_id=job_id)
        return fsv.ref

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        if not self.project.filesets.exists(self.fileset):
            return None
        ref = self.project.filesets.resolve(self.fileset).ref
        return self.project.metadata.get(ref).get("step")

    def restore(self, template, *, version: Optional[int] = None,
                mesh=None, specs=None):
        """Rebuild ``template``-shaped state. With (mesh, specs) the arrays
        are placed sharded on the target mesh — any device count (elastic).
        Returns (state, step)."""
        ref = self.fileset if version is None else \
            f"{self.fileset}:{version}"
        fsv = self.project.filesets.resolve(ref)
        raw = self.project.storage._get_blob(
            self.project.storage.resolve(
                f"/{self.fileset}/state.npz",
                fsv.files[f"/{self.fileset}/state.npz"]).blob)
        man = json.loads(self.project.storage._get_blob(
            self.project.storage.resolve(
                f"/{self.fileset}/manifest.json",
                fsv.files[f"/{self.fileset}/manifest.json"]).blob))
        npz = np.load(io.BytesIO(raw))
        flat = {k: npz[k] for k in npz.files}
        state = _unflatten_like(template, flat, cast=True)
        if mesh is not None and specs is not None:
            flat_spec = _flatten(specs)
            placed = {}
            for key, arr in _flatten(state).items():
                spec = flat_spec.get(key)
                if spec is not None:
                    placed[key] = jax.device_put(
                        arr, NamedSharding(mesh, spec))
                else:
                    placed[key] = jnp.asarray(arr)
            state = _unflatten_like(template, placed)
        else:
            state = jax.tree.map(jnp.asarray, state)
        return state, man["step"]
