"""Data pipeline: deterministic sharded synthetic token stream + datalake
registration.

Every shard is reproducible from (dataset_seed, shard_index, step): training
can restart anywhere without replaying the stream, and elastic rescaling
re-partitions shards across a different host count deterministically. The
dataset identity (seed, vocab, seq) is registered as a fileset so training
jobs get provenance edges from their data."""
from __future__ import annotations

import dataclasses
import json
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 8
    n_hosts: int = 1
    host_index: int = 0
    # markov-chain order-1 synthetic language (learnable structure)
    markov_temp: float = 1.5


class TokenPipeline:
    """Order-1 Markov synthetic LM data (has learnable statistics, so loss
    decreases measurably during the e2e example runs)."""

    def __init__(self, cfg: DataConfig, arch: Optional[ArchConfig] = None):
        self.cfg = cfg
        self.arch = arch
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        logits = rng.normal(0, cfg.markov_temp, (v, v))
        self.trans = np.exp(logits - logits.max(1, keepdims=True))
        self.trans /= self.trans.sum(1, keepdims=True)
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def _sample_rows(self, rng, n, s):
        v = self.cfg.vocab_size
        rows = np.empty((n, s + 1), np.int32)
        rows[:, 0] = rng.integers(0, v, n)
        # vectorized markov walk via inverse-CDF sampling
        cdf = np.cumsum(self.trans, axis=1)
        for t in range(s):
            u = rng.random(n)
            rows[:, t + 1] = (cdf[rows[:, t]] < u[:, None]).sum(1)
        return rows

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for (host, step): restart-safe."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, c.host_index, step, 0xACA1))
        rows = self._sample_rows(rng, self.local_batch, c.seq_len)
        batch = {"tokens": rows[:, :-1], "labels": rows[:, 1:]}
        if self.arch is not None and self.arch.n_codebooks:
            k = self.arch.n_codebooks
            rng2 = np.random.default_rng((c.seed, c.host_index, step, 1))
            toks = rng2.integers(0, c.vocab_size,
                                 (self.local_batch, c.seq_len, k),
                                 dtype=np.int32)
            batch = {"tokens": toks,
                     "labels": np.roll(toks, -1, axis=1)}
        if self.arch is not None and self.arch.family == "vlm":
            rng3 = np.random.default_rng((c.seed, c.host_index, step, 2))
            batch["vision"] = rng3.normal(
                0, 1, (self.local_batch, self.arch.n_vision_tokens,
                       self.arch.vision_dim)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    # -- datalake registration ------------------------------------------
    def register(self, project, name: str, creator: str = "") -> str:
        spec = dataclasses.asdict(self.cfg)
        ref = project.upload(f"/datasets/{name}.json",
                             json.dumps(spec).encode(), creator)
        return project.create_file_set(name, [f"/datasets/{name}.json"],
                                       creator)
