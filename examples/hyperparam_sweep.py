"""The paper's usability-study workflow (§5.2) end-to-end through the ACAI
SDK: upload data -> create file set -> submit a hyperparameter sweep ->
log-parser auto-tags accuracies -> one indexed query finds the best run ->
provenance traces how its output was produced.

    PYTHONPATH=src python examples/hyperparam_sweep.py
"""
import json
import tempfile

import jax
import jax.numpy as jnp

from repro.core.acai import AcaiPlatform
from repro.core.engine.registry import JobSpec


def train_job(workdir, job):
    cfg = job.spec.args
    data = json.loads((workdir / "data/train.json").read_text())
    x = jnp.asarray(data["x"])
    y = jnp.asarray(data["y"])
    key = jax.random.PRNGKey(cfg["seed"])
    w = jax.random.normal(key, (x.shape[1], cfg["hidden"])) * 0.1
    v = jnp.zeros((cfg["hidden"],))

    def loss(w, v):
        p = jax.nn.sigmoid(jnp.tanh(x @ w) @ v)
        return -jnp.mean(y * jnp.log(p + 1e-7)
                         + (1 - y) * jnp.log(1 - p + 1e-7))

    g = jax.jit(jax.grad(loss, (0, 1)))
    for _ in range(cfg["steps"]):
        gw, gv = g(w, v)
        w, v = w - cfg["lr"] * gw, v - cfg["lr"] * gv
    acc = float(jnp.mean(((jnp.tanh(x @ w) @ v) > 0) == (y > 0.5)))
    (workdir / "out/model.json").write_text(
        json.dumps({"w": w.tolist(), "v": v.tolist()}))
    # the intelligent log parser turns this into queryable metadata
    print(f"[[acai:accuracy={acc},hidden={cfg['hidden']},lr={cfg['lr']}]]")


def main():
    root = tempfile.mkdtemp(prefix="acai-sweep-")
    plat = AcaiPlatform(root)
    admin = plat.create_project(plat.admin_token, "sweep-demo")
    proj = plat.project(admin)

    # 1. dataset into the lake, referenced by a file set
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 16))
    w_true = jax.random.normal(jax.random.PRNGKey(1), (16,))
    y = (x @ w_true > 0).astype(jnp.float32)
    proj.upload("/data/train.json",
                json.dumps({"x": x.tolist(), "y": y.tolist()}).encode(),
                creator="demo")
    proj.create_file_set("TrainSet", ["/data/train.json"], creator="demo")

    # 2. the sweep: 8 jobs, each reads the file set, writes a model fileset
    for i, (h, lr) in enumerate((h, lr) for h in (8, 16, 32, 64)
                                for lr in (0.5, 0.1)):
        plat.submit_job(admin, JobSpec(
            name=f"sweep-{i}", project="", user="", fn=train_job,
            input_fileset="TrainSet", output_fileset=f"model-{i}",
            args={"hidden": h, "lr": lr, "steps": 100, "seed": i},
            resources={"vcpu": 1, "mem_mb": 512}))

    # 3. one indexed query replaces the manual experiment log
    best_id = proj.metadata.find_max("accuracy", kind="job")
    best = proj.metadata.get(best_id)
    print(f"best job: {best_id} acc={best['accuracy']:.3f} "
          f"hidden={best['hidden']} lr={best['lr']} cost=${best['cost']:.6f}")

    # 4. provenance: trace the best model back to its inputs
    eng = plat.engine(admin)
    out_ref = eng.registry.get(best_id).outputs["fileset"]
    print("model fileset:", out_ref)
    print("derived from:", proj.provenance.backward(out_ref))
    print("replay order:", proj.provenance.replay_order(out_ref))
    # range query, as in the paper's exemplar
    good = proj.metadata.find(kind="job", accuracy=(">", 0.9))
    print(f"{len(good)} jobs with accuracy > 0.9")


if __name__ == "__main__":
    main()
