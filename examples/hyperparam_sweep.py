"""The paper's usability-study workflow (§5.2) as a declared Pipeline:
ETL stage -> horizontal hyperparameter sweep (`pipeline.map`) -> report
stage, with zero manual sequencing. Stage edges are inferred from the
dataflow (one stage's output_fileset feeding another's input_fileset),
the scheduler gates each stage on its parents, every handle resolves in
dependency order, and provenance records one edge per declared DAG edge.

    PYTHONPATH=src python examples/hyperparam_sweep.py
"""
import json
import tempfile

import jax
import jax.numpy as jnp

from repro.core.acai import AcaiPlatform
from repro.core.engine.registry import JobSpec


def etl_job(workdir, job):
    """Normalize the raw dump into the training fileset."""
    raw = json.loads((workdir / "raw/dump.json").read_text())
    x = jnp.asarray(raw["x"])
    x = (x - x.mean(axis=0)) / (x.std(axis=0) + 1e-6)
    (workdir / "out/train.json").write_text(
        json.dumps({"x": x.tolist(), "y": raw["y"]}))
    print(f"[[acai:rows={len(raw['y'])}]]")


def train_job(workdir, job):
    cfg = job.spec.args
    data = json.loads((workdir / "TrainSet/train.json").read_text())
    x = jnp.asarray(data["x"])
    y = jnp.asarray(data["y"])
    key = jax.random.PRNGKey(cfg["seed"])
    w = jax.random.normal(key, (x.shape[1], cfg["hidden"])) * 0.1
    v = jnp.zeros((cfg["hidden"],))

    def loss(w, v):
        p = jax.nn.sigmoid(jnp.tanh(x @ w) @ v)
        return -jnp.mean(y * jnp.log(p + 1e-7)
                         + (1 - y) * jnp.log(1 - p + 1e-7))

    g = jax.jit(jax.grad(loss, (0, 1)))
    for _ in range(cfg["steps"]):
        gw, gv = g(w, v)
        w, v = w - cfg["lr"] * gw, v - cfg["lr"] * gv
    acc = float(jnp.mean(((jnp.tanh(x @ w) @ v) > 0) == (y > 0.5)))
    (workdir / "out/model.json").write_text(
        json.dumps({"w": w.tolist(), "v": v.tolist()}))
    # the intelligent log parser turns this into queryable metadata
    print(f"[[acai:accuracy={acc},hidden={cfg['hidden']},lr={cfg['lr']}]]")


def main():
    root = tempfile.mkdtemp(prefix="acai-sweep-")
    plat = AcaiPlatform(root, runner="thread", max_workers=4, quota_k=100)
    admin = plat.create_project(plat.admin_token, "sweep-demo")
    proj = plat.project(admin)

    # 0. only the RAW dump goes to the lake; the pipeline derives the rest
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 16)) * 3.0 + 1.5   # unnormalized
    w_true = jax.random.normal(jax.random.PRNGKey(1), (16,))
    y = ((x - 1.5) @ w_true > 0).astype(jnp.float32)
    proj.upload("/raw/dump.json",
                json.dumps({"x": x.tolist(), "y": y.tolist()}).encode(),
                creator="demo")
    proj.create_file_set("RawDump", ["/raw/dump.json"], creator="demo")

    def report_job(workdir, job):
        """Runs only after every sweep stage: one indexed query replaces
        the manual experiment log."""
        best = proj.metadata.find_max("accuracy", kind="job")
        (workdir / "out/best.json").write_text(
            json.dumps(proj.metadata.get(best) | {"job_id": best}))

    # 1. declare the DAG: ETL -> map sweep -> report. The sweep's edge on
    # ETL and the report handles' ordering need no manual sequencing —
    # TrainSet/model-* dataflow plus after= declare everything.
    pipe = plat.pipeline(admin, name="sweep")
    etl = pipe.stage(JobSpec(
        name="etl", project="", user="", fn=etl_job,
        input_fileset="RawDump", output_fileset="TrainSet",
        resources={"vcpu": 1, "mem_mb": 512}))
    sweep = pipe.map(
        lambda p: JobSpec(
            name=f"train-h{p['hidden']}-lr{p['lr']}", project="", user="",
            fn=train_job, input_fileset="TrainSet",
            output_fileset=f"model-h{p['hidden']}-lr{p['lr']}",
            args={**p, "steps": 100, "seed": p["hidden"]},
            resources={"vcpu": 1, "mem_mb": 512}),
        {"hidden": (8, 16, 32, 64), "lr": (0.5, 0.1)})
    report = pipe.stage(JobSpec(
        name="report", project="", user="", fn=report_job,
        output_fileset="SweepReport",
        resources={"vcpu": 1, "mem_mb": 256}), after=sweep)

    # 2. run: every stage gets a JobHandle future; resolution is DAG-gated
    handles = pipe.run()
    print(f"submitted {len(handles)} stages "
          f"({plat.engine(admin).scheduler.held_count()} held on parents)")
    states = pipe.wait(timeout=600)
    print("terminal states:", [s.value for s in states])

    report.handle.result()          # resolves the report stage (or raises)
    best = json.loads(proj.storage.download("/SweepReport/best.json"))
    print(f"best job: {best['job_id']} acc={best['accuracy']:.3f} "
          f"hidden={best['hidden']} lr={best['lr']} cost=${best['cost']:.6f}")

    # 3. provenance reflects the DECLARED dataflow: one edge per DAG edge
    edges = proj.provenance.dependency_edges(pipeline="sweep")
    print(f"declared DAG edges recorded: {len(edges)} "
          f"(1 etl->train x8, train->report x8)")
    out_ref = plat.engine(admin).registry.get(best["job_id"]) \
        .outputs["fileset"]
    print("best model fileset:", out_ref)
    print("derived from:", proj.provenance.backward(out_ref))

    # 4. failure cascade: a broken ETL upstream-fails its whole subtree
    def bad_etl(workdir, job):
        raise RuntimeError("schema drift in raw dump")

    pipe2 = plat.pipeline(admin, name="broken")
    bad = pipe2.stage(JobSpec(name="bad-etl", project="", user="",
                              fn=bad_etl, output_fileset="Clean2"))
    kids = pipe2.map(
        lambda p: JobSpec(name=f"never-{p['i']}", project="", user="",
                          fn=train_job, input_fileset="Clean2"),
        [{"i": 0}, {"i": 1}])
    pipe2.run()
    print("broken pipeline:",
          {h.spec.name: h.wait(timeout=60).value for h in pipe2.handles})


if __name__ == "__main__":
    main()
