"""Serve a small model with batched requests: prefill via the decode path,
then greedy generation with the KV-cache/SSM-state machinery — the same
serve_step the decode dry-run cells lower.

    PYTHONPATH=src python examples/serve_batch.py [--arch rwkv6-7b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch, list_archs
from repro.models import model as M
from repro.serve.decode import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(42)
    if cfg.n_codebooks:
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len, cfg.n_codebooks), 0,
            cfg.vocab_size)
    else:
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
    vision = None
    if cfg.family == "vlm":
        vision = jax.random.normal(
            jax.random.PRNGKey(7),
            (args.batch, cfg.n_vision_tokens, cfg.vision_dim)
        ).astype(jnp.bfloat16)

    print(f"serving {args.arch} (reduced), batch={args.batch}")
    out = greedy_generate(cfg, params, prompt, args.max_new, vision=vision)
    print("prompt :", prompt[0].tolist())
    print("output :", out[0].tolist())
    assert out.shape[1] == args.max_new
    print("ok — generated", out.shape, "tokens")


if __name__ == "__main__":
    main()
