"""The paper's flagship feature on the TPU grid: profile a training job
template with a (virtual) fleet through the execution engine, fit the
log-linear runtime model, then auto-provision under a cost cap and under a
deadline — including the beyond-paper active-refinement loop.

    PYTHONPATH=src python examples/autoprovision_train.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np

from benchmarks.oracle import job_time
from repro.configs.base import get_arch
from repro.configs.shapes import get_shape
from repro.core.acai import AcaiPlatform
from repro.core.engine.registry import JobSpec
from repro.core.provision.autoprovision import AutoProvisioner
from repro.core.provision.pricing import TPU_PRICING
from repro.core.provision.profiler import CommandTemplate

ARCH, SHAPE = "qwen3-8b", "train_4k"


def main():
    rng = np.random.default_rng(0)
    cfg, shape = get_arch(ARCH), get_shape(SHAPE)

    def true_runtime(c):
        return job_time(cfg, shape, c["steps"], c["chips"], c["hbm_gb"],
                        rng, noise=0.05)

    plat = AcaiPlatform(tempfile.mkdtemp(), virtual=True, quota_k=1000,
                        pricing=TPU_PRICING,
                        oracle=lambda job: true_runtime(job.spec.args))
    admin = plat.create_project(plat.admin_token, "provision-demo")
    # the profiler submits as this token's user (stamped project/user)
    profiler = plat.make_profiler(admin)

    template = CommandTemplate(
        name=f"{ARCH}-train",
        hints={"steps": [50, 100, 200]},
        resource_hints={"chips": [8, 32, 128], "hbm_gb": [4, 8, 16]})
    print(f"profiling fleet: {len(template.grid())} jobs (95% quorum)...")
    profiler.profile(template, lambda c: JobSpec(
        name="prof", project="", user="", args=c,
        resources={k: c[k] for k in ("chips", "hbm_gb")}))
    print(f"virtual fleet time: "
          f"{plat.engine(admin).launcher.now:.0f}s")

    ap = AutoProvisioner(profiler, TPU_PRICING)
    values = {"steps": 500}
    baseline = {"chips": 32, "hbm_gb": 16}
    t_base = true_runtime({**values, **baseline})
    c_base = TPU_PRICING.job_cost(baseline, t_base)
    print(f"baseline {baseline}: {t_base:.0f}s ${c_base:.2f}")

    dec, hist = ap.refined_search(template.name, values,
                                  measure_fn=true_runtime,
                                  objective="runtime", max_cost=c_base)
    if dec.feasible:
        t = true_runtime({**values, **dec.resources})
        print(f"[fix cost, optimize runtime] -> {dec.resources}: {t:.0f}s "
              f"(speedup {t_base/t:.2f}x, {len(hist)} refinement rounds)")
    else:
        # refinement measured the candidate, found the model overshooting
        # past the collective wall, and the refit excludes the whole grid:
        # stay on the baseline rather than bust the budget
        print(f"[fix cost, optimize runtime] -> infeasible after "
              f"{len(hist)} refinement rounds; keeping baseline {baseline}")

    dec, hist = ap.refined_search(template.name, values,
                                  measure_fn=true_runtime,
                                  objective="cost", max_runtime=t_base)
    if dec.feasible:
        t = true_runtime({**values, **dec.resources})
        c = TPU_PRICING.job_cost(dec.resources, t)
        print(f"[fix runtime, optimize cost] -> {dec.resources}: ${c:.2f} "
              f"(saving {100*(1-c/c_base):.1f}%, {len(hist)} rounds)")
    else:
        print(f"[fix runtime, optimize cost] -> infeasible after "
              f"{len(hist)} refinement rounds; keeping baseline {baseline}")


if __name__ == "__main__":
    main()
