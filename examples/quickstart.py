"""Quickstart: train a reduced-config model end-to-end on CPU with the full
production stack — data pipeline, AdamW, remat, datalake-versioned
checkpoints, fault-tolerant supervision, provenance.

    PYTHONPATH=src python examples/quickstart.py [--arch olmo-1b] [--steps 30]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch, list_archs
from repro.core.acai import AcaiEngine, AcaiProject
from repro.core.engine.registry import JobSpec
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import model as M
from repro.train.checkpoints import CheckpointManager
from repro.train.fault import TrainSupervisor
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (TrainConfig, make_opt_state,
                                    make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    print(f"arch={args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model}, "
          f"{cfg.n_params():,} params)")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(remat="full")
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, tcfg, ocfg))
    opt = make_opt_state(params, tcfg)
    pipe = TokenPipeline(DataConfig(vocab_size=32, seq_len=32,
                                    global_batch=16, markov_temp=2.5), cfg)

    workdir = tempfile.mkdtemp(prefix="acai-quickstart-")
    project = AcaiProject("quickstart", workdir)
    data_ref = pipe.register(project, "synthetic-markov", creator="you")
    ckpt = CheckpointManager(project, "quickstart-run")
    sup = TrainSupervisor(ckpt, save_every=10)

    def batch_fn(i):
        return jax.tree.map(jnp.asarray, pipe.batch_at(i))

    state, report = sup.run(step, {"params": params, "opt": opt, "step": 0},
                            args.steps, batch_fn)
    print(f"ran {report.steps_run} steps, {report.checkpoints} checkpoints,"
          f" {report.restarts} restarts")

    # the checkpoint is a versioned fileset with metadata + provenance
    latest = ckpt.latest_step()
    restored, rstep = ckpt.restore({"params": state["params"],
                                    "opt": state["opt"]})
    print(f"latest checkpoint step={latest}; restored step={rstep}")
    print("datalake filesets:", project.filesets.list_sets())
    ids = project.metadata.find(kind="checkpoint")
    print("checkpoint metadata:", {i: project.metadata.get(i).get('loss')
                                   for i in ids[-2:]})

    # evaluation as a platform job: submit returns a JobHandle future and
    # .result() resolves it — no run_all(), no manual sequencing
    eng = AcaiEngine(datalake=project, workroot=workdir + "/jobs")

    def eval_job(wd, job):
        n_params = sum(p.size for p in jax.tree.leaves(restored["params"]))
        print(f"[[acai:eval_params={n_params},ckpt_step={rstep}]]")
        return {"params": int(n_params)}

    handle = eng.submit(JobSpec(name="eval", project="quickstart",
                                user="you", fn=eval_job,
                                resources={"vcpu": 1, "mem_mb": 512}))
    print(f"eval job {handle.job_id}: {handle.result()['params']:,} params "
          f"verified from checkpoint step {rstep}")


if __name__ == "__main__":
    main()
