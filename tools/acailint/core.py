"""Shared infrastructure for the acailint checkers.

Every checker consumes :class:`SourceFile` objects (parsed AST + the
comment map the annotation conventions live in) and yields
:class:`Violation` records. Suppression happens in one place
(:func:`apply_suppressions`):

- inline: ``# acailint: disable=ACAI101 -- <justification>`` on the
  violating line (or on its own line immediately above). A disable
  without a justification is itself an error (ACAI001) — the point of
  the suite is that every exception to an invariant is argued for.
- baseline: a file of ``<path-suffix>:<CODE>`` lines
  (:func:`load_baseline`); matching violations are dropped. The
  checked-in baseline for ``core/engine`` must stay empty — new
  violations get fixed, not recorded.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import tokenize
from pathlib import Path
from typing import Iterable, Optional

#: codes emitted by the infrastructure itself (not a checker)
BAD_SUPPRESSION = "ACAI001"


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


class SourceFile:
    """One parsed python file: AST, raw lines, and per-line comments."""

    def __init__(self, path: str, text: str):
        self.path = str(Path(path).as_posix())
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=self.path)
        self.comments: dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenError:        # torn file: AST parsed, so the
            pass                           # comment map is merely partial

    @classmethod
    def load(cls, path: str | Path) -> "SourceFile":
        return cls(str(path), Path(path).read_text())

    def comment(self, lineno: int) -> str:
        return self.comments.get(lineno, "")

    def endswith(self, suffix: str) -> bool:
        return self.path.endswith(suffix)


def parse_disables(sf: SourceFile) -> tuple[dict[int, set[str]],
                                            list[Violation]]:
    """Per-line disabled codes from ``# acailint: disable=...`` comments.

    A comment on its own line applies to the next source line as well
    (so multi-line statements can carry their suppression above). Returns
    the map plus ACAI001 violations for disables missing a justification.
    """
    disabled: dict[int, set[str]] = {}
    errors: list[Violation] = []
    for lineno, comment in sf.comments.items():
        marker = "acailint: disable="
        if marker not in comment:
            continue
        rest = comment.split(marker, 1)[1]
        codes_part, sep, why = rest.partition("--")
        codes = {c.strip() for c in codes_part.split(",") if c.strip()}
        if not sep or not why.strip():
            errors.append(Violation(
                sf.path, lineno, BAD_SUPPRESSION,
                "acailint disable without a justification: write "
                "'# acailint: disable=CODE -- why this is safe'"))
            continue
        own_line = sf.lines[lineno - 1].lstrip().startswith("#") \
            if lineno <= len(sf.lines) else False
        targets = [lineno, lineno + 1] if own_line else [lineno]
        for ln in targets:
            disabled.setdefault(ln, set()).update(codes)
    return disabled, errors


def apply_suppressions(files: Iterable[SourceFile],
                       violations: list[Violation],
                       baseline: Optional[set[tuple[str, str]]] = None
                       ) -> list[Violation]:
    """Filter inline-disabled and baselined violations; surface malformed
    suppression comments as ACAI001."""
    by_path: dict[str, dict[int, set[str]]] = {}
    out: list[Violation] = []
    for sf in files:
        disabled, errors = parse_disables(sf)
        by_path[sf.path] = disabled
        out.extend(errors)
    for v in violations:
        codes = by_path.get(v.path, {}).get(v.line, set())
        if v.code in codes:
            continue
        if baseline and any(v.path.endswith(suffix) and v.code == code
                            for suffix, code in baseline):
            continue
        out.append(v)
    out.sort(key=lambda v: (v.path, v.line, v.code))
    return out


def load_baseline(path: str | Path) -> set[tuple[str, str]]:
    """Baseline entries: one ``<path-suffix>:<CODE>`` per line; blank
    lines and ``#`` comments ignored."""
    entries: set[tuple[str, str]] = set()
    p = Path(path)
    if not p.exists():
        return entries
    for raw in p.read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        suffix, _, code = line.rpartition(":")
        if suffix and code:
            entries.add((suffix, code))
    return entries


# -- small AST helpers shared by checkers --------------------------------
def attr_chain(node: ast.AST) -> list[str]:
    """``self.registry.set_state`` -> ["self", "registry", "set_state"];
    empty when the expression is not a plain name/attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


def call_name(call: ast.Call) -> str:
    """Trailing name of the called expression (``x.y.publish`` ->
    ``publish``; bare ``publish(...)`` -> ``publish``)."""
    func = call.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def jobstate_member(node: ast.AST) -> Optional[str]:
    """``JobState.FINISHED`` (or ``lifecycle.JobState.FINISHED``) -> the
    member name; None for anything else."""
    chain = attr_chain(node)
    if len(chain) >= 2 and chain[-2] == "JobState":
        return chain[-1]
    return None


def functions_of(tree: ast.AST) -> list[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def classes_of(tree: ast.AST) -> list[ast.ClassDef]:
    return [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]
