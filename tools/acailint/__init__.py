"""acailint — engine-invariant static analysis for the ACAI control
plane.

Run as ``python -m tools.acailint src``. The checkers are AST-based and
pin the concurrency/durability contracts of ``src/repro/core/engine``:
lock discipline (ACAI1xx), epoch guards (ACAI2xx), journal/codec
coverage (ACAI3xx), reserve/release pairing (ACAI4xx) and lifecycle
transition closure (ACAI5xx). See ``docs/invariants.md`` for the full
catalogue and ``--explain CODE`` for any one of them.
"""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, Optional

from tools.acailint.checks import FILE_CHECKS, PROJECT_CHECKS
from tools.acailint.core import (SourceFile, Violation, apply_suppressions,
                                 load_baseline)

#: only files under this marker are engine code; everything else scanned
#: from a directory argument is skipped unless --all-files is given
ENGINE_MARKER = "repro/core/engine"

DEFAULT_BASELINE = Path(__file__).with_name("baseline.txt")


def collect_files(paths: Iterable[str | Path],
                  scoped: bool = True) -> list[SourceFile]:
    out: list[SourceFile] = []
    for path in paths:
        p = Path(path)
        candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for c in candidates:
            sf = SourceFile.load(c)
            if scoped and ENGINE_MARKER not in sf.path:
                continue
            out.append(sf)
    return out


def run_files(files: list[SourceFile],
              baseline: Optional[set[tuple[str, str]]] = None
              ) -> list[Violation]:
    raw: set[Violation] = set()    # nested functions are walked twice;
    for sf in files:               # the set collapses the duplicates
        for check in FILE_CHECKS:
            raw.update(check(sf))
    for check in PROJECT_CHECKS:
        raw.update(check(files))
    return apply_suppressions(files, list(raw), baseline)


def run_paths(paths: Iterable[str | Path],
              baseline_path: Optional[str | Path] = DEFAULT_BASELINE,
              scoped: bool = True) -> list[Violation]:
    files = collect_files(paths, scoped=scoped)
    baseline = load_baseline(baseline_path) if baseline_path else None
    return run_files(files, baseline)
