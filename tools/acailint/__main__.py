"""CLI: ``python -m tools.acailint src [--baseline F] [--all-files]``.

Exit codes: 0 clean, 1 violations found, 2 usage/IO error.
"""
from __future__ import annotations

import argparse
import sys

from tools.acailint import DEFAULT_BASELINE, run_paths
from tools.acailint.explain import EXPLANATIONS, explain


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.acailint",
        description="engine-invariant static analysis for the ACAI "
                    "control plane")
    parser.add_argument("paths", nargs="*",
                        help="files or directories to scan (default: src)")
    parser.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                        help="baseline suppression file "
                             "(path-suffix:CODE per line)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline file")
    parser.add_argument("--all-files", action="store_true",
                        help="scan every .py under the given paths, not "
                             "just repro/core/engine")
    parser.add_argument("--explain", metavar="CODE",
                        help="print the rationale for a code and exit")
    args = parser.parse_args(argv)

    if args.explain:
        print(explain(args.explain))
        return 0 if args.explain.upper() in EXPLANATIONS else 2

    paths = args.paths or ["src"]
    try:
        violations = run_paths(
            paths,
            baseline_path=None if args.no_baseline else args.baseline,
            scoped=not args.all_files)
    except (OSError, SyntaxError) as exc:
        print(f"acailint: {exc}", file=sys.stderr)
        return 2
    for v in violations:
        print(v.render())
    if violations:
        print(f"acailint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:            # e.g. `... --explain X | head`
        sys.exit(0)
