"""Checker registry.

``FILE_CHECKS`` run per file; ``PROJECT_CHECKS`` see the whole scanned
set at once (they correlate dataclasses with the codec, and every
module with the lifecycle table).
"""
from tools.acailint.checks import codec, epochs, lifecycle, locks, reserve

FILE_CHECKS = (locks.check, epochs.check, reserve.check)
PROJECT_CHECKS = (codec.check_project, lifecycle.check_project)
