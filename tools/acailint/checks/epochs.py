"""ACAI2xx — epoch guards on terminal transitions and events.

ACAI201: every ``set_state(..., JobState.<terminal>)`` call must pass
``expect_epoch=`` so the write commits only for the incarnation it
belongs to. The check-and-write share the registry lock; an unguarded
terminal write lets a superseded worker (the PR-5 zombie-incarnation
class) terminal-ize a job that was preempted/retried after the worker's
last epoch read.

ACAI202: every ``publish(TOPIC_CONTAINER_STATUS, {...})`` whose message
carries a terminal ``"status"`` literal must stamp an ``"epoch"`` key
(in the dict literal, or via ``msg["epoch"] = ...`` on a locally-built
dict in the same function). Handlers drop events stamped older than the
registry epoch; an unstamped terminal event can never be recognized as
stale. Messages whose status is computed dynamically are skipped — the
publisher of a dynamic status is expected to thread the epoch through
the same record (the runtime tests cover that path).
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.acailint.core import (SourceFile, Violation, call_name,
                                 const_str, functions_of, jobstate_member)

CODE_SET_STATE = "ACAI201"
CODE_PUBLISH = "ACAI202"

TERMINAL_MEMBERS = frozenset({"FINISHED", "FAILED", "KILLED",
                              "UPSTREAM_FAILED", "QUARANTINED"})


def _state_arg(call: ast.Call) -> Optional[ast.AST]:
    """The state argument of a ``set_state`` call: second positional
    (after job_id) or the ``new``/``state`` keyword."""
    if len(call.args) >= 2:
        return call.args[1]
    for kw in call.keywords:
        if kw.arg in ("new", "state"):
            return kw.value
    return None


def _check_set_state(sf: SourceFile, out: list[Violation]) -> None:
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call) or call_name(node) != "set_state":
            continue
        state = _state_arg(node)
        member = jobstate_member(state) if state is not None else None
        if member not in TERMINAL_MEMBERS:
            continue
        if not any(kw.arg == "expect_epoch" for kw in node.keywords):
            out.append(Violation(
                sf.path, node.lineno, CODE_SET_STATE,
                f"terminal set_state(JobState.{member}) without "
                f"expect_epoch=: a superseded incarnation could "
                f"terminal-ize the live one"))


def _dict_keys(d: ast.Dict) -> set[str]:
    return {k for k in (const_str(key) for key in d.keys if key is not None)
            if k is not None}


def _dict_value(d: ast.Dict, key: str) -> Optional[ast.AST]:
    for k, v in zip(d.keys, d.values):
        if k is not None and const_str(k) == key:
            return v
    return None


def _local_dicts(fn: ast.AST) -> tuple[dict[str, ast.Dict], set[str]]:
    """Name -> dict literal assigned to it in ``fn``, plus the set of
    names that ever receive an ``name["epoch"] = ...`` subscript store."""
    dicts: dict[str, ast.Dict] = {}
    stamped: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Dict):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    dicts[t.id] = node.value
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.value, ast.Name) and \
                        const_str(t.slice) == "epoch":
                    stamped.add(t.value.id)
    return dicts, stamped


def _is_container_topic(arg: ast.AST) -> bool:
    if isinstance(arg, ast.Name):
        return arg.id == "TOPIC_CONTAINER_STATUS"
    return const_str(arg) == "container_status"


def _check_publish(sf: SourceFile, out: list[Violation]) -> None:
    for fn in functions_of(sf.tree):
        dicts, stamped = _local_dicts(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call) \
                    or call_name(node) != "publish" or len(node.args) < 2:
                continue
            if not _is_container_topic(node.args[0]):
                continue
            msg = node.args[1]
            has_epoch = False
            if isinstance(msg, ast.Name):
                has_epoch = msg.id in stamped
                msg = dicts.get(msg.id)
            if not isinstance(msg, ast.Dict):
                continue            # not statically resolvable
            status = _dict_value(msg, "status")
            if status is None:
                continue
            literal = const_str(status)
            member = jobstate_member(status)
            # JobState.X.value resolves through the .value attribute
            if member is None and isinstance(status, ast.Attribute) \
                    and status.attr == "value":
                member = jobstate_member(status.value)
            terminal = (literal in TERMINAL_MEMBERS
                        or member in TERMINAL_MEMBERS)
            if not terminal:
                continue
            if "epoch" in _dict_keys(msg) or has_epoch:
                continue
            out.append(Violation(
                sf.path, node.lineno, CODE_PUBLISH,
                f"terminal container_status "
                f"({literal or member}) published without an "
                f"'epoch' stamp: handlers cannot drop it as stale"))


def check(sf: SourceFile) -> list[Violation]:
    out: list[Violation] = []
    _check_set_state(sf, out)
    _check_publish(sf, out)
    return out
