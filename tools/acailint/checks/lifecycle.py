"""ACAI5xx — lifecycle transition closure.

ACAI501: state-machine edges used anywhere in the engine must be edges
the declared table in ``lifecycle.py`` (or a privileged reassignment
site) actually grants:

- a direct ``<obj>.state = JobState.X`` assignment is allowed only in
  ``registry.py`` (the implementation: every write goes through
  ``check_transition`` or a documented privileged method) and
  ``durable/recovery.py`` (the rebuild replays history, and the
  epoch-rebirth requeue is a privileged reassignment by design —
  see the lifecycle module docstring). Anywhere else it bypasses
  ``check_transition`` entirely.
- a ``set_state(..., JobState.X)`` target must be reachable — i.e. ``X``
  appears as a destination of some edge in ``_TRANSITIONS``.

ACAI502: the declared table itself must be closed: every ``JobState``
member has a row, every edge endpoint is a member, every edge out of a
``TERMINAL_STATES`` state lands in ``TERMINAL_STATES`` (terminal
refinement only — FAILED -> QUARANTINED), every non-terminal state has a
way forward, and ``TERMINAL_STATES`` only names members.

This is a project-level check: the table is parsed from the scanned
``lifecycle.py``; the edge checks run over every scanned file.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from tools.acailint.core import (SourceFile, Violation, call_name,
                                 jobstate_member)
from tools.acailint.checks.epochs import _state_arg

CODE_EDGE = "ACAI501"
CODE_TABLE = "ACAI502"

#: modules whose direct ``.state =`` writes are the privileged
#: implementation (see module docstring)
PRIVILEGED_SUFFIXES = ("registry.py", "durable/recovery.py")


def _parse_members(tree: ast.AST) -> set[str]:
    for cls in ast.walk(tree):
        if isinstance(cls, ast.ClassDef) and cls.name == "JobState":
            return {n.targets[0].id for n in cls.body
                    if isinstance(n, ast.Assign)
                    and isinstance(n.targets[0], ast.Name)}
    return set()


def _parse_table(tree: ast.AST) -> Optional[dict[str, set[str]]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "_TRANSITIONS"
                        for t in node.targets) \
                and isinstance(node.value, ast.Dict):
            table: dict[str, set[str]] = {}
            for k, v in zip(node.value.keys, node.value.values):
                src = jobstate_member(k) if k is not None else None
                if src is None:
                    continue
                dsts = set()
                if isinstance(v, (ast.Set, ast.Tuple, ast.List)):
                    dsts = {m for m in map(jobstate_member, v.elts)
                            if m is not None}
                table[src] = dsts
            return table
    return None


def _parse_terminal(tree: ast.AST) -> set[str]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "TERMINAL_STATES"
                        for t in node.targets):
            value = node.value
            if isinstance(value, ast.Call):     # frozenset({...})
                value = value.args[0] if value.args else None
            if isinstance(value, (ast.Set, ast.Tuple, ast.List)):
                return {m for m in map(jobstate_member, value.elts)
                        if m is not None}
    return set()


def _check_table(sf: SourceFile, out: list[Violation]) -> None:
    members = _parse_members(sf.tree)
    table = _parse_table(sf.tree)
    terminal = _parse_terminal(sf.tree)
    if table is None or not members:
        return
    line = next((n.lineno for n in ast.walk(sf.tree)
                 if isinstance(n, ast.Assign)
                 and any(isinstance(t, ast.Name) and t.id == "_TRANSITIONS"
                         for t in n.targets)), 1)
    for m in sorted(members - set(table)):
        out.append(Violation(sf.path, line, CODE_TABLE,
                             f"JobState.{m} has no _TRANSITIONS row"))
    for src, dsts in table.items():
        for d in sorted(dsts - members):
            out.append(Violation(sf.path, line, CODE_TABLE,
                                 f"edge {src} -> {d} targets an "
                                 f"undeclared state"))
        if src in terminal:
            for d in sorted(dsts - terminal):
                out.append(Violation(
                    sf.path, line, CODE_TABLE,
                    f"edge {src} -> {d} leaves a terminal state for a "
                    f"non-terminal one: terminal refinement only"))
        elif src in members and not dsts:
            out.append(Violation(
                sf.path, line, CODE_TABLE,
                f"non-terminal state {src} has no outgoing edge: jobs "
                f"strand there forever"))
    for m in sorted(terminal - members):
        out.append(Violation(sf.path, line, CODE_TABLE,
                             f"TERMINAL_STATES names undeclared "
                             f"state {m}"))


def _check_edges(sf: SourceFile, targets: Optional[set[str]],
                 out: list[Violation]) -> None:
    privileged = any(sf.endswith(s) for s in PRIVILEGED_SUFFIXES)
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Assign) and not privileged:
            for t in node.targets:
                if isinstance(t, ast.Attribute) and t.attr == "state" \
                        and jobstate_member(node.value) is not None:
                    out.append(Violation(
                        sf.path, node.lineno, CODE_EDGE,
                        f"direct .state = JobState."
                        f"{jobstate_member(node.value)} assignment "
                        f"bypasses check_transition; go through the "
                        f"registry"))
        if isinstance(node, ast.Call) and call_name(node) == "set_state" \
                and targets is not None:
            state = _state_arg(node)
            member = jobstate_member(state) if state is not None else None
            if member is not None and member not in targets:
                out.append(Violation(
                    sf.path, node.lineno, CODE_EDGE,
                    f"set_state(JobState.{member}): no edge in "
                    f"_TRANSITIONS reaches {member}"))


def check_project(files: list[SourceFile]) -> list[Violation]:
    out: list[Violation] = []
    lifecycle = next((f for f in files if f.endswith("lifecycle.py")), None)
    targets: Optional[set[str]] = None
    if lifecycle is not None:
        _check_table(lifecycle, out)
        table = _parse_table(lifecycle.tree)
        if table:
            targets = set().union(*table.values()) if table else set()
    for sf in files:
        if sf is lifecycle:
            continue
        _check_edges(sf, targets, out)
    return out
