"""ACAI3xx — journal/codec coverage.

ACAI301: every dataclass field of the journaled engine records
(``JobSpec``/``Job``/``GangSpec``/``RetryPolicy`` in ``registry.py``,
``FaultPlan`` in ``faults.py``) must appear — as a string key — in BOTH
the encode and decode half of ``durable/codec.py``. A field added to the
dataclass but not the codec is silent data loss across a crash (the
class PR 9 had to handle by hand when ``RetryPolicy`` landed). Fields
that are deliberately in-memory-only carry an
``# acailint: runtime-only`` marker on their declaration line.

ACAI302: every ``JobRegistry`` method that mutates durable state
(assigns ``.state``/``.epoch`` on a job, or stores into ``self._jobs``)
must reference ``self.journal`` — the write-ahead hook is what makes the
mutation survive a crash.

This is a project-level check: it needs ``registry.py``, ``faults.py``
and ``codec.py`` together, located by path suffix among the scanned
files, and runs only when at least one of them is present.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from tools.acailint.core import SourceFile, Violation

CODE_CODEC = "ACAI301"
CODE_JOURNAL = "ACAI302"

RUNTIME_ONLY_MARKER = "acailint: runtime-only"

#: dataclass -> (defining file suffix, encode fn, decode fn)
CODEC_MAP = {
    "JobSpec": ("registry.py", "encode_spec", "decode_spec"),
    "Job": ("registry.py", "encode_job", "decode_job"),
    "GangSpec": ("registry.py", "encode_gang", "decode_gang"),
    "RetryPolicy": ("registry.py", "encode_retry", "decode_retry"),
    "FaultPlan": ("faults.py", "encode_fault_plan", "decode_fault_plan"),
}

#: JobRegistry methods exempt from ACAI302 would be listed here; the
#: registry currently has none — ``adopt`` journals too (recovery runs
#: it under ``journal.paused()``, so the rebuild never double-records).
JOURNAL_EXEMPT: frozenset[str] = frozenset()


def _is_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(target, ast.Attribute) and target.attr == "dataclass":
            return True
        if isinstance(target, ast.Name) and target.id == "dataclass":
            return True
    return False


def dataclass_fields(sf: SourceFile, class_name: str) -> Optional[list[str]]:
    """Declared field names of a dataclass, excluding runtime-only ones;
    None when the class is not in this file."""
    for cls in ast.walk(sf.tree):
        if not isinstance(cls, ast.ClassDef) or cls.name != class_name:
            continue
        if not _is_dataclass(cls):
            return None
        fields = []
        for node in cls.body:
            if not isinstance(node, ast.AnnAssign) \
                    or not isinstance(node.target, ast.Name):
                continue
            if RUNTIME_ONLY_MARKER in sf.comment(node.lineno):
                continue
            fields.append(node.target.id)
        return fields
    return None


def runtime_only_fields(sf: SourceFile, class_name: str) -> set[str]:
    """Fields carrying the runtime-only marker (for the runtime
    round-trip test to share one source of truth with the linter)."""
    for cls in ast.walk(sf.tree):
        if isinstance(cls, ast.ClassDef) and cls.name == class_name:
            return {node.target.id for node in cls.body
                    if isinstance(node, ast.AnnAssign)
                    and isinstance(node.target, ast.Name)
                    and RUNTIME_ONLY_MARKER in sf.comment(node.lineno)}
    return set()


def _function_strings(sf: SourceFile, fn_name: str) -> Optional[set[str]]:
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == fn_name:
            return {n.value for n in ast.walk(node)
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, str)}
    return None


def _find(files: Iterable[SourceFile], suffix: str) -> Optional[SourceFile]:
    return next((f for f in files if f.endswith(suffix)), None)


def _check_codec(files: list[SourceFile], out: list[Violation]) -> None:
    codec = _find(files, "codec.py")
    if codec is None:
        return
    for cls_name, (suffix, enc_name, dec_name) in CODEC_MAP.items():
        src = _find(files, suffix)
        if src is None:
            continue
        fields = dataclass_fields(src, cls_name)
        if fields is None:
            continue
        for fn_name in (enc_name, dec_name):
            strings = _function_strings(codec, fn_name)
            if strings is None:
                out.append(Violation(
                    codec.path, 1, CODE_CODEC,
                    f"no {fn_name}() in codec: {cls_name} cannot "
                    f"round-trip the durable store"))
                continue
            for field in fields:
                if field not in strings:
                    out.append(Violation(
                        codec.path, 1, CODE_CODEC,
                        f"{cls_name}.{field} is not covered by "
                        f"{fn_name}(): the field is silently lost "
                        f"across a crash/recovery"))


def _mutates_durable_state(method: ast.FunctionDef) -> bool:
    for node in ast.walk(method):
        targets: list[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Attribute) and t.attr in ("state", "epoch"):
                return True
            if isinstance(t, ast.Subscript) \
                    and isinstance(t.value, ast.Attribute) \
                    and t.value.attr == "_jobs":
                return True
    return False


def _references_journal(method: ast.FunctionDef) -> bool:
    for node in ast.walk(method):
        if isinstance(node, ast.Attribute) and node.attr == "journal":
            return True
    return False


def _check_registry_journal(files: list[SourceFile],
                            out: list[Violation]) -> None:
    for sf in files:
        for cls in ast.walk(sf.tree):
            if not isinstance(cls, ast.ClassDef) \
                    or cls.name != "JobRegistry":
                continue
            for method in cls.body:
                if not isinstance(method, ast.FunctionDef) \
                        or method.name == "__init__" \
                        or method.name in JOURNAL_EXEMPT:
                    continue
                if _mutates_durable_state(method) \
                        and not _references_journal(method):
                    out.append(Violation(
                        sf.path, method.lineno, CODE_JOURNAL,
                        f"JobRegistry.{method.name} mutates durable job "
                        f"state without a journal hook: the mutation "
                        f"does not survive a crash"))


def check_project(files: list[SourceFile]) -> list[Violation]:
    out: list[Violation] = []
    _check_codec(files, out)
    _check_registry_journal(files, out)
    return out
