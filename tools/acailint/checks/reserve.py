"""ACAI401 — reserve/release pairing.

Every ``cluster.reserve(...)`` / ``reserve_gang(...)`` call site must
dominate a release on its exception paths: either

- the call sits inside a ``try`` whose handlers or ``finally`` contain a
  release-family call — anything whose name contains "release", or a
  same-file helper that transitively calls one (an unwind helper like
  ``_abort_launch`` counts through its body) — so a raise after the
  reservation is taken hands the capacity back; or
- nothing that can raise (no call, no ``raise``, no ``assert``) follows
  the reserve in the enclosing function, so there is no exception path
  to leak on.

An ``except CapacityError`` around a bare reserve is the atomic-failure
pattern (reserve raised, nothing held, nothing to release) and is fine —
but only when no later raising statement can strand a *successful*
reservation, which the second clause checks.

Leaked reservations are permanent phantom capacity: ``used`` never
drains, admission starves, and the drift only surfaces as the
``release_underflow`` counters much later — the class PR 7/8's settle
paths were built to prevent.
"""
from __future__ import annotations

import ast

from tools.acailint.core import SourceFile, Violation, call_name, functions_of

CODE = "ACAI401"

RESERVE_NAMES = frozenset({"reserve", "reserve_gang"})


def _releasing_names(tree: ast.AST) -> frozenset[str]:
    """Names of functions in this file that transitively reach a
    release call — an unwind helper counts as release-family at its
    call sites (fixpoint over same-file call edges)."""
    bodies = {fn.name: fn for fn in functions_of(tree)}
    releasing = set()
    changed = True
    while changed:
        changed = False
        for name, fn in bodies.items():
            if name in releasing:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and \
                        ("release" in call_name(node)
                         or call_name(node) in releasing):
                    releasing.add(name)
                    changed = True
                    break
    return frozenset(releasing)


def _is_release_call(node: ast.AST, releasing: frozenset[str]) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = call_name(node)
    return "release" in name or name in releasing


def _protecting_try(fn: ast.AST, call: ast.Call,
                    releasing: frozenset[str]) -> ast.Try | None:
    """Innermost ``try`` whose *body* lexically contains ``call`` and
    whose handlers/finally contain a release-family call."""
    best: ast.Try | None = None
    for node in ast.walk(fn):
        if not isinstance(node, ast.Try):
            continue
        span = (node.body[0].lineno, node.body[-1].end_lineno or 0)
        if not (span[0] <= call.lineno <= span[1]):
            continue
        protected = any(_is_release_call(n, releasing)
                        for h in node.handlers for n in ast.walk(h))
        protected = protected or any(_is_release_call(n, releasing)
                                     for s in node.finalbody
                                     for n in ast.walk(s))
        if protected:
            best = node
    return best


def _raising_after(fn: ast.AST, call: ast.Call) -> int | None:
    """Line of the first statement after ``call`` (lexically, in the
    same function) that can raise — a Call, ``raise`` or ``assert``
    outside the handlers of the try containing the reserve."""
    handler_spans = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Try) and node.body and \
                node.body[0].lineno <= call.lineno \
                <= (node.body[-1].end_lineno or 0):
            for h in node.handlers:
                if h.body:
                    handler_spans.append((h.body[0].lineno,
                                          h.body[-1].end_lineno or 0))
    end = call.end_lineno or call.lineno
    for node in ast.walk(fn):
        if not isinstance(node, (ast.Call, ast.Raise, ast.Assert)):
            continue
        if node.lineno <= end:
            continue
        if any(a <= node.lineno <= b for a, b in handler_spans):
            continue        # the reserve's own failure handler: nothing
        return node.lineno  # is held when it runs
    return None


def check(sf: SourceFile) -> list[Violation]:
    out: list[Violation] = []
    releasing = _releasing_names(sf.tree)
    for fn in functions_of(sf.tree):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call) \
                    or call_name(node) not in RESERVE_NAMES:
                continue
            if _protecting_try(fn, node, releasing) is not None:
                continue
            after = _raising_after(fn, node)
            if after is not None:
                out.append(Violation(
                    sf.path, node.lineno, CODE,
                    f"{call_name(node)}() is not covered by a "
                    f"try/except-or-finally that releases: the raising "
                    f"statement at line {after} would leak the "
                    f"reservation as phantom capacity"))
    return out
