"""ACAI1xx — lock discipline.

ACAI101: a field declared guarded (``self.x = ...  # guarded-by: _lock``
in ``__init__``) may only be read or written inside a matching
``with self._lock:`` scope within its class. ``__init__`` itself is
exempt: construction happens-before publication.

ACAI102: a lock declared with forbidden work
(``self._lock = RLock()  # acailint: lock(forbid: publish, bare-calls)``)
must never lexically hold that work inside its ``with`` scope. Tokens:

- ``bare-calls`` — calling a plain name that is not a python builtin
  (subscriber/handler invocation: the EventBus must call handlers
  outside its lock or handler-held locks invert order);
- any other token ``t`` — no call whose attribute chain contains ``t``
  (``publish`` forbids ``bus.publish(...)`` under the registry lock,
  ``metadata``/``launch`` forbid store and runner callouts there).

The scheduler's own lock carries no annotation by design: the engine's
bus is synchronous and re-entrant, so the scheduler deliberately
publishes under its lock; the ordering contract it must keep is "never
while holding the *registry* or *bus* lock", which is exactly what the
annotations on those classes pin.
"""
from __future__ import annotations

import ast
import builtins
import re

from tools.acailint.core import SourceFile, Violation, attr_chain

CODE_GUARDED = "ACAI101"
CODE_FORBIDDEN = "ACAI102"

_GUARDED_RE = re.compile(r"guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")
_LOCK_RE = re.compile(r"acailint:\s*lock\(forbid:\s*([^)]*)\)")
_BUILTINS = frozenset(dir(builtins))


def _self_attr_target(node: ast.stmt) -> str | None:
    """``self.x = ...`` / ``self.x: T = ...`` -> "x"."""
    target = None
    if isinstance(node, ast.Assign) and len(node.targets) == 1:
        target = node.targets[0]
    elif isinstance(node, ast.AnnAssign):
        target = node.target
    if isinstance(target, ast.Attribute) and \
            isinstance(target.value, ast.Name) and target.value.id == "self":
        return target.attr
    return None


def _declarations(sf: SourceFile,
                  cls: ast.ClassDef) -> tuple[dict[str, str],
                                              dict[str, set[str]]]:
    """(guarded fields {field: lock}, lock rules {lock: forbid tokens})
    from the class ``__init__``'s annotated assignments."""
    guarded: dict[str, str] = {}
    rules: dict[str, set[str]] = {}
    init = next((n for n in cls.body
                 if isinstance(n, ast.FunctionDef) and n.name == "__init__"),
                None)
    if init is None:
        return guarded, rules
    for node in ast.walk(init):
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        field = _self_attr_target(node)
        if field is None:
            continue
        comment = sf.comment(node.lineno)
        m = _GUARDED_RE.search(comment)
        if m:
            guarded[field] = m.group(1)
        m = _LOCK_RE.search(comment)
        if m:
            rules[field] = {t.strip() for t in m.group(1).split(",")
                            if t.strip()}
    return guarded, rules


def _with_locks(node: ast.With) -> set[str]:
    """Lock names acquired by ``with self.<name>:`` items."""
    out = set()
    for item in node.items:
        chain = attr_chain(item.context_expr)
        if len(chain) == 2 and chain[0] == "self":
            out.add(chain[1])
    return out


class _MethodScan(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, guarded: dict[str, str],
                 rules: dict[str, set[str]], out: list[Violation]):
        self.sf = sf
        self.guarded = guarded
        self.rules = rules
        self.out = out
        self.held: set[str] = set()

    def visit_With(self, node: ast.With) -> None:
        acquired = _with_locks(node) - self.held
        self.held |= acquired
        for item in node.items:       # the acquire expression itself runs
            self.generic_visit(item)  # before the lock is held? no — but
        for stmt in node.body:        # guarded fields in it are fine to
            self.visit(stmt)          # treat as held (RLock idiom)
        self.held -= acquired

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            lock = self.guarded.get(node.attr)
            if lock is not None and lock not in self.held:
                self.out.append(Violation(
                    self.sf.path, node.lineno, CODE_GUARDED,
                    f"self.{node.attr} is declared guarded-by {lock} but "
                    f"is accessed outside 'with self.{lock}:'"))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        for lock in self.held:
            tokens = self.rules.get(lock)
            if not tokens:
                continue
            chain = attr_chain(node.func)
            if isinstance(node.func, ast.Name) and "bare-calls" in tokens \
                    and node.func.id not in _BUILTINS:
                self.out.append(Violation(
                    self.sf.path, node.lineno, CODE_FORBIDDEN,
                    f"call to {node.func.id}() while holding self.{lock} "
                    f"(declared no-bare-calls: handlers/callbacks must "
                    f"run outside this lock)"))
                continue
            hit = next((t for t in tokens
                        if t != "bare-calls" and t in chain), None)
            if hit is not None:
                self.out.append(Violation(
                    self.sf.path, node.lineno, CODE_FORBIDDEN,
                    f"call through '{hit}' while holding self.{lock} "
                    f"(declared forbidden under this lock)"))
        self.generic_visit(node)


def check(sf: SourceFile) -> list[Violation]:
    out: list[Violation] = []
    for cls in [n for n in ast.walk(sf.tree) if isinstance(n, ast.ClassDef)]:
        guarded, rules = _declarations(sf, cls)
        if not guarded and not rules:
            continue
        for method in cls.body:
            if not isinstance(method, ast.FunctionDef) \
                    or method.name == "__init__":
                continue
            scan = _MethodScan(sf, guarded, rules, out)
            for stmt in method.body:
                scan.visit(stmt)
    return out
