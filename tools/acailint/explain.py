"""``--explain CODE``: the long-form rationale behind each invariant.

The one-line lint message says *what*; this says *why* — which bug
class the invariant pins and what the approved fix shapes are. The
full catalogue with historical context lives in ``docs/invariants.md``.
"""
from __future__ import annotations

EXPLANATIONS = {
    "ACAI001": """\
Malformed suppression.

Every '# acailint: disable=CODE' must carry ' -- <justification>'.
Suppressions are exceptions to engine invariants; an exception nobody
argued for is indistinguishable from a bug someone silenced. Write
    # acailint: disable=ACAI101 -- snapshot read, staleness is benign
or fix the violation instead.""",
    "ACAI101": """\
Guarded field accessed outside its lock.

Fields annotated '# guarded-by: <lock>' on their __init__ assignment
may only be touched inside 'with self.<lock>:' in that class. The
engine's monitor aggregates (utilization sums, peak, samples) are
written by bus handler threads; an unguarded read can observe a torn
update (sum bumped, count not) and report impossible utilization.
Fix: take the lock, or expose a locked accessor for cross-module
readers. __init__ is exempt (construction happens-before publication).""",
    "ACAI102": """\
Forbidden work under an annotated lock.

A lock annotated '# acailint: lock(forbid: ...)' must never lexically
contain the listed work in its 'with' scope:
  - 'bare-calls': no plain-name calls (handler/callback invocation) —
    the EventBus must invoke subscribers outside its lock, or a handler
    that takes the scheduler lock inverts the lock order and deadlocks;
  - 'publish' / 'metadata' / 'launch': no call through that attribute —
    publishing or hitting the store/runner under the registry lock
    nests foreign locks under it.
Fix: snapshot under the lock, do the work after releasing.""",
    "ACAI201": """\
Terminal set_state without expect_epoch.

Every set_state(..., JobState.<terminal>) must pass expect_epoch= so
the write commits only for the incarnation it belongs to. Preemption
and retry bump Job.epoch; a worker from the previous incarnation that
reports late would otherwise terminal-ize the live rebirth (the
zombie-incarnation bug). expect_epoch=job.epoch read under the same
lock that bumps epochs is always safe — it pins 'this incarnation'.""",
    "ACAI202": """\
Terminal container_status event without an epoch stamp.

Monitor handlers drop container_status events whose 'epoch' is older
than the registry's current epoch. A terminal message published
without the stamp can never be recognized as stale: a KILLED event
from epoch 0 would mark the epoch-1 rebirth terminal and wake
wait_terminal() on a job that is actually running. Stamp the message
('"epoch": job.epoch' in the literal, or msg["epoch"] = ... before
publish).""",
    "ACAI301": """\
Dataclass field missing from the durable codec.

Every field of JobSpec/Job/GangSpec/RetryPolicy/FaultPlan must appear
as a key in both the encode_* and decode_* half of durable/codec.py.
A field added to the dataclass but not the codec is silent data loss:
the engine runs fine until the first crash, then recovery rebuilds
jobs without it. In-memory-only fields are declared with
'# acailint: runtime-only' on their declaration line — which also
excludes them from the runtime round-trip test.""",
    "ACAI302": """\
Registry mutation without a journal hook.

Every JobRegistry method that mutates durable job state (state/epoch
assignment, self._jobs stores) must go through a self.journal hook —
the write-ahead record is what makes the mutation survive a crash.
Recovery wraps its rebuild in journal.paused(), so journaling inside
adopt/force_state is a no-op there and never double-records.""",
    "ACAI401": """\
Reservation not release-protected on exception paths.

A cluster.reserve()/reserve_gang() call followed by anything that can
raise must sit inside a try whose handlers or finally release the
hold. reserve raising is safe (atomic: nothing held); reserve
*succeeding* and a later launch step raising leaks the hold as
phantom capacity — 'used' never drains, admission starves, and the
drift only surfaces as release_underflow counters much later.""",
    "ACAI501": """\
State-machine edge outside the declared table.

Direct '.state = JobState.X' assignment is allowed only in registry.py
(the implementation) and durable/recovery.py (replay + privileged
epoch-rebirth requeue); anywhere else it bypasses check_transition and
the journal. And a set_state() target must be reachable: some edge in
lifecycle._TRANSITIONS must point at it (SUBMITTED, for example, is an
origin only — no edge re-enters it).""",
    "ACAI502": """\
Lifecycle table not closed.

The declared _TRANSITIONS table must satisfy: every JobState member
has a row; every edge endpoint is a declared member; edges out of
TERMINAL_STATES stay inside TERMINAL_STATES (terminal refinement only,
e.g. FAILED -> QUARANTINED); every non-terminal state has at least one
outgoing edge (no strand states); TERMINAL_STATES only names members.
These keep the table the single source of truth the rest of the engine
(and ACAI501) checks against.""",
}


def explain(code: str) -> str:
    text = EXPLANATIONS.get(code.upper())
    if text is None:
        known = ", ".join(sorted(EXPLANATIONS))
        return f"unknown code {code!r}; known codes: {known}"
    return f"{code.upper()}\n\n{text}"
