"""compressed_psum correctness on a real (multi-host-device) mesh — needs
its own process for the device count."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.train.compression import compressed_psum

mesh = jax.make_mesh((4,), ("d",))
x = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.0

def f(kind):
    def body(xl):
        return compressed_psum(xl[0], "d", kind)[None]
    return shard_map(body, mesh=mesh, in_specs=(P("d", None),),
                     out_specs=P("d", None), check_rep=False)

want = np.asarray(x.sum(0))
out = {}
for kind in ("bf16", "int8"):
    got = np.asarray(jax.jit(f(kind))(x))[0]
    out[kind] = float(np.abs(got - want).max() / np.abs(want).max())
print("RESULT::" + json.dumps(out))
"""


@pytest.mark.slow
def test_compressed_psum_on_mesh():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=300,
                          env={**__import__("os").environ,
                               "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT::")][0]
    out = json.loads(line[len("RESULT::"):])
    assert out["bf16"] < 0.01
    assert out["int8"] < 0.03
