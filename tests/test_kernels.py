"""Per-kernel allclose validation against the ref.py oracles, sweeping
shapes/dtypes (interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 256, 4, 4, 64),       # MHA
    (2, 256, 4, 2, 32),       # GQA 2:1
    (1, 512, 8, 2, 64),       # GQA 4:1, more blocks
    (1, 128, 2, 1, 128),      # MQA, single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, s, h, kv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=128,
                              block_k=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 192, 2, 2, 80),       # s and d both off the 128 grid
    (2, 320, 4, 2, 96),       # multi-batch ragged
    (1, 100, 2, 1, 64),       # s smaller than one block
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_ragged_shapes(b, s, h, kv, d, causal):
    """Sequence lengths / head dims that don't divide the block grid:
    the kernel pads internally and must mask the tail correctly."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("blocks", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,k", [(1, 128, 2, 32), (2, 256, 4, 64),
                                     (1, 64, 1, 16)])
@pytest.mark.parametrize("seed", [0, 1])
def test_wkv6_matches_ref(b, s, h, k, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (b, s, h, k)) * 0.5
    kk = jax.random.normal(ks[1], (b, s, h, k)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, k)) * 0.5
    # realistic RWKV decay magnitudes: logw in (-0.5, -1e-3)
    logw = -jnp.exp(jax.random.uniform(ks[3], (b, s, h, k),
                                       minval=-7.0, maxval=-0.7))
    u = jax.random.normal(ks[4], (h, k)) * 0.3
    out = ops.wkv6(r, kk, v, logw, u, chunk=64, interpret=True)
    want = ref.wkv6_ref(r, kk, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_jnp_chunked_matches_ref():
    """The pure-jnp chunked path (models/rwkv.py) against the oracle."""
    from repro.models.rwkv import wkv6_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, k = 2, 128, 2, 32
    r = jax.random.normal(ks[0], (b, s, h, k)) * 0.5
    kk = jax.random.normal(ks[1], (b, s, h, k)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, k)) * 0.5
    logw = -jnp.exp(jax.random.uniform(ks[3], (b, s, h, k), minval=-7.0,
                                       maxval=-0.7))
    u = jax.random.normal(ks[4], (h, k)) * 0.3
    out = wkv6_chunked(r, kk, v, logw, u, chunk=32)
    want = ref.wkv6_ref(r, kk, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_recurrent_matches_ref():
    from repro.models.rwkv import wkv6_recurrent
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, s, h, k = 1, 8, 2, 16
    r = jax.random.normal(ks[0], (b, s, h, k)) * 0.5
    kk = jax.random.normal(ks[1], (b, s, h, k)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, k)) * 0.5
    logw = -jnp.exp(jax.random.uniform(ks[3], (b, s, h, k), minval=-7.0,
                                       maxval=-0.7))
    u = jax.random.normal(ks[4], (h, k)) * 0.3
    state = jnp.zeros((b, h, k, k))
    outs = []
    for t in range(s):
        y, state = wkv6_recurrent(r[:, t:t+1], kk[:, t:t+1], v[:, t:t+1],
                                  logw[:, t:t+1], u, state)
        outs.append(y)
    out = jnp.concatenate(outs, axis=1)
    want = ref.wkv6_ref(r, kk, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,p,g,n", [
    (1, 128, 2, 32, 1, 16), (2, 256, 4, 64, 2, 32), (1, 64, 2, 16, 1, 8)])
def test_ssd_matches_ref(b, s, h, p, g, n):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    D = jnp.ones((h,))
    out = ops.mamba2_ssd(x, dt, A, B, C, D, chunk=64, interpret=True)
    want = ref.ssd_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_ssd_jnp_chunked_matches_ref():
    from repro.models.mamba import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    b, s, h, p, g, n = 2, 128, 4, 32, 2, 16
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    D = jnp.ones((h,))
    out = ssd_chunked(x, dt, A, B, C, D, chunk=32)
    want = ref.ssd_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_ssd_recurrent_matches_ref():
    from repro.models.mamba import ssd_recurrent
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    b, s, h, p, g, n = 1, 8, 2, 16, 1, 8
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    D = jnp.ones((h,))
    state = jnp.zeros((b, h, n, p))
    outs = []
    for t in range(s):
        y, state = ssd_recurrent(x[:, t:t+1], dt[:, t:t+1], A,
                                 B[:, t:t+1], C[:, t:t+1], D, state)
        outs.append(y)
    out = jnp.concatenate(outs, axis=1)
    want = ref.ssd_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kv,d", [(2, 512, 4, 2, 64),
                                        (1, 1024, 8, 8, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(b, s, h, kv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    cache_len = jax.random.randint(ks[3], (b,), 1, s)
    out = ops.decode_attention(q, kc, vc, cache_len, block_k=256,
                               interpret=True)
    want = ref.decode_attention_ref(
        jnp.swapaxes(q, 1, 2)[:, :, 0],
        jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2), cache_len)
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# autotuned configs stay numerically equivalent
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kernel", ["flash_attention", "decode_attention",
                                    "mamba2_ssd", "rwkv6"])
def test_tuned_configs_match_default_and_ref(kernel):
    """Every legal block config is a pure scheduling choice: sweeping the
    tuning ladders (what the autotuner explores) must reproduce both the
    untuned default's output and the reference oracle."""
    from repro.core.provision.autotune import (KERNELS, SMOKE_SHAPES, legal,
                                               max_abs_err, seed_config)
    spec = KERNELS[kernel]
    shape = SMOKE_SHAPES[kernel][0]
    args, ref_out = spec.build(shape, 0)
    default = seed_config(spec, shape)
    assert max_abs_err(spec, args, ref_out, default,
                       interpret=True) <= spec.tol
    param, ladder = next(iter(spec.ladders.items()))
    swept = 0
    for v in ladder:
        cfg = dict(default, **{param: v})
        if cfg == default or not legal(spec, shape, cfg):
            continue
        assert max_abs_err(spec, args, ref_out, cfg,
                           interpret=True) <= spec.tol, \
            f"{kernel} config {cfg} diverges from ref"
        swept += 1
    assert swept >= 1                 # the ladder must offer real choices
