"""Process-boundary runner: jobs execute in a detached worker process
that outlives the engine. Covers the drain protocol (launch/pending/
step), failure propagation, the importable-fn contract, engine-restart
re-adoption of in-flight jobs, replay of results buffered while no
engine was alive, and exactly-once side effects across a crash."""
import time

import pytest

from repro.core.acai import AcaiEngine
from repro.core.engine.durable.jobs import (append_once_job, echo_job,
                                            fail_job, sleep_job)
from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import JobSpec


def _engine(tmp_path, **kw):
    return AcaiEngine(runner="subprocess", workroot=str(tmp_path / "w"),
                      durable=tmp_path / "state", quota_k=100, **kw)


def _spec(name, fn, args=None):
    return JobSpec(name=name, project="p", user="u", fn=fn,
                   args=args or {},
                   resources={"vcpu": 1.0, "mem_mb": 512.0})


def _drain(engine, timeout=30.0):
    launcher = engine.scheduler.launcher
    while launcher.pending():
        launcher.step(timeout=timeout)


@pytest.fixture
def eng(tmp_path):
    engine = _engine(tmp_path)
    yield engine
    engine.launcher.shutdown()
    engine.store.close()


def test_launch_result_outputs_and_log(eng):
    h = eng.submit(_spec("e", echo_job, {"msg": "over the wire"}))
    _drain(eng)
    job = eng.registry.get(h.job_id)
    assert job.state is JobState.FINISHED
    assert job.outputs["echo"] == "over the wire"
    assert "echo: over the wire" in job.outputs["log"]
    assert job.runtime is not None and job.runtime >= 0
    assert h.wait(timeout=1.0) is JobState.FINISHED


def test_failure_carries_traceback(eng):
    h = eng.submit(_spec("f", fail_job, {"msg": "kaput"}))
    _drain(eng)
    job = eng.registry.get(h.job_id)
    assert job.state is JobState.FAILED
    assert "kaput" in job.error


def test_unimportable_fn_fails_loudly(eng):
    h = eng.submit(_spec("lam", lambda w, j: {}))
    _drain(eng)
    job = eng.registry.get(h.job_id)
    assert job.state is JobState.FAILED
    assert "importable" in job.error


def test_worker_survives_engine_death_and_readopts(tmp_path):
    """The headline: jobs keep running through an engine crash; the
    restarted engine re-adopts in-flight work at its original epoch and
    applies results completed while it was down — without re-running."""
    marks = tmp_path / "marks.txt"
    eng1 = _engine(tmp_path)
    h_slow = eng1.submit(_spec("slow", sleep_job, {"seconds": 3.0}))
    h_mark = eng1.submit(_spec("mark", append_once_job,
                               {"path": str(marks), "seconds": 0.2}))
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        if all(eng1.registry.get(h.job_id).state is JobState.RUNNING
               for h in (h_slow, h_mark)):
            break
        time.sleep(0.05)
    else:
        raise AssertionError("jobs never reached RUNNING in the worker")
    # engine dies: no shutdown — the detached worker keeps executing
    eng1.store.close()
    eng1.launcher._disconnect()
    del eng1
    time.sleep(1.0)     # "mark" completes while no engine is alive

    eng2 = _engine(tmp_path)
    rep = eng2.recovery
    assert rep is not None
    assert rep.adopted >= 1             # slow: still in flight, re-attached
    assert rep.worker_results >= 1      # mark: buffered result applied
    assert rep.requeued == 0            # nothing re-queued, nothing re-run
    slow = eng2.registry.get(h_slow.job_id)
    assert slow.epoch == 0              # original incarnation, re-adopted
    _drain(eng2)
    assert eng2.registry.get(h_slow.job_id).state is JobState.FINISHED
    assert eng2.registry.get(h_mark.job_id).state is JobState.FINISHED
    # exactly-once side effect: one line, despite crash + recovery
    assert marks.read_text().splitlines() == [h_mark.job_id]
    eng2.launcher.shutdown()
    eng2.store.close()


def test_dead_worker_buffered_results_still_settle(tmp_path):
    """Worker AND engine both die after a completion: the results.jsonl
    buffer alone settles the finished job on restart; only genuinely
    unfinished work re-queues."""
    marks = tmp_path / "marks.txt"
    eng1 = _engine(tmp_path)
    h = eng1.submit(_spec("mark", append_once_job, {"path": str(marks)}))
    _drain(eng1)
    assert eng1.registry.get(h.job_id).state is JobState.FINISHED
    eng1.launcher.shutdown()            # worker exits too
    eng1.store.close()
    time.sleep(0.3)
    # strip the journal's terminal records to force reliance on the
    # worker buffer: keep only the submit record
    state = tmp_path / "state"
    lines = (state / "journal.jsonl").read_text().splitlines()
    keep = [ln for ln in lines if '"t": "submit"' in ln]
    (state / "journal.jsonl").write_text("\n".join(keep) + "\n")

    eng2 = _engine(tmp_path)
    assert eng2.recovery.worker_results == 1
    job = eng2.registry.get(h.job_id)
    assert job.state is JobState.FINISHED
    assert job.outputs["marked"] == h.job_id
    assert marks.read_text().splitlines() == [h.job_id]     # no re-run
    eng2.launcher.shutdown()
    eng2.store.close()


def test_duplicate_result_replay_applies_once(tmp_path):
    """adopt() replays the worker's whole buffer; a job the journal
    already settled must not settle twice."""
    eng1 = _engine(tmp_path)
    h = eng1.submit(_spec("e", echo_job))
    _drain(eng1)
    eng1.store.close()
    eng1.launcher._disconnect()     # worker stays alive with the buffer
    del eng1

    eng2 = _engine(tmp_path)
    # journal adopted it as terminal; the buffered duplicate was dropped
    assert eng2.recovery.terminal == 1
    assert eng2.recovery.worker_results == 0
    assert eng2.registry.get(h.job_id).state is JobState.FINISHED
    assert eng2.launcher.pending() == 0
    eng2.launcher.shutdown()
    eng2.store.close()
