"""Profiler + auto-provisioner behaviour, against a synthetic multiplicative
ground-truth oracle (the paper's model is exactly recoverable -> tight
assertions), plus constrained-search invariants across random seeds
(property-style sweep)."""
import math

import numpy as np
import pytest

from repro.core.acai import AcaiPlatform
from repro.core.engine.registry import JobSpec
from repro.core.provision.autoprovision import AutoProvisioner
from repro.core.provision.pricing import CPU_PRICING, Pricing, ResourceDim
from repro.core.provision.profiler import (CommandTemplate, LogLinearModel,
                                           Profiler)


def oracle_runtime(cfg, noise=0.0, rng=None):
    """t = t1 * epochs * c^-0.9 * m^-0.05 (paper Fig. 10 shape)."""
    t = 120.0 * cfg["epoch"] * cfg["vcpu"] ** -0.9 * \
        (cfg["mem_mb"] / 512.0) ** -0.05
    if noise:
        t *= math.exp(rng.normal(0, noise))
    return t


TEMPLATE = CommandTemplate(
    name="mnist",
    hints={"epoch": [1, 2, 3]},
    resource_hints={"vcpu": [0.5, 1, 2], "mem_mb": [512, 1024, 2048]})


def test_loglinear_exact_recovery():
    grid = TEMPLATE.grid()
    runtimes = [oracle_runtime(c) for c in grid]
    model = LogLinearModel(TEMPLATE.feature_names).fit(grid, runtimes)
    # the model family contains the oracle -> near-exact extrapolation
    test_cfg = {"epoch": 20, "vcpu": 7.5, "mem_mb": 4096}
    assert model.predict(test_cfg) == pytest.approx(
        oracle_runtime(test_cfg), rel=1e-6)


@pytest.mark.parametrize("seed", range(5))
def test_loglinear_beats_averaging_with_noise(seed):
    rng = np.random.default_rng(seed)
    grid = TEMPLATE.grid()
    runtimes = [oracle_runtime(c, noise=0.1, rng=rng) for c in grid]
    model = LogLinearModel(TEMPLATE.feature_names).fit(grid, runtimes)
    # eval on the paper's extrapolated grid
    eval_cfgs = [{"epoch": e, "vcpu": c, "mem_mb": m}
                 for e in (5, 10, 20) for c in (0.5, 1, 2, 4, 8)
                 for m in (512, 2048, 8192)]
    true = np.array([oracle_runtime(c, noise=0.1, rng=rng)
                     for c in eval_cfgs])
    pred = model.predict_many(eval_cfgs)
    ours = LogLinearModel.errors(pred, true)
    base = LogLinearModel.errors(np.full_like(true, true.mean()), true)
    assert ours["l1"] < base["l1"]
    # per-seed extrapolation quality varies with noise draw; the Table-1
    # benchmark reports the actual figure (paper: 98 %)
    assert ours["variance_explained"] > 0.75


def test_profiler_through_engine_with_quorum(tmp_path):
    # virtual fleet: runtime oracle drives virtual durations
    plat = AcaiPlatform(
        tmp_path, virtual=True, quota_k=1000,
        oracle=lambda job: oracle_runtime(job.spec.args))
    admin = plat.create_project(plat.admin_token, "proj")
    profiler = plat.make_profiler(admin)

    def job_factory(cfg):
        return JobSpec(name="prof", project="proj", user="u", args=cfg,
                       resources={k: cfg[k] for k in ("vcpu", "mem_mb")})

    class _Eng:  # thin facade binding submit to the platform
        registry = plat.engine(admin).registry
        scheduler = plat.engine(admin).scheduler

        @staticmethod
        def submit(spec):
            return plat.submit_job(admin, spec)

    profiler.engine = _Eng()
    model = profiler.profile(TEMPLATE, job_factory)
    cfgs, runtimes = profiler.training_sets["mnist"]
    assert len(cfgs) >= int(0.95 * len(TEMPLATE.grid()))
    assert model.predict({"epoch": 10, "vcpu": 4, "mem_mb": 1024}) == \
        pytest.approx(oracle_runtime(
            {"epoch": 10, "vcpu": 4, "mem_mb": 1024}), rel=1e-6)


def _fit_profiler():
    grid = TEMPLATE.grid()
    prof = Profiler(engine=None)
    prof.fit_offline(TEMPLATE, grid, [oracle_runtime(c) for c in grid])
    return prof


def test_optimize_runtime_under_cost(tmp_path):
    prof = _fit_profiler()
    ap = AutoProvisioner(prof, CPU_PRICING)
    baseline = {"vcpu": 2.0, "mem_mb": 7680}
    values = {"epoch": 20}
    t_base = oracle_runtime({**values, **baseline})
    c_base = CPU_PRICING.job_cost(baseline, t_base)
    dec = ap.optimize_runtime("mnist", values, max_cost=c_base)
    assert dec.feasible
    assert dec.predicted_cost <= c_base * (1 + 1e-9)
    assert dec.predicted_runtime < t_base        # speedup achieved
    # provisioner should pick more CPU, less memory (paper Table 2 pattern)
    assert dec.resources["vcpu"] > baseline["vcpu"]
    assert dec.resources["mem_mb"] < baseline["mem_mb"]


def test_optimize_cost_under_runtime(tmp_path):
    prof = _fit_profiler()
    ap = AutoProvisioner(prof, CPU_PRICING)
    baseline = {"vcpu": 2.0, "mem_mb": 7680}
    values = {"epoch": 20}
    t_base = oracle_runtime({**values, **baseline})
    c_base = CPU_PRICING.job_cost(baseline, t_base)
    dec = ap.optimize_cost("mnist", values, max_runtime=t_base)
    assert dec.feasible
    assert dec.predicted_runtime <= t_base * (1 + 1e-9)
    assert dec.predicted_cost < c_base           # cost reduction achieved
    # conservative allocation (paper Table 3 pattern): far below baseline mem
    assert dec.resources["mem_mb"] <= 2048


def test_infeasible_constraints():
    prof = _fit_profiler()
    ap = AutoProvisioner(prof, CPU_PRICING)
    dec = ap.optimize_runtime("mnist", {"epoch": 20}, max_cost=1e-9)
    assert not dec.feasible


@pytest.mark.parametrize("seed", range(8))
def test_search_invariants_random_pricing(seed):
    """Property sweep: for random pricing/constraints, the decision is
    always feasible-optimal within the table."""
    rng = np.random.default_rng(seed)
    pricing = Pricing([
        ResourceDim("vcpu", 0.5, 8.0, float(rng.uniform(0.01, 0.1)),
                    tuple(np.arange(0.5, 8.5, 0.5))),
        ResourceDim("mem_mb", 512, 8192, float(rng.uniform(1e-6, 1e-5)),
                    tuple(range(512, 8448, 256))),
    ])
    prof = _fit_profiler()
    ap = AutoProvisioner(prof, pricing)
    budget = float(rng.uniform(0.001, 0.2))
    dec = ap.optimize_runtime("mnist", {"epoch": 5}, max_cost=budget)
    feas = [r for r in dec.table if r["feasible"]]
    if not feas:
        assert not dec.feasible
        return
    assert dec.feasible
    assert dec.predicted_runtime == pytest.approx(
        min(r["runtime"] for r in feas))
    assert dec.predicted_cost <= budget * (1 + 1e-9)
