"""Property-style sweep: the indexed metadata store must agree with a
brute-force reference under random operation sequences (hypothesis is not
installed in this offline container — seeded randomized sweeps assert the
same invariants)."""
import numpy as np
import pytest

from repro.core.datalake.metadata import MetadataStore


def brute_find(docs, conditions):
    out = []
    for aid, doc in docs.items():
        ok = True
        for key, cond in conditions.items():
            v = doc.get(key)
            if v is None:
                ok = False
                break
            if isinstance(cond, tuple):
                op = cond[0]
                if op == "range":
                    ok = cond[1] < v < cond[2]
                elif op == ">":
                    ok = v > cond[1]
                elif op == "<":
                    ok = v < cond[1]
            else:
                ok = v == cond
            if not ok:
                break
        if ok:
            out.append(aid)
    return sorted(out)


@pytest.mark.parametrize("seed", range(10))
def test_random_ops_match_bruteforce(tmp_path, seed):
    rng = np.random.default_rng(seed)
    store = MetadataStore(tmp_path / f"s{seed}")
    docs = {}
    keys = ["loss", "acc", "epoch"]
    models = ["bert", "gpt", "t5"]
    for _ in range(rng.integers(20, 60)):
        aid = f"a{rng.integers(0, 30)}"
        attrs = {}
        if rng.random() < 0.8:
            attrs[str(rng.choice(keys))] = float(
                np.round(rng.uniform(0, 10), 3))
        if rng.random() < 0.5:
            attrs["model"] = str(rng.choice(models))
        if aid not in docs:
            store.register(aid, kind="job", **attrs)
            docs[aid] = {"kind": "job", **attrs}
        else:
            store.put(aid, **attrs)
            docs[aid].update(attrs)

    # equality, range, threshold queries vs brute force
    for key in keys:
        thr = float(rng.uniform(0, 10))
        assert store.find(**{key: (">", thr)}) == \
            brute_find(docs, {key: (">", thr)})
        lo, hi = sorted(rng.uniform(0, 10, 2))
        assert store.find(**{key: ("range", float(lo), float(hi))}) == \
            brute_find(docs, {key: ("range", float(lo), float(hi))})
    for mdl in models:
        assert store.find(model=mdl) == brute_find(docs, {"model": mdl})
    # conjunction
    got = store.find(model="bert", loss=("<", 5.0))
    assert got == brute_find(docs, {"model": "bert", "loss": ("<", 5.0)})
    # max/min agree with brute force over the same filter
    ids = store.find(kind="job")
    with_loss = [(docs[a]["loss"], a) for a in ids if "loss" in docs[a]]
    if with_loss:
        assert store.find_max("loss", kind="job") == \
            max(with_loss)[1]
        assert store.find_min("loss", kind="job") == \
            min(with_loss)[1]

    # persistence: reload gives identical answers
    store2 = MetadataStore(tmp_path / f"s{seed}")
    assert store2.find(model="gpt") == store.find(model="gpt")
