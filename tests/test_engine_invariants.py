"""Runtime counterparts of the acailint invariants: codec completeness
by dataclass introspection, monitor thread-safety, launch-abort
reservation unwinding, epoch-stamped terminal events, and journaled
adoption — regression tests for the violations the linter surfaced."""
import dataclasses
import threading
from pathlib import Path

import pytest

from repro.core.engine.cluster import Cluster
from repro.core.engine.durable import codec
from repro.core.engine.durable.jobs import echo_job
from repro.core.engine.durable.journal import JOURNAL_STREAM, Journal
from repro.core.engine.durable.store import MemoryStore
from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_SCHEDULER)
from repro.core.engine.faults import FaultPlan
from repro.core.engine.launcher import VirtualRunner
from repro.core.engine.lifecycle import JobState
from repro.core.engine.monitor import JobMonitor
from repro.core.engine.registry import (GangSpec, Job, JobRegistry,
                                        JobSpec, RetryPolicy)
from repro.core.engine.scheduler import Scheduler
from tools.acailint.checks.codec import runtime_only_fields
from tools.acailint.core import SourceFile

REPO = Path(__file__).resolve().parents[1]


def _spec(name="j", user="u", duration=1.0, resources=None, **kw):
    return JobSpec(name=name, project="p", user=user, duration=duration,
                   resources=resources or {}, **kw)


def _engine(cluster=None, quota_k=100):
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus)
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      cluster=cluster)
    return registry, bus, runner, sched


# -- codec completeness (runtime half of ACAI301) ----------------------
def _runtime_only(class_name, filename="src/repro/core/engine/registry.py"):
    return runtime_only_fields(SourceFile.load(REPO / filename), class_name)


def _full_spec():
    return JobSpec(
        name="train", project="proj", user="alice", fn=echo_job,
        argv=["--lr", "0.1"], input_fileset="fs-in", output_fileset="fs-out",
        resources={"vcpu": 2.0}, args={"k": "v"}, duration=3.5, priority=7,
        depends_on=["job-9"], pool="gpu",
        pool_resources={"gpu": {"vcpu": 4.0}}, template="tmpl",
        gang=GangSpec(n_pods=4, per_pod_resources={"vcpu": 1.0},
                      topology="close", min_pods=2),
        input_bytes=2048.0,
        retry=RetryPolicy(max_retries=2, backoff_base=0.5,
                          backoff_cap=9.0, retry_on="any"),
        timeout_s=60.0, deadline=99.0)


def _full_job():
    job = Job(job_id="job-7", spec=_full_spec(), state=JobState.PREEMPTED)
    job.started_at = 10.0
    job.finished_at = 20.0
    job.runtime = 1.5
    job.cost = 2.25
    job.pool = "gpu"
    job.error = "boom"
    job.outputs = {"log": "l"}
    job.epoch = 3
    job.preemptions = 2
    job.gang_pods = 4
    job.retries = 1
    job.failures = 2
    return job


@pytest.mark.parametrize("cls,encode,decode,sample,src", [
    (JobSpec, codec.encode_spec, codec.decode_spec, _full_spec,
     "src/repro/core/engine/registry.py"),
    (Job, codec.encode_job, codec.decode_job, _full_job,
     "src/repro/core/engine/registry.py"),
    (GangSpec, codec.encode_gang, codec.decode_gang,
     lambda: GangSpec(n_pods=4, per_pod_resources={"vcpu": 1.0},
                      topology="close", min_pods=2),
     "src/repro/core/engine/registry.py"),
    (RetryPolicy, codec.encode_retry, codec.decode_retry,
     lambda: RetryPolicy(max_retries=2, backoff_base=0.5,
                         backoff_cap=9.0, retry_on="any"),
     "src/repro/core/engine/registry.py"),
    (FaultPlan, codec.encode_fault_plan, codec.decode_fault_plan,
     lambda: FaultPlan(seed=3, node_mtbf_s=100.0, transient_mtbf_s=50.0,
                       straggler_mtbf_s=25.0, straggler_factor=2.0,
                       start=5.0, max_node_failures=4),
     "src/repro/core/engine/faults.py"),
])
def test_every_dataclass_field_round_trips(cls, encode, decode, sample,
                                           src):
    """Introspect ``dataclasses.fields``: every field that is not marked
    runtime-only must appear in the encoded doc and survive the round
    trip — a field added to the dataclass but not the codec fails here
    (and in acailint) instead of silently vanishing across a crash."""
    runtime_only = _runtime_only(cls.__name__, src)
    persisted = {f.name for f in dataclasses.fields(cls)} - runtime_only
    obj = sample()
    doc = encode(obj)
    assert set(doc) == persisted
    back = decode(doc)
    for name in sorted(persisted):
        assert getattr(back, name) == getattr(obj, name), name


def test_runtime_only_markers_match_expectations():
    # the marker is the single source of truth shared by linter and
    # tests; pin the current set so accidental marker drift is loud
    assert _runtime_only("Job") == {"preempt_flag", "retry_pending"}
    assert _runtime_only("JobSpec") == set()


# -- monitor thread-safety (ACAI101 fixes) -----------------------------
def test_monitor_aggregates_exact_under_concurrent_ingest():
    bus = EventBus()
    mon = JobMonitor(bus, max_samples=100)
    n, threads = 200, 8

    def feed():
        for i in range(n):
            bus.publish(TOPIC_SCHEDULER,
                        {"now": float(i), "utilization": {"vcpu": 0.5}})

    workers = [threading.Thread(target=feed) for _ in range(threads)]
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    # ingest counters are exact, not approximately-right: a torn
    # unguarded update would drop increments under contention
    assert mon.samples_seen == n * threads
    has, peak, mean = mon.utilization_summary()
    assert has
    assert peak == {"vcpu": 0.5}
    assert abs(mean["vcpu"] - 0.5) < 1e-9
    assert mon.peak_utilization() == peak
    assert mon.mean_utilization() == mean


def test_monitor_record_status_semantics():
    bus = EventBus()
    mon = JobMonitor(bus)
    mon.record_status("job-1", "FAILED")
    mon.record_status("job-1", "FINISHED", overwrite=False)
    assert mon.status["job-1"] == "FAILED"      # replay never clobbers
    mon.record_status("job-1", "FINISHED")
    assert mon.status["job-1"] == "FINISHED"
    assert mon.is_terminal("job-1")


def test_monitor_drops_stale_epoch_terminal():
    registry, bus, _, _ = _engine()
    mon = JobMonitor(bus, registry=registry)
    job = registry.submit(_spec())
    for state in (JobState.QUEUED, JobState.LAUNCHING, JobState.RUNNING):
        registry.set_state(job.job_id, state)
    registry.mark_preempted(job.job_id)         # epoch 0 -> 1
    bus.publish(TOPIC_CONTAINER_STATUS,
                {"job_id": job.job_id, "status": "FAILED", "epoch": 0})
    # the zombie incarnation's terminal is kept as history but never
    # cached as the job's status
    assert mon.status.get(job.job_id) != "FAILED"
    assert any(e.get("status") == "FAILED" for e in mon.watch(job.job_id))


# -- launch-abort unwinding (ACAI401 fix) ------------------------------
class _ExplodingRunner(VirtualRunner):
    def launch(self, job):
        raise RuntimeError("launcher exploded")


def test_aborted_launch_releases_reservation_and_fails_job():
    cl = Cluster({"vcpu": 8.0}, {"vcpu": 1.0})
    registry = JobRegistry()
    bus = EventBus()
    runner = _ExplodingRunner(registry, bus)
    sched = Scheduler(registry, runner, bus, quota_k=10, cluster=cl)
    job = registry.submit(_spec(resources={"vcpu": 2.0}))
    with pytest.raises(RuntimeError, match="launcher exploded"):
        sched.submit(job)
    # the reservation taken just before launch was handed back...
    assert cl.reservations() == {}
    assert all(v == 0.0 for v in cl.used.values())
    # ...and the job terminal-ized instead of stranding in LAUNCHING
    assert job.state == JobState.FAILED
    assert "launch aborted" in (job.error or "")
    assert sched.active_count("p", "u") == 0
    msg = {"job_id": job.job_id, "status": "FAILED", "epoch": 0}
    assert (TOPIC_CONTAINER_STATUS, msg) in bus.history


# -- epoch-stamped terminal publishes (ACAI202 fixes) ------------------
def test_queued_kill_event_carries_epoch_stamp():
    registry, bus, _, sched = _engine()
    parent = registry.submit(_spec("parent", duration=100.0))
    sched.submit(parent)
    child = registry.submit(_spec("child", depends_on=[parent.job_id]))
    sched.submit(child)
    sched.kill(child.job_id)            # held on its parent: never launched
    assert (TOPIC_CONTAINER_STATUS,
            {"job_id": child.job_id, "status": "KILLED",
             "epoch": 0}) in bus.history


def test_upstream_failure_event_carries_epoch_stamp():
    registry, bus, _, sched = _engine()
    parent = registry.submit(_spec("parent", duration=100.0))
    sched.submit(parent)
    child = registry.submit(_spec("child", depends_on=[parent.job_id]))
    sched.submit(child)
    sched.kill(parent.job_id)
    sched.run_to_completion()
    assert child.state == JobState.UPSTREAM_FAILED
    assert any(t == TOPIC_CONTAINER_STATUS
               and m.get("job_id") == child.job_id
               and m.get("status") == "UPSTREAM_FAILED"
               and m.get("epoch") == 0
               for t, m in bus.history)


# -- journaled adoption (ACAI302 fix) ----------------------------------
def test_adopt_journals_outside_recovery_and_not_inside():
    store = MemoryStore()
    journal = Journal(store)
    registry = JobRegistry(journal=journal)
    job = Job(job_id="job-5", spec=_spec(), state=JobState.RUNNING)

    with journal.paused():              # recovery replay: no re-records
        registry.adopt(job)
    assert store.read(JOURNAL_STREAM) == []

    other = Job(job_id="job-6", spec=_spec(), state=JobState.RUNNING)
    registry.adopt(other)               # live adoption: fully journaled
    kinds = [r["t"] for r in store.read(JOURNAL_STREAM)]
    assert kinds == ["submit", "state"]
    # the id counter advanced past both, journaled or not
    assert registry.submit(_spec()).job_id == "job-7"


def test_force_state_journals_and_stamps_started_at():
    store = MemoryStore()
    journal = Journal(store)
    registry = JobRegistry(journal=journal)
    job = registry.submit(_spec())
    assert job.started_at is None
    registry.force_state(job.job_id, JobState.RUNNING)
    assert job.state == JobState.RUNNING
    assert job.started_at is not None
    states = [r for r in store.read(JOURNAL_STREAM) if r["t"] == "state"]
    assert states and states[-1]["state"] == "RUNNING"
