"""Elastic scaling: a checkpoint saved under one mesh restores — correctly
resharded — onto a DIFFERENT device count (subprocess for device count)."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.acai import AcaiProject
from repro.train.checkpoints import CheckpointManager

proj = AcaiProject("p", "/tmp/acai-elastic")
ckpt = CheckpointManager(proj, "elastic")

mesh_a = jax.make_mesh((4,), ("model",), devices=jax.devices()[:4])
mesh_b = jax.make_mesh((2,), ("model",), devices=jax.devices()[:2])
spec = {"w": P("model", None), "b": P(None)}

w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
b = jnp.ones((8,), jnp.float32)
params_a = {"w": jax.device_put(w, NamedSharding(mesh_a, spec["w"])),
            "b": jax.device_put(b, NamedSharding(mesh_a, spec["b"]))}
ckpt.save(3, params_a)

restored, step = ckpt.restore({"params": params_a}, mesh=mesh_b,
                              specs={"params": spec})
rw = restored["params"]["w"]
ok_vals = bool(jnp.array_equal(rw, w))
ok_shard = len(rw.sharding.device_set) == 2
print("RESULT::" + json.dumps({"step": step, "vals": ok_vals,
                               "devices": ok_shard}))
"""


@pytest.mark.slow
def test_checkpoint_restores_to_different_mesh():
    import shutil
    shutil.rmtree("/tmp/acai-elastic", ignore_errors=True)
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=300,
                          env={**__import__("os").environ,
                               "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT::")][0]
    out = json.loads(line[len("RESULT::"):])
    assert out == {"step": 3, "vals": True, "devices": True}
