"""Futures/Pipeline SDK: JobHandle resolution, DAG dependency gating,
cancel, upstream-failure cascade, fan-out sweeps, fair-share decay."""
import threading

import pytest

from repro.core.acai import AcaiPlatform
from repro.core.engine.handle import (JobFailedError, UpstreamFailedError,
                                      wait_all)
from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import JobSpec


def _spec(name, **kw):
    kw.setdefault("resources", {"vcpu": 1, "mem_mb": 256})
    return JobSpec(name=name, project="", user="", **kw)


@pytest.fixture
def thread_plat(tmp_path):
    plat = AcaiPlatform(tmp_path, runner="thread", max_workers=4,
                        quota_k=100)
    admin = plat.create_project(plat.admin_token, "proj")
    return plat, admin


@pytest.fixture
def virtual_plat(tmp_path):
    plat = AcaiPlatform(tmp_path, virtual=True, quota_k=100)
    admin = plat.create_project(plat.admin_token, "proj")
    return plat, admin


# -- diamond dependency ordering ----------------------------------------

def test_diamond_order_thread(thread_plat):
    """A -> {B, C} -> D on real worker threads: every parent finishes
    before its child starts, and all handles resolve FINISHED."""
    plat, admin = thread_plat
    order, lock = [], threading.Lock()

    def step(label):
        def fn(workdir, job):
            with lock:
                order.append(label)
        return fn

    pipe = plat.pipeline(admin, name="diamond")
    a = pipe.stage(_spec("A", fn=step("A")))
    b = pipe.stage(_spec("B", fn=step("B")), after=a)
    c = pipe.stage(_spec("C", fn=step("C")), after=a)
    d = pipe.stage(_spec("D", fn=step("D")), after=[b, c])
    handles = pipe.run()
    assert pipe.wait(timeout=60) == [JobState.FINISHED] * 4
    assert order.index("A") < min(order.index("B"), order.index("C"))
    assert order.index("D") > max(order.index("B"), order.index("C"))
    assert [h.job_id for h in handles] == \
        [s.job_id for s in (a, b, c, d)]


def test_diamond_virtual_clock(virtual_plat):
    """Gating on the virtual clock: D launches only at max(end B, end C)."""
    plat, admin = virtual_plat
    eng = plat.engine(admin)
    a = plat.submit_job(admin, _spec("A", duration=1.0))
    b = plat.submit_job(admin, _spec("B", duration=1.0,
                                     depends_on=[a.job_id]))
    c = plat.submit_job(admin, _spec("C", duration=2.0,
                                     depends_on=[a.job_id]))
    d = plat.submit_job(admin, _spec("D", duration=1.0,
                                     depends_on=[b.job_id, c.job_id]))
    # only A launched; B, C, D are held out of every dispatch queue
    assert a.status() == JobState.RUNNING
    assert {b.status(), c.status(), d.status()} == {JobState.QUEUED}
    assert eng.scheduler.held_count() == 3
    assert wait_all([a, b, c, d], timeout=30) == [JobState.FINISHED] * 4
    # A ends t=1; B ends 2, C ends 3; D starts at 3, ends 4
    assert eng.launcher.now == pytest.approx(4.0)
    assert eng.scheduler.held_count() == 0


def test_fileset_edges_inferred(virtual_plat):
    """input_fileset == another stage's output_fileset => implicit edge."""
    plat, admin = virtual_plat
    pipe = plat.pipeline(admin, name="etl")
    pipe.stage(_spec("etl", duration=5.0, output_fileset="Clean"))
    train = pipe.stage(_spec("train", duration=1.0, input_fileset="Clean",
                             output_fileset="Model"))
    pipe.run()
    # no explicit after=, yet train is gated on etl
    assert train.handle.status() == JobState.QUEUED
    assert plat.engine(admin).scheduler.held_count() == 1
    assert pipe.wait(timeout=30) == [JobState.FINISHED] * 2
    assert plat.engine(admin).launcher.now == pytest.approx(6.0)


def test_pipeline_cycle_rejected(virtual_plat):
    plat, admin = virtual_plat
    pipe = plat.pipeline(admin)
    # a consumes what b produces and vice versa: no valid topo order
    pipe.stage(_spec("a", duration=1.0, input_fileset="X",
                     output_fileset="Y"))
    pipe.stage(_spec("b", duration=1.0, input_fileset="Y",
                     output_fileset="X"))
    with pytest.raises(ValueError, match="cycle"):
        pipe.run()


# -- cancel ---------------------------------------------------------------

def test_cancel_queued_handle(tmp_path):
    plat = AcaiPlatform(tmp_path, virtual=True, quota_k=1)
    admin = plat.create_project(plat.admin_token, "proj")
    eng = plat.engine(admin)
    running = plat.submit_job(admin, _spec("long", duration=100.0))
    queued = plat.submit_job(admin, _spec("victim", duration=1.0))
    assert queued.status() == JobState.QUEUED
    assert queued.cancel() == JobState.KILLED
    # the kill published a terminal event: monitor + waiters observe it
    assert eng.monitor.status[queued.job_id] == "KILLED"
    assert queued.wait(timeout=5) == JobState.KILLED
    assert running.wait(timeout=30) == JobState.FINISHED


def test_cancel_held_handle_cascades(virtual_plat):
    """Cancelling a held job upstream-fails everything declared below."""
    plat, admin = virtual_plat
    a = plat.submit_job(admin, _spec("a", duration=50.0))
    b = plat.submit_job(admin, _spec("b", duration=1.0,
                                     depends_on=[a.job_id]))
    c = plat.submit_job(admin, _spec("c", duration=1.0,
                                     depends_on=[b.job_id]))
    b.cancel()
    assert b.status() == JobState.KILLED
    assert c.status() == JobState.UPSTREAM_FAILED
    with pytest.raises(UpstreamFailedError):
        c.result()
    assert a.wait(timeout=30) == JobState.FINISHED


# -- upstream-failure cascade ---------------------------------------------

def test_upstream_failure_cascade_thread(thread_plat):
    plat, admin = thread_plat

    def boom(workdir, job):
        raise RuntimeError("etl exploded")

    def never(workdir, job):  # pragma: no cover - must not run
        raise AssertionError("dependent of a failed job must not run")

    pipe = plat.pipeline(admin, name="cascade")
    etl = pipe.stage(_spec("etl", fn=boom))
    trains = pipe.map(lambda p: _spec(f"train-{p['i']}", fn=never),
                      [{"i": 0}, {"i": 1}], after=etl)
    report = pipe.stage(_spec("report", fn=never), after=trains)
    pipe.run()
    states = [h.wait(timeout=60) for h in pipe.handles]
    assert states == [JobState.FAILED] + [JobState.UPSTREAM_FAILED] * 3
    with pytest.raises(JobFailedError):
        etl.handle.result()
    with pytest.raises(UpstreamFailedError) as ei:
        report.handle.result()
    assert "did not finish" in str(ei.value)


def test_upstream_fail_already_terminal_parent(thread_plat):
    """Submitting after the parent already failed cascades immediately."""
    plat, admin = thread_plat

    def boom(workdir, job):
        raise RuntimeError("nope")

    parent = plat.submit_job(admin, _spec("p", fn=boom))
    assert parent.wait(timeout=30) == JobState.FAILED
    child = plat.submit_job(admin, _spec("c", fn=lambda w, j: None,
                                         depends_on=[parent.job_id]))
    assert child.status() == JobState.UPSTREAM_FAILED
    # a parent that FINISHED gates nothing
    ok = plat.submit_job(admin, _spec("ok", fn=lambda w, j: {"x": 1}))
    assert ok.wait(timeout=30) == JobState.FINISHED
    dep = plat.submit_job(admin, _spec("dep", fn=lambda w, j: None,
                                       depends_on=[ok.job_id]))
    assert dep.wait(timeout=30) == JobState.FINISHED


def test_unknown_dependency_rejected(virtual_plat):
    plat, admin = virtual_plat
    with pytest.raises(ValueError, match="unknown job"):
        plat.submit_job(admin, _spec("x", duration=1.0,
                                     depends_on=["job-999"]))


# -- Pipeline.map sweep + metadata + provenance ---------------------------

def test_map_sweep_metadata_and_provenance(thread_plat):
    """ETL -> map sweep -> report, zero manual sequencing: accuracies are
    queryable, the report sees every model, and provenance has one
    declared edge per DAG edge."""
    plat, admin = thread_plat
    proj = plat.project(admin)
    proj.upload("/raw/data.txt", b"3 1 4 1 5", creator="admin")
    proj.create_file_set("Raw", ["/raw/data.txt"], creator="admin")

    def etl(workdir, job):
        vals = (workdir / "raw/data.txt").read_text().split()
        (workdir / "out/clean.txt").write_text(" ".join(sorted(vals)))

    def train(workdir, job):
        lr = job.spec.args["lr"]
        n = len((workdir / "Clean/clean.txt").read_text().split())
        print(f"[[acai:accuracy={lr * n},lr={lr}]]")

    def report(workdir, job):
        best = proj.metadata.find_max("accuracy", kind="job")
        (workdir / "out/best.txt").write_text(str(best))

    pipe = plat.pipeline(admin, name="sweep")
    pipe.stage(_spec("etl", fn=etl, input_fileset="Raw",
                     output_fileset="Clean"))
    trains = pipe.map(
        lambda p: _spec(f"train-lr{p['lr']}", fn=train, args=dict(p),
                        input_fileset="Clean",
                        output_fileset=f"model-{p['lr']}"),
        {"lr": [0.1, 0.2, 0.4]})
    pipe.stage(_spec("report", fn=report, output_fileset="Report"),
               after=trains)
    handles = pipe.run()
    assert pipe.wait(timeout=120) == [JobState.FINISHED] * 5
    # sweep metadata is queryable (log parser -> indexed metadata)
    best = proj.metadata.find_max("accuracy", kind="job")
    assert best == handles[3].job_id          # lr=0.4
    assert proj.metadata.get(best)["accuracy"] == pytest.approx(2.0)
    # one provenance edge per declared DAG edge: 3 etl->train + 3 ->report
    edges = proj.provenance.dependency_edges(pipeline="sweep")
    assert len(edges) == 6
    etl_id = handles[0].job_id
    assert sorted(v for u, v, _ in edges if u == etl_id) == \
        sorted(h.job_id for h in handles[1:4])
    # the declared edges carry the dataflow filesets
    assert {d["src_fileset"] for _, v, d in edges if v != handles[4].job_id} \
        == {"Clean"}


def test_map_grid_forms(virtual_plat):
    plat, admin = virtual_plat
    pipe = plat.pipeline(admin)
    product = pipe.map(lambda p: _spec(f"a-{p['x']}-{p['y']}", duration=1.0),
                      {"x": [1, 2], "y": [3, 4]})
    explicit = pipe.map(lambda p: _spec(f"b-{p['x']}", duration=1.0),
                        [{"x": 9}])
    assert len(product) == 4 and len(explicit) == 1
    assert pipe.run() and pipe.wait(timeout=30) == [JobState.FINISHED] * 5


# -- run_all deprecation shim ---------------------------------------------

def test_run_all_deprecated(virtual_plat):
    plat, admin = virtual_plat
    h = plat.submit_job(admin, _spec("j", duration=1.0))
    eng = plat.engine(admin)
    with pytest.deprecated_call():
        eng.run_all()
    assert h.status() == JobState.FINISHED


# -- fair-share usage decay ------------------------------------------------

def test_usage_halflife_decay(tmp_path):
    plat = AcaiPlatform(tmp_path, virtual=True, quota_k=100,
                        usage_halflife=10.0)
    admin = plat.create_project(plat.admin_token, "proj")
    eng = plat.engine(admin)
    sched = eng.scheduler
    h = plat.submit_job(admin, _spec("burn", duration=40.0))
    assert h.wait(timeout=30) == JobState.FINISHED
    key = ("proj", "proj-admin")
    charged = sched._usage[key]
    assert charged > 0
    # two half-lives later the charge has decayed to a quarter
    eng.launcher.now += 20.0
    assert sched._decayed_usage(key) == pytest.approx(charged / 4)
    # without a half-life, usage accumulates forever (seed behaviour)
    sched.usage_halflife = None
    assert sched._decayed_usage(key) == pytest.approx(charged)


def test_usage_decay_restores_priority(tmp_path):
    """After a long idle period, a queue's past burn no longer outranks a
    fresh competitor: both queues launch on fair-share order again."""
    plat = AcaiPlatform(tmp_path, virtual=True, quota_k=1,
                        cluster_nodes=1, usage_halflife=5.0)
    admin = plat.create_project(plat.admin_token, "proj")
    alice = plat.create_user(admin, "proj", "alice")
    eng = plat.engine(admin)
    # alice burns a lot of capacity early
    for _ in range(3):
        plat.submit_job(alice, _spec("a", duration=100.0))
    eng.wait_all()
    assert eng.scheduler._decayed_usage(("proj", "alice")) > 0
    # long idle gap: alice's usage decays below any fresh admin burn
    eng.launcher.now += 10_000.0
    a = plat.submit_job(alice, _spec("late-a", duration=1.0))
    assert eng.scheduler._decayed_usage(("proj", "alice")) < 1e-9
    assert a.wait(timeout=30) == JobState.FINISHED


# -- registry lock (satellite bugfix) -------------------------------------

def test_registry_reads_locked(thread_plat):
    """get()/all_jobs() under concurrent submit: no lost reads/races."""
    plat, admin = thread_plat
    eng = plat.engine(admin)
    errors = []

    def reader():
        try:
            for _ in range(200):
                for j in eng.registry.all_jobs():
                    eng.registry.get(j.job_id)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    t = threading.Thread(target=reader)
    t.start()
    handles = [plat.submit_job(admin, _spec(f"j{i}", fn=lambda w, j: None))
               for i in range(30)]
    t.join()
    assert not errors
    assert wait_all(handles, timeout=120) == [JobState.FINISHED] * 30
