"""Training substrate: optimizer, microbatching, compression, checkpoints,
fault-tolerant supervision, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.acai import AcaiProject
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.train import compression as C
from repro.train.checkpoints import CheckpointManager
from repro.train.fault import JobPreempted, TrainSupervisor
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state, schedule)
from repro.train.train_step import (TrainConfig, make_loss_fn,
                                    make_opt_state, make_train_step)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.array(s))) for s in (0, 5, 10, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] == pytest.approx(0.1)


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    huge = {"w": jnp.full(3, 1e6)}
    _, _, m = adamw_update(cfg, params, huge, opt)
    assert float(m["grad_norm"]) > 1e5   # reported pre-clip


def _tiny_setup(arch="olmo-1b", **tkw):
    cfg = get_arch(arch).reduced()
    from repro.models import model as M
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(**tkw)
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                           weight_decay=0.0)
    step = make_train_step(cfg, tcfg, ocfg)
    # data vocab << model vocab: fast-learnable structure for the assertion
    pipe = TokenPipeline(DataConfig(vocab_size=32, seq_len=32,
                                    global_batch=16, markov_temp=2.5), cfg)
    return cfg, params, tcfg, step, pipe


def test_train_loss_decreases():
    cfg, params, tcfg, step, pipe = _tiny_setup()
    opt = make_opt_state(params, tcfg)
    step = jax.jit(step)
    losses = []
    for i in range(25):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses


def test_microbatch_equals_fullbatch_grads():
    cfg, params, _, _, pipe = _tiny_setup()
    batch = jax.tree.map(jnp.asarray, pipe.batch_at(0))
    lf = make_loss_fn(cfg, TrainConfig(remat="none"))
    (_, _), g_full = jax.value_and_grad(lf, has_aux=True)(params, batch)

    tcfg = TrainConfig(microbatches=4, remat="none")
    lf4 = make_loss_fn(cfg, tcfg)
    k = 4
    micro = jax.tree.map(
        lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]), batch)
    accum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(k):
        mb = jax.tree.map(lambda a, i=i: a[i], micro)
        (_, _), g = jax.value_and_grad(lf4, has_aux=True)(params, mb)
        accum = jax.tree.map(jnp.add, accum, g)
    g_micro = jax.tree.map(lambda g: g / k, accum)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_micro)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
    res = C.init_residuals(g)
    # accumulated compressed updates track accumulated true gradient
    total_true = np.zeros((64, 64), np.float32)
    total_sent = np.zeros((64, 64), np.float32)
    for _ in range(20):
        gi = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
        sent, res = C.compress_grads_with_feedback(gi, res, "int8")
        total_true += np.asarray(gi["w"])
        total_sent += np.asarray(sent["w"])
    # error feedback keeps the drift bounded by one quantization step
    drift = np.abs(total_true - total_sent).max()
    assert drift < 0.2, drift


@pytest.mark.parametrize("kind", ["bf16", "int8"])
def test_compression_roundtrip(kind):
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(0, 3, (128,)), jnp.float32)
    q, scale = C.compress(g, kind)
    deq = C.decompress(q, scale)
    rel = float(jnp.abs(deq - g).max() / jnp.abs(g).max())
    assert rel < (0.01 if kind == "bf16" else 0.02)


def test_train_step_with_compression_runs():
    cfg, params, _, _, pipe = _tiny_setup(grad_compression="int8")
    tcfg = TrainConfig(grad_compression="int8")
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=0)
    step = jax.jit(make_train_step(cfg, tcfg, ocfg))
    opt = make_opt_state(params, tcfg)
    batch = jax.tree.map(jnp.asarray, pipe.batch_at(0))
    params2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))


def test_checkpoint_roundtrip(tmp_path):
    proj = AcaiProject("p", tmp_path)
    ckpt = CheckpointManager(proj, "run1")
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    opt = init_opt_state(params)
    ref = ckpt.save(5, params, opt, extra={"loss": 1.5})
    assert ref.endswith(":1")
    state, step = ckpt.restore({"params": params, "opt": opt})
    assert step == 5
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(params["w"]))
    # versioned history: second save -> version 2, both restorable
    params2 = jax.tree.map(lambda a: a + 1, params)
    ckpt.save(9, params2, opt)
    s2, st2 = ckpt.restore({"params": params, "opt": opt})
    assert st2 == 9
    s1, st1 = ckpt.restore({"params": params, "opt": opt}, version=1)
    assert st1 == 5
    np.testing.assert_array_equal(np.asarray(s1["params"]["w"]),
                                  np.asarray(params["w"]))
    # provenance: checkpoint registered in metadata with its step
    assert proj.metadata.get(f"run1-ckpt:2")["step"] == 9


def test_supervisor_restart_and_stragglers(tmp_path):
    proj = AcaiProject("p", tmp_path)
    ckpt = CheckpointManager(proj, "runF")
    sup = TrainSupervisor(ckpt, save_every=5, straggler_factor=3.0)

    params = {"w": jnp.zeros(2)}
    opt = init_opt_state(params)

    def step_fn(params, opt, batch):
        grads = {"w": jnp.ones(2)}
        p, o, _ = adamw_update(OptimizerConfig(lr=0.1, warmup_steps=0),
                               params, grads, opt)
        return p, o, {"loss": jnp.sum(p["w"] ** 2)}

    fails = {12}
    def failure_hook(step):
        if step in fails:
            fails.discard(step)
            raise JobPreempted(f"node died at {step}")

    # time_fn is called twice per step; entry 9 is the *within-step* delta
    # of step 4 -> one straggler step
    clock = iter(np.concatenate([np.ones(9) * 0.01, [0.5],
                                 np.ones(100) * 0.01]).cumsum())
    state, report = sup.run(step_fn, {"params": params, "opt": opt,
                                      "step": 0},
                            n_steps=20, batch_fn=lambda s: {},
                            failure_hook=failure_hook,
                            time_fn=lambda: next(clock))
    assert state["step"] == 20
    assert report.restarts == 1
    # resumed from step 10 checkpoint, not from scratch
    assert report.steps_run == 20 + (12 - 10)
    assert report.checkpoints >= 4
    assert len(report.straggler_steps) >= 1


def test_pipeline_determinism_and_sharding():
    base = DataConfig(seed=7, vocab_size=64, seq_len=16, global_batch=8,
                      n_hosts=2, host_index=0)
    p0 = TokenPipeline(base)
    p0b = TokenPipeline(base)
    np.testing.assert_array_equal(p0.batch_at(3)["tokens"],
                                  p0b.batch_at(3)["tokens"])
    import dataclasses as dc
    p1 = TokenPipeline(dc.replace(base, host_index=1))
    assert not np.array_equal(p0.batch_at(3)["tokens"],
                              p1.batch_at(3)["tokens"])
    # labels are next-token shifted
    b = p0.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
