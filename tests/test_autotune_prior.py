"""The profiler feedback loop: kernel autotuner (cache round-trip,
deterministic hillclimb), roofline cold-start priors, fitted-vs-prior
precedence with hull gating, online refit through the event bus, and the
placement fallback counters the dashboard surfaces."""
import math

import pytest

from repro.core.engine.cluster import Cluster
from repro.core.engine.dashboard import scheduler_page
from repro.core.engine.events import EventBus
from repro.core.engine.launcher import VirtualRunner
from repro.core.engine.placement import Placement
from repro.core.engine.registry import JobRegistry, JobSpec
from repro.core.engine.scheduler import Scheduler
from repro.core.provision.autotune import (KERNELS, TuningCache, cache_key,
                                           hillclimb, seed_config)
from repro.core.provision.profiler import (CommandTemplate, LogLinearModel,
                                           Profiler)
from repro.roofline.prior import (HardwareSpec, RooflinePrior, TemplateCost,
                                  roofline_ceiling_s)


# -- synthetic tuning costs (no accelerator, no timing) -------------------
def _flash_cost(cfg):
    """Convex synthetic landscape with a unique optimum at (64, 256)."""
    return (1.0 + abs(math.log2(cfg["block_q"]) - 6)
            + 0.5 * abs(math.log2(cfg["block_k"]) - 8)) * 1e-3


def test_hillclimb_finds_synthetic_optimum_deterministically():
    spec = KERNELS["flash_attention"]
    shape = {"b": 1, "s": 256, "h": 2, "kv": 2, "d": 64}
    runs = []
    for _ in range(3):
        calls = []

        def measure(cfg, calls=calls):
            calls.append(dict(cfg))
            return _flash_cost(cfg)
        best, best_t, n = hillclimb(spec, shape, measure)
        runs.append((best, best_t, n, calls))
    first = runs[0]
    assert first[0] == {"block_q": 64, "block_k": 256}
    assert first[1] == pytest.approx(_flash_cost(first[0]))
    for other in runs[1:]:          # identical walk, not just identical end
        assert other[:3] == first[:3]
        assert other[3] == first[3]


def test_hillclimb_memoizes_and_respects_hysteresis():
    spec = KERNELS["mamba2_ssd"]
    shape = {"b": 1, "s": 256, "h": 2, "p": 32, "n": 16}
    calls = []

    def flat(cfg):                  # neighbors within 3% never displace
        calls.append(dict(cfg))
        return 1.0 + 0.01 * math.log2(cfg["chunk"])
    best, _, n = hillclimb(spec, shape, flat)
    assert best == seed_config(spec, shape)
    assert len(calls) == len({tuple(c.items()) for c in calls})  # memoized
    assert n == len(calls)


def test_seed_config_steps_down_for_ragged_sequence():
    # 192 is not divisible by the MXU-default 128: pad-less kernels must
    # seed at the largest legal rung instead of crashing
    assert seed_config(KERNELS["mamba2_ssd"],
                       {"b": 1, "s": 192, "h": 2, "p": 32, "n": 16}) == \
        {"chunk": 64}
    # flash pads internally, so its default survives ragged shapes
    assert seed_config(KERNELS["flash_attention"],
                       {"b": 1, "s": 192, "h": 2, "kv": 2, "d": 80}) == \
        {"block_q": 128, "block_k": 128}


def test_tuning_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tune.json")
    cache = TuningCache()
    entry = {"kernel": "flash_attention",
             "shape": {"b": 1, "s": 256, "h": 2, "kv": 2, "d": 64},
             "family": "interpret",
             "config": {"block_q": 64, "block_k": 256},
             "us": 12.5, "max_err": 1e-6, "tol": 2e-2}
    cache.put(entry)
    cache.save(path)
    loaded = TuningCache(path)
    assert loaded.get(entry["kernel"], entry["shape"],
                      "interpret") == entry
    assert loaded.best_config(entry["kernel"], entry["shape"],
                              "interpret") == entry["config"]
    # a miss serves the caller's default untouched
    assert loaded.best_config("flash_attention", {"b": 9, "s": 128,
                                                  "h": 1, "kv": 1, "d": 64},
                              "interpret",
                              default={"block_q": 128}) == {"block_q": 128}
    assert cache_key(entry["kernel"], entry["shape"], "interpret") in \
        loaded.entries


# -- log-linear guard rails ----------------------------------------------
def test_loglinear_predict_before_fit_raises():
    m = LogLinearModel(["work"])
    with pytest.raises(RuntimeError, match="predict before fit"):
        m.predict({"work": 10.0})
    with pytest.raises(RuntimeError, match="predict before fit"):
        m.predict_many([{"work": 10.0}])


def test_loglinear_clamp_bounds_extrapolation():
    m = LogLinearModel(["work"])
    m.fit([{"work": w} for w in (10.0, 20.0, 40.0)], [10.0, 20.0, 40.0])
    raw = m.predict({"work": 1e6})            # exact power law: y = work
    assert raw == pytest.approx(1e6, rel=1e-6)
    clamped = m.predict({"work": 1e6}, clamp=True)
    assert clamped <= 40.0 * LogLinearModel.EXTRAPOLATION_SLACK
    assert m.predict({"work": 20.0}, clamp=True) == pytest.approx(20.0,
                                                                  rel=1e-6)


def test_loglinear_in_hull():
    m = LogLinearModel(["work"])
    assert not m.in_hull({"work": 10.0})      # unfit: no support
    m.fit([{"work": 10.0}], [10.0])
    assert not m.in_hull({"work": 10.0})      # one point is not support
    m.fit([{"work": w} for w in (10.0, 40.0)], [10.0, 40.0])
    assert m.in_hull({"work": 20.0})
    assert m.in_hull({"work": 79.0})          # within the 2x slack
    assert not m.in_hull({"work": 1000.0})
    assert not m.in_hull({"work": 0.1})


# -- roofline prior --------------------------------------------------------
def _prior():
    cpu = HardwareSpec("cpu", peak_flops=1e9, hbm_bw=1.0)
    tpu = HardwareSpec("tpu", peak_flops=1e9, hbm_bw=1.0, startup_s=30.0,
                       scale_dim="chips", ref_chips=1.0)
    return RooflinePrior({"cpu": cpu, "tpu": tpu}).register(
        "work", flops=lambda cfg: cfg["work"] * 1e9)


def test_roofline_prior_estimates():
    prior = _prior()
    assert prior.can_estimate("work", "cpu")
    assert not prior.can_estimate("work", "gpu")
    assert not prior.can_estimate("train", "cpu")
    assert prior.estimate("work", "cpu", {"work": 120.0}) == \
        pytest.approx(120.0)
    # 8 chips split the same FLOPs, plus the startup tax
    assert prior.estimate("work", "tpu", {"work": 120.0, "chips": 8.0}) == \
        pytest.approx(30.0 + 15.0)
    with pytest.raises(KeyError):
        prior.estimate("train", "cpu", {})


def test_roofline_ceiling_takes_binding_term():
    hw = HardwareSpec("x", peak_flops=100.0, hbm_bw=10.0, ici_bw=1.0)
    assert roofline_ceiling_s(1000.0, 1.0, hw) == pytest.approx(10.0)
    assert roofline_ceiling_s(1.0, 1000.0, hw) == pytest.approx(100.0)
    assert roofline_ceiling_s(1.0, 1.0, hw, coll_bytes=500.0) == \
        pytest.approx(500.0)
    assert roofline_ceiling_s(1000.0, 1.0, hw, n_chips=10.0) == \
        pytest.approx(1.0)


def test_template_cost_constants_and_callables():
    tc = TemplateCost(flops=7.0, nbytes=lambda c: c["n"] * 2.0)
    assert tc.evaluate({"n": 3.0}) == (7.0, 6.0, 0.0)


# -- precedence: fitted model vs prior ------------------------------------
def test_prior_serves_cold_then_fitted_takes_over():
    prof = Profiler(engine=None, prior=_prior())
    cfg = {"work": 100.0, "vcpu": 1.0}
    assert prof.resolve_source("work", "cpu", cfg) == "prior"
    assert prof.predict_for_pool("work", "cpu", cfg) == pytest.approx(100.0)
    assert prof.last_source == "prior"

    tmpl = CommandTemplate("work@cpu", {"work": [50.0, 100.0, 200.0]},
                           {"vcpu": [1.0, 2.0]})
    grid = tmpl.grid()
    prof.fit_offline(tmpl, grid, [2.0 * c["work"] for c in grid])
    assert prof.resolve_source("work", "cpu", cfg) == "pool-model"
    assert prof.predict_for_pool("work", "cpu", cfg) == \
        pytest.approx(200.0, rel=1e-6)
    assert prof.last_source == "pool-model"
    # an unknown template with no prior coverage still raises
    with pytest.raises(KeyError):
        prof.predict_for_pool("train", "cpu", cfg)


def test_out_of_hull_model_defers_to_prior():
    prof = Profiler(engine=None, prior=_prior())
    tmpl = CommandTemplate("work@cpu", {"work": [5.0, 30.0, 60.0]},
                           {"vcpu": [1.0, 2.0]})
    grid = tmpl.grid()
    prof.fit_offline(tmpl, grid, [c["work"] for c in grid])
    # in-hull: the measurement wins
    near = {"work": 30.0, "vcpu": 1.0}
    assert prof.resolve_source("work", "cpu", near) == "pool-model"
    # far outside the explored grid (an hour-long job scored by a model
    # fit on sub-minute profiling runs): the roofline prior wins
    far = {"work": 3600.0, "vcpu": 1.0}
    assert prof.resolve_source("work", "cpu", far) == "prior"
    assert prof.predict_for_pool("work", "cpu", far) == \
        pytest.approx(3600.0)
    # without a prior the (clamped) model still serves — better than 1.0s
    prof.prior = None
    assert prof.resolve_source("work", "cpu", far) == "pool-model"
    assert prof.predict_for_pool("work", "cpu", far) <= \
        60.0 * LogLinearModel.EXTRAPOLATION_SLACK


# -- online feedback -------------------------------------------------------
def test_add_observation_bootstraps_and_refits_rank():
    pools = {"cpu": Cluster({"vcpu": 8.0}, {"vcpu": 0.5}, name="cpu"),
             "tpu": Cluster({"chips": 16.0}, {"chips": 8.0}, name="tpu")}
    placement = Placement(pools, objective="runtime")
    prof = Profiler(engine=None, recency_halflife=2.0)
    placement.use_profiler(prof)
    spec = JobSpec(name="j", project="p", user="u", template="work",
                   args={"work": 100.0},
                   pool_resources={"cpu": {"vcpu": 1.0},
                                   "tpu": {"chips": 8.0}})

    # bootstrap per-pool models purely from observations (cold start)
    for w, t in ((50.0, 50.0), (100.0, 100.0), (200.0, 200.0)):
        prof.add_observation("work@cpu", {"work": w, "vcpu": 1.0}, t)
        prof.add_observation("work@tpu", {"work": w, "chips": 8.0}, t / 10)
    opts = placement.eligible(spec)
    assert placement.rank(spec, opts) == ["tpu", "cpu"]

    # the pool drifts 100x slower; recency-weighted refits must flip the
    # ranking instead of averaging the stale history forever
    for w, t in ((50.0, 500.0), (100.0, 1000.0), (200.0, 2000.0),
                 (100.0, 1000.0), (50.0, 500.0), (200.0, 2000.0)):
        prof.add_observation("work@tpu", {"work": w, "chips": 8.0}, t)
    opts = placement.eligible(spec)
    assert placement.rank(spec, opts) == ["cpu", "tpu"]


def test_attach_feedback_observes_finished_jobs():
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus,
                           oracle=lambda job: job.spec.args["work"])
    sched = Scheduler(registry, runner, bus, quota_k=4,
                      placement=Placement(
                          {"cpu": Cluster({"vcpu": 8.0}, {"vcpu": 0.5},
                                          name="cpu")}))
    prof = Profiler(engine=None)
    prof.attach_feedback(bus, registry)
    for w in (10.0, 20.0, 40.0):
        job = registry.submit(JobSpec(
            name=f"j{w}", project="p", user="u", template="work",
            args={"work": w}, resources={"vcpu": 1.0}))
        sched.submit(job)
    sched.run_to_completion()
    assert prof.has_model("work@cpu")
    configs, runtimes = prof.training_sets["work@cpu"]
    assert len(configs) == 3 and sorted(runtimes) == [10.0, 20.0, 40.0]
    # the learned pool model now serves placement's predictions
    assert prof.predict_for_pool("work", "cpu",
                                 {"work": 20.0, "vcpu": 1.0}) == \
        pytest.approx(20.0, rel=1e-6)


def test_observe_skips_jobs_without_template_or_runtime():
    prof = Profiler(engine=None)

    class FakeJob:
        spec = JobSpec(name="j", project="p", user="u", duration=1.0)
        pool = "cpu"
        runtime = 5.0
    assert not prof.observe(FakeJob())        # no template
    assert prof.training_sets == {}


# -- placement fallback counters ------------------------------------------
def _two_pool_placement(**kw):
    return Placement(
        {"cpu": Cluster({"vcpu": 8.0}, {"vcpu": 0.5}, name="cpu"),
         "tpu": Cluster({"chips": 16.0}, {"chips": 8.0}, name="tpu")},
        **kw)


def _flex_spec(duration=None, template=None):
    return JobSpec(name="j", project="p", user="u", duration=duration,
                   template=template, args={"work": 10.0},
                   pool_resources={"cpu": {"vcpu": 1.0},
                                   "tpu": {"chips": 8.0}})


def test_placement_stats_count_prediction_sources():
    placement = _two_pool_placement()
    spec = _flex_spec(duration=7.0)
    placement.rank(spec, placement.eligible(spec))
    assert placement.stats["declared"] == 2   # one per scored pool
    spec = _flex_spec()                       # no duration, no predictor
    placement.rank(spec, placement.eligible(spec))
    assert placement.stats["default"] == 2

    placement = _two_pool_placement()
    placement.use_profiler(Profiler(engine=None, prior=_prior()))
    spec = _flex_spec(template="work")
    placement.rank(spec, placement.eligible(spec))
    assert placement.stats["prior"] == 2
    assert placement.stats["predictor"] == 0


def test_dashboard_renders_prediction_sources():
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus)
    placement = _two_pool_placement()
    sched = Scheduler(registry, runner, bus, quota_k=4, placement=placement)
    job = registry.submit(_flex_spec(duration=3.0))
    sched.submit(job)
    sched.run_to_completion()
    page = scheduler_page(sched)
    assert "prediction sources:" in page
    assert "declared=2" in page
