"""Durable control plane, layer 2: crash-recoverable restart.

Covers the recovery invariants end to end: mixed in-flight states
(QUEUED / RUNNING / PREEMPTED / dependency-held) re-queued as new epochs
with checkpoint progress intact, terminal jobs adopted without a re-run,
duplicate/stale journal records and bus events dropped (exactly-once
release + settle, asserted through the cluster's underflow counters and
scheduler completion stats), cross-process terminal resolution through
the persisted registry (monitor/handle fallback), and a real SIGKILL of
a mid-fleet engine process followed by a bit-identical recovery against
the uncrashed golden run."""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

from repro.core.acai import AcaiEngine
from repro.core.engine.durable import drill
from repro.core.engine.durable.journal import JOURNAL_STREAM
from repro.core.engine.durable.jobs import echo_job
from repro.core.engine.durable.store import FileStore
from repro.core.engine.events import TOPIC_CONTAINER_STATUS
from repro.core.engine.handle import JobHandle
from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import JobSpec
from repro.core.provision.pricing import CPU_PRICING


def _engine(state_dir, **kw):
    kw.setdefault("virtual", True)
    kw.setdefault("pricing", CPU_PRICING)
    kw.setdefault("cluster_nodes", 1)       # vcpu=8, mem_mb=8192
    kw.setdefault("quota_k", 100)
    kw.setdefault("preemption", True)
    kw.setdefault("checkpoint_interval", 10.0)
    return AcaiEngine(durable=state_dir, **kw)


def _spec(name, duration, vcpu=2.0, priority=0, depends_on=()):
    return JobSpec(name=name, project="p", user="u", duration=duration,
                   priority=priority,
                   resources={"vcpu": vcpu, "mem_mb": 512.0},
                   depends_on=list(depends_on))


def _crash(engine):
    """Simulate process death: close file handles, drop the object. No
    shutdown, no snapshot — recovery sees exactly what was journaled."""
    engine.store.close()


def _underflow(engine) -> int:
    return sum(cl.stats["release_underflow"]
               for cl in engine.scheduler.pools.values())


# -- mixed-state crash + recovery ----------------------------------------
def test_recover_mixed_states(tmp_path):
    """QUEUED, RUNNING, PREEMPTED-requeued, dependency-held and terminal
    jobs all survive a crash; the recovered fleet completes with zero
    lost jobs and exactly-once settles."""
    eng = _engine(tmp_path / "s")
    h_done = eng.submit(_spec("done", duration=5.0))
    h_long = eng.submit(_spec("long", duration=100.0, vcpu=4.0))
    h_parent = eng.submit(_spec("parent", duration=50.0, vcpu=2.0))
    h_held = eng.submit(_spec("held", duration=5.0,
                              depends_on=[h_parent.job_id]))
    h_queued = eng.submit(_spec("queued", duration=5.0, vcpu=8.0))
    eng.scheduler.launcher.step()           # t=5: "done" finishes
    assert h_done.status() is JobState.FINISHED
    # preempt the long job mid-run: banks 0 full intervals? no — t=5 on a
    # 10s grid banks 0.0; advance to t=25 first via another completion
    eng.submit(_spec("filler", duration=25.0, vcpu=2.0))
    eng.scheduler.launcher.step()           # t=30: filler finishes
    assert eng.scheduler.preempt(h_long.job_id)     # 30s checkpointed
    long_job = eng.registry.get(h_long.job_id)
    assert long_job.epoch == 1 and long_job.state is JobState.QUEUED
    states = {j.spec.name: j.state for j in eng.registry.all_jobs()}
    assert states["parent"] is JobState.RUNNING
    assert states["held"] is JobState.QUEUED        # held, not dispatched
    _crash(eng)

    eng2 = _engine(tmp_path / "s")
    rep = eng2.recovery
    assert rep is not None
    assert rep.jobs_total == 6
    assert rep.terminal == 2                # done + filler adopted as-is
    assert rep.requeued == 4
    assert rep.resumed == 1                 # long's 20% checkpoint
    # epochs bumped: every requeued job is a fresh incarnation
    assert eng2.registry.get(h_long.job_id).epoch == 2
    assert eng2.registry.get(h_parent.job_id).epoch == 1
    launcher = eng2.scheduler.launcher
    while launcher.pending():
        launcher.step()
    for h in (h_done, h_long, h_parent, h_held, h_queued):
        assert eng2.registry.get(h.job_id).state is JobState.FINISHED
    # checkpoint survived: only the remaining 70s of "long" re-ran
    assert eng2.registry.get(h_long.job_id).runtime == 70.0
    # exactly-once settle: each of the 6 jobs completed exactly once in
    # eng2 except the 2 adopted terminals, and no release underflow
    assert eng2.scheduler.stats["completed"] == 4
    assert _underflow(eng2) == 0


def test_recovery_preserves_dependency_gating(tmp_path):
    """Held children survive the crash held: after recovery one parent
    finishes (child runs) and the other is killed (child cascades
    UPSTREAM_FAILED) — the dependency graph rebuilt from the journal
    behaves exactly like the live one."""
    eng = _engine(tmp_path / "s")
    h_ok = eng.submit(_spec("ok-parent", duration=50.0, vcpu=4.0))
    h_ok_child = eng.submit(_spec("ok-child", duration=5.0,
                                  depends_on=[h_ok.job_id]))
    h_bad = eng.submit(_spec("bad-parent", duration=50.0, vcpu=4.0))
    h_bad_child = eng.submit(_spec("bad-child", duration=5.0,
                                   depends_on=[h_bad.job_id]))
    assert eng.registry.get(h_ok.job_id).state is JobState.RUNNING
    _crash(eng)

    eng2 = _engine(tmp_path / "s")
    eng2.scheduler.kill(h_bad.job_id)
    launcher = eng2.scheduler.launcher
    while launcher.pending():
        launcher.step()
    assert eng2.registry.get(h_ok.job_id).state is JobState.FINISHED
    assert eng2.registry.get(h_ok_child.job_id).state is JobState.FINISHED
    assert eng2.registry.get(h_bad.job_id).state is JobState.KILLED
    assert eng2.registry.get(h_bad_child.job_id).state is \
        JobState.UPSTREAM_FAILED


# -- duplicate / stale record + event idempotency (satellite audit) -------
def test_replayed_duplicate_terminal_records_dropped(tmp_path):
    """At-least-once journal delivery: duplicating every record in the
    raw journal file changes nothing on recovery."""
    eng = _engine(tmp_path / "s")
    h1 = eng.submit(_spec("a", duration=5.0))
    h2 = eng.submit(_spec("b", duration=8.0))
    launcher = eng.scheduler.launcher
    while launcher.pending():
        launcher.step()
    _crash(eng)
    # replay attack: append a full copy of the journal to itself
    jpath = tmp_path / "s" / f"{JOURNAL_STREAM}.jsonl"
    jpath.write_text(jpath.read_text() + jpath.read_text())

    eng2 = _engine(tmp_path / "s")
    assert eng2.recovery.terminal == 2
    assert eng2.recovery.requeued == 0
    for h in (h1, h2):
        job = eng2.registry.get(h.job_id)
        assert job.state is JobState.FINISHED
        assert job.epoch == 0               # no spurious re-queue
    assert eng2.scheduler.stats["completed"] == 0   # nothing re-ran
    assert _underflow(eng2) == 0


def test_stale_epoch_terminal_event_dropped_after_recovery(tmp_path):
    """A zombie of the crashed incarnation publishing its terminal after
    recovery must not settle the new incarnation (satellite: terminal-
    event idempotency under replay for the runner/scheduler pair)."""
    eng = _engine(tmp_path / "s")
    h = eng.submit(_spec("a", duration=100.0))
    assert eng.registry.get(h.job_id).state is JobState.RUNNING
    _crash(eng)

    eng2 = _engine(tmp_path / "s")
    job = eng2.registry.get(h.job_id)
    assert job.epoch == 1 and job.state is JobState.RUNNING
    completed_before = eng2.scheduler.stats["completed"]
    # the zombie: epoch-0 terminal event lands on the live bus
    eng2.bus.publish(TOPIC_CONTAINER_STATUS,
                     {"job_id": h.job_id, "epoch": 0,
                      "status": "FINISHED"})
    job = eng2.registry.get(h.job_id)
    assert job.state is JobState.RUNNING    # not terminal-ized
    assert eng2.scheduler.stats["completed"] == completed_before
    launcher = eng2.scheduler.launcher
    while launcher.pending():
        launcher.step()
    assert eng2.registry.get(h.job_id).state is JobState.FINISHED
    assert eng2.scheduler.stats["completed"] == completed_before + 1
    assert _underflow(eng2) == 0


def test_unknown_job_terminal_event_ignored(tmp_path):
    """Cross-process event sources can name jobs this engine never saw;
    the scheduler must ignore them instead of raising."""
    eng = _engine(tmp_path / "s")
    eng.bus.publish(TOPIC_CONTAINER_STATUS,
                    {"job_id": "job-999", "status": "FINISHED"})
    assert eng.scheduler.stats["completed"] == 0


def test_threadpool_terminal_idempotent_across_recovery(tmp_path):
    """ThreadPoolRunner jobs journaled to completion adopt as terminal on
    recovery — no re-run, and replaying their terminal events through
    the recovered engine's bus is a no-op (exactly-once settle)."""
    eng = AcaiEngine(runner="thread", durable=tmp_path / "s",
                     workroot=str(tmp_path / "w"), cluster_nodes=1,
                     quota_k=100)
    handles = [eng.submit(JobSpec(name=f"t{i}", project="p", user="u",
                                  fn=echo_job, args={"msg": str(i)},
                                  resources={"vcpu": 1.0,
                                             "mem_mb": 512.0}))
               for i in range(4)]
    for h in handles:
        assert h.wait(timeout=30.0) is JobState.FINISHED
    eng.launcher.shutdown()
    _crash(eng)

    eng2 = AcaiEngine(runner="thread", durable=tmp_path / "s",
                      workroot=str(tmp_path / "w"), cluster_nodes=1,
                      quota_k=100)
    assert eng2.recovery.terminal == 4
    assert eng2.recovery.requeued == 0
    for h in handles:
        job = eng2.registry.get(h.job_id)
        assert job.state is JobState.FINISHED
        assert job.outputs.get("echo") is not None
        # replay the terminal event: settled-job duplicate must drop
        eng2.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": h.job_id, "epoch": job.epoch,
                          "status": "FINISHED"})
    assert eng2.scheduler.stats["completed"] == 0
    assert _underflow(eng2) == 0
    eng2.launcher.shutdown()


# -- cross-process terminal resolution (monitor/handle fallback) ----------
def test_wait_resolves_from_persisted_state(tmp_path):
    """A handle attached after the terminal event was published (fresh
    process over recovered state) resolves immediately instead of
    hanging: monitor falls back to the registry's persisted state."""
    eng = _engine(tmp_path / "s")
    h = eng.submit(_spec("a", duration=5.0))
    eng.scheduler.launcher.step()
    _crash(eng)

    eng2 = _engine(tmp_path / "s")
    # no terminal event ever crossed eng2's bus for this job
    assert eng2.monitor.status.get(h.job_id) in (None, "FINISHED")
    assert eng2.monitor.wait_terminal(h.job_id, timeout=1.0)
    assert eng2.monitor.is_terminal(h.job_id)
    h2 = JobHandle(eng2.registry.get(h.job_id), eng2)
    assert h2.wait(timeout=1.0) is JobState.FINISHED
    assert not eng2.monitor.wait_terminal("job-404", timeout=0.05)


def test_elastic_resize_survives_restart(tmp_path):
    eng = _engine(tmp_path / "s")
    pool = next(iter(eng.scheduler.pools))
    eng.scheduler.resize_pool(pool, {"vcpu": 5.0})
    _crash(eng)
    eng2 = _engine(tmp_path / "s")
    assert eng2.scheduler.pools[pool].capacity["vcpu"] == 5.0


# -- the exit criterion: SIGKILL mid-fleet, restart, golden completes -----
def test_sigkill_recovery_matches_golden(tmp_path):
    """Kill -9 a real engine process mid-fleet (mixed states in flight),
    restart over its state dir, and the golden trace completes: no lost
    jobs, no duplicated terminal events, bit-identical final states."""
    n = 150
    golden = drill.run_fresh(tmp_path / "golden", n_jobs=n, seed=7)
    assert set(golden) == {f"job-{i}" for i in range(1, n + 1)}

    d = tmp_path / "crash"
    d.mkdir()
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.core.engine.durable.drill",
         "--dir", str(d), "--n-jobs", str(n), "--seed", "7"], env=env)
    heartbeat = d / "progress"
    deadline = time.monotonic() + 60.0
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise AssertionError("drill finished before we could kill it "
                                 "— raise n or lower the kill threshold")
        try:
            if int(heartbeat.read_text() or 0) >= 40:
                break
        except (FileNotFoundError, ValueError):
            pass
        time.sleep(0.01)
    else:
        raise AssertionError("drill never reached the kill threshold")
    os.kill(proc.pid, signal.SIGKILL)
    proc.wait(timeout=10)

    out = drill.resume(d, n, seed=7)
    assert out["report"] is not None
    assert out["report"]["jobs_total"] == n           # no lost jobs
    assert out["final"] == golden                     # bit-identical
    assert out["duplicate_terminals"] == {}           # exactly-once
    assert out["release_underflow"] == 0
    # the crashed run really was mid-flight: some jobs were already
    # terminal (adopted), the rest re-queued
    assert out["report"]["terminal"] >= 40
    assert out["report"]["requeued"] > 0


def test_durability_off_has_no_journal():
    """With durability disabled nothing changes: no journal attached
    anywhere, so existing decision traces replay bit-identically."""
    eng = AcaiEngine(virtual=True, cluster_nodes=1)
    assert eng.journal is None and eng.store is None
    assert eng.registry.journal is None
    assert eng.scheduler.journal is None
    assert eng.launcher.journal is None
    assert eng.recovery is None
