"""GPipe pipeline-parallel schedule vs the sequential oracle (4 pipeline
stages on 4 host devices, own subprocess for the device count)."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.train.pipeline import pipeline_apply, sequential_apply

mesh = jax.make_mesh((4,), ("stage",))
S, D, B, M = 4, 16, 8, 4
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (S, D, D)) * 0.3
b = jax.random.normal(jax.random.PRNGKey(1), (S, D)) * 0.1
params = {"w": w, "b": b}
x = jax.random.normal(jax.random.PRNGKey(2), (B, D))

def stage_fn(p, a):
    return jnp.tanh(a @ p["w"] + p["b"])

want = sequential_apply(stage_fn, params, x)
got = jax.jit(lambda p, xx: pipeline_apply(
    stage_fn, p, xx, mesh=mesh, n_microbatches=M))(params, x)
err = float(jnp.abs(got - want).max())

# gradient flows through the pipeline too
def loss_pipe(p):
    return jnp.sum(pipeline_apply(stage_fn, p, x, mesh=mesh,
                                  n_microbatches=M) ** 2)
def loss_seq(p):
    return jnp.sum(sequential_apply(stage_fn, p, x) ** 2)
g1 = jax.grad(loss_pipe)(params)
g2 = jax.grad(loss_seq)(params)
gerr = max(float(jnp.abs(a - b).max())
           for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
print("RESULT::" + json.dumps({"err": err, "gerr": gerr}))
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=560,
                          env={**__import__("os").environ,
                               "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT::")][0]
    out = json.loads(line[len("RESULT::"):])
    assert out["err"] < 1e-5, out
    assert out["gerr"] < 1e-4, out
