"""Fixture: a justified suppression silences the violation
(never imported)."""


class Runner:
    def finish(self, registry, job_id):
        # acailint: disable=ACAI201 -- fixture: single-incarnation runner, no epoch ever bumps
        registry.set_state(job_id, JobState.FINISHED)
