"""Fixture: every lock-discipline violation class (never imported)."""
import threading


class Registry:
    def __init__(self):
        self.jobs = {}  # guarded-by: _lock
        self._lock = threading.RLock()  # acailint: lock(forbid: publish, metadata)
        self.bus = None
        self.metadata = None

    def get(self, job_id):
        return self.jobs[job_id]                        # ACAI101

    def put(self, job_id, job):
        with self._lock:
            self.jobs[job_id] = job
            self.bus.publish("container_status",        # ACAI102
                             {"job_id": job_id})
            self.metadata.register(job_id)              # ACAI102


class Bus:
    def __init__(self):
        self._subs = []  # guarded-by: _lock
        self._lock = threading.RLock()  # acailint: lock(forbid: bare-calls)

    def publish(self, msg):
        with self._lock:
            for fn in list(self._subs):
                fn(msg)                                 # ACAI102 (bare call)
