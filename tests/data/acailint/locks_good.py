"""Fixture: the same shapes as locks_bad, done right (never imported)."""
import threading


class Registry:
    def __init__(self):
        self.jobs = {}  # guarded-by: _lock
        self._lock = threading.RLock()  # acailint: lock(forbid: publish, metadata)
        self.bus = None
        self.metadata = None

    def get(self, job_id):
        with self._lock:
            return self.jobs[job_id]

    def put(self, job_id, job):
        with self._lock:
            self.jobs[job_id] = job
        # side effects happen after the lock is released
        self.bus.publish("container_status", {"job_id": job_id})
        self.metadata.register(job_id)


class Bus:
    def __init__(self):
        self._subs = []  # guarded-by: _lock
        self._lock = threading.RLock()  # acailint: lock(forbid: bare-calls)

    def publish(self, msg):
        with self._lock:
            subs = list(self._subs)
        for fn in subs:         # handlers run outside the bus lock
            fn(msg)
