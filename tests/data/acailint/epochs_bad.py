"""Fixture: unguarded terminal transitions and unstamped terminal
events (never imported)."""
TOPIC_CONTAINER_STATUS = "container_status"


class Runner:
    def finish(self, registry, bus, job_id):
        registry.set_state(job_id, JobState.FINISHED)           # ACAI201
        bus.publish(TOPIC_CONTAINER_STATUS,
                    {"job_id": job_id, "status": "FINISHED"})   # ACAI202

    def kill_via_local_dict(self, bus, job_id):
        msg = {"job_id": job_id, "status": "KILLED"}
        bus.publish(TOPIC_CONTAINER_STATUS, msg)                # ACAI202

    def kill_via_member(self, bus, job_id):
        bus.publish("container_status",
                    {"job_id": job_id,
                     "status": JobState.KILLED.value})          # ACAI202
