"""Fixture: reservations whose exception paths leak (never imported)."""


class Scheduler:
    def launch(self, cl, job):
        cl.reserve(job.job_id, job.resources)           # ACAI401
        self.launcher.launch(job)       # raising here leaks the hold

    def launch_gang(self, cl, job, pods):
        cl.reserve_gang(job.job_id, job.resources, pods)  # ACAI401
        if not job.ready:
            raise RuntimeError("not ready")
