"""Fixture: an unjustified suppression is itself an error and does not
silence anything (never imported)."""


class Runner:
    def finish(self, registry, job_id):
        # acailint: disable=ACAI201
        registry.set_state(job_id, JobState.FINISHED)
