"""Fixture: epoch-guarded terminal transitions and stamped terminal
events (never imported)."""
TOPIC_CONTAINER_STATUS = "container_status"


class Runner:
    def finish(self, registry, bus, job, job_id):
        registry.set_state(job_id, JobState.FINISHED,
                           expect_epoch=job.epoch)
        bus.publish(TOPIC_CONTAINER_STATUS,
                    {"job_id": job_id, "status": "FINISHED",
                     "epoch": job.epoch})

    def kill_via_local_dict(self, bus, job, job_id):
        msg = {"job_id": job_id, "status": "KILLED"}
        msg["epoch"] = job.epoch
        bus.publish(TOPIC_CONTAINER_STATUS, msg)

    def progress_is_not_terminal(self, registry, bus, job_id):
        registry.set_state(job_id, JobState.RUNNING)    # non-terminal: fine
        bus.publish(TOPIC_CONTAINER_STATUS,
                    {"job_id": job_id, "status": "RUNNING"})
