"""Fixture: release-protected (or raise-free) reservations
(never imported)."""


class Scheduler:
    def launch(self, cl, job):
        try:
            cl.reserve(job.job_id, job.resources)
            self.launcher.launch(job)
        except Exception:
            cl.release(job.job_id)      # exception path hands it back
            raise

    def launch_with_finally(self, cl, job):
        ok = False
        try:
            cl.reserve(job.job_id, job.resources)
            self.launcher.launch(job)
            ok = True
        finally:
            if not ok:
                cl.release(job.job_id)

    def launch_via_unwind_helper(self, cl, job):
        try:
            cl.reserve_gang(job.job_id, job.resources, 4)
            self.launcher.launch(job)
        except Exception:
            self._abort(cl, job)        # helper releases transitively
            raise

    def _abort(self, cl, job):
        cl.release(job.job_id)
        job.pool = None

    def reserve_last(self, cl, job):
        # nothing after the reserve can raise: no leak path to protect
        cl.reserve(job.job_id, job.resources)
