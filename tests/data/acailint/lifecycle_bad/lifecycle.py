"""Fixture: a lifecycle table failing every closure property
(never imported)."""
import enum


class JobState(str, enum.Enum):
    SUBMITTED = "SUBMITTED"
    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    FINISHED = "FINISHED"
    FAILED = "FAILED"


_TRANSITIONS = {
    JobState.SUBMITTED: {JobState.QUEUED},
    JobState.QUEUED: set(),                             # non-terminal dead end
    JobState.RUNNING: {JobState.FINISHED, JobState.KILLED},  # undeclared target
    JobState.FINISHED: {JobState.QUEUED},               # terminal escape
    # FAILED: missing row
}

TERMINAL_STATES = frozenset({JobState.FINISHED, JobState.FAILED})
