"""Fixture: edges the declared table does not grant (never imported)."""


class Engine:
    def finish(self, job):
        job.state = JobState.FINISHED                   # ACAI501 (direct)

    def resubmit(self, registry, job_id):
        registry.set_state(job_id, JobState.SUBMITTED)  # ACAI501 (no edge)
