"""Fixture: a closed lifecycle table (never imported)."""
import enum


class JobState(str, enum.Enum):
    SUBMITTED = "SUBMITTED"
    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    FINISHED = "FINISHED"
    FAILED = "FAILED"


_TRANSITIONS = {
    JobState.SUBMITTED: {JobState.QUEUED, JobState.FAILED},
    JobState.QUEUED: {JobState.RUNNING, JobState.FAILED},
    JobState.RUNNING: {JobState.FINISHED, JobState.FAILED},
    JobState.FINISHED: set(),
    JobState.FAILED: set(),
}

TERMINAL_STATES = frozenset({JobState.FINISHED, JobState.FAILED})
