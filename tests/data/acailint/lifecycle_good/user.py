"""Fixture: only table-granted edges (never imported)."""


class Engine:
    def finish(self, registry, job, job_id):
        registry.set_state(job_id, JobState.FINISHED,
                           expect_epoch=job.epoch)

    def enqueue(self, registry, job_id):
        registry.set_state(job_id, JobState.QUEUED)
