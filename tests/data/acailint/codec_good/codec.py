"""Fixture: codec covering every non-runtime-only field
(never imported)."""


def encode_job(job):
    return {"job_id": job.job_id,
            "state": job.state,
            "epoch": job.epoch}


def decode_job(doc):
    return Job(job_id=doc["job_id"],
               state=doc.get("state", "SUBMITTED"),
               epoch=int(doc.get("epoch", 0)))
