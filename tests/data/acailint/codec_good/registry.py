"""Fixture: fully-covered dataclass + journaled registry
(never imported)."""
import dataclasses


@dataclasses.dataclass
class Job:
    job_id: str
    state: str = "SUBMITTED"
    epoch: int = 0
    cursor: int = 0  # acailint: runtime-only


class JobRegistry:
    def __init__(self, journal=None):
        self.journal = journal
        self._jobs = {}

    def kill(self, job_id):
        job = self._jobs[job_id]
        job.state = "KILLED"
        if self.journal is not None:
            self.journal.job_state(job)
        return job
