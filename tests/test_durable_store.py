"""Durable control plane, layer 1: StateStore transports, the
write-ahead journal's sequencing/compaction/torn-write semantics, and
round-trip serialization for every spec/event shape the journal persists
(JobSpec incl. GangSpec, Job records, transfer-cost configs) —
property-style over randomized shapes, identical on both backends."""
import dataclasses
import json
import random

import pytest

from repro.core.engine.durable.codec import (decode_fn, decode_job,
                                             decode_spec,
                                             decode_transfer_costs,
                                             encode_fn, encode_job,
                                             encode_spec,
                                             encode_transfer_costs,
                                             json_safe)
from repro.core.engine.durable.journal import (JOURNAL_STREAM, SNAPSHOT_KEY,
                                               Journal)
from repro.core.engine.durable.store import FileStore, MemoryStore
from repro.core.engine.lifecycle import JobState
from repro.core.engine.placement import TransferCostModel
from repro.core.engine.registry import GangSpec, Job, JobSpec
from repro.core.engine.durable.jobs import echo_job


def _stores(tmp_path):
    return [MemoryStore(), FileStore(tmp_path / "fs")]


# -- StateStore transports ------------------------------------------------
def test_store_stream_append_read_truncate(tmp_path):
    for store in _stores(tmp_path):
        assert store.read("s") == []
        store.append("s", {"a": 1})
        store.append("s", {"b": [1, 2]})
        assert store.read("s") == [{"a": 1}, {"b": [1, 2]}]
        store.truncate("s")
        assert store.read("s") == []
        store.append("s", {"c": 3})     # append after truncate works
        assert store.read("s") == [{"c": 3}]


def test_store_keys_put_get_delete(tmp_path):
    for store in _stores(tmp_path):
        assert store.get("k") is None
        store.put("k", {"x": {"y": 2.5}})
        assert store.get("k") == {"x": {"y": 2.5}}
        store.put("k", {"z": None})     # overwrite
        assert store.get("k") == {"z": None}
        store.delete("k")
        assert store.get("k") is None
        store.delete("k")               # idempotent


def test_filestore_skips_torn_trailing_line(tmp_path):
    store = FileStore(tmp_path)
    store.append("j", {"n": 1})
    store.append("j", {"n": 2})
    store.close()
    # simulate kill -9 mid-append: a partial record at the tail
    with (tmp_path / "j.jsonl").open("a") as fh:
        fh.write('{"n": 3, "truncat')
    assert FileStore(tmp_path).read("j") == [{"n": 1}, {"n": 2}]


def test_filestore_rejects_mid_stream_corruption(tmp_path):
    (tmp_path / "j.jsonl").write_text('{"n": 1}\ngarbage\n{"n": 3}\n')
    with pytest.raises(json.JSONDecodeError):
        FileStore(tmp_path).read("j")


def test_filestore_survives_reopen(tmp_path):
    store = FileStore(tmp_path)
    store.append("j", {"n": 1})
    store.put("snap", {"seq": 1})
    store.close()
    reopened = FileStore(tmp_path)
    assert reopened.read("j") == [{"n": 1}]
    assert reopened.get("snap") == {"seq": 1}


# -- journal sequencing / compaction --------------------------------------
def test_journal_assigns_monotone_seq_and_loads(tmp_path):
    for store in _stores(tmp_path):
        j = Journal(store)
        for i in range(5):
            j.record({"t": "x", "i": i})
        snap, events = Journal(store).load()
        assert snap is None
        assert [e["n"] for e in events] == [1, 2, 3, 4, 5]


def test_journal_seq_survives_compaction(tmp_path):
    """Sequence numbers never reset: records appended after a snapshot
    continue past the watermark, so the watermark filter is correct."""
    store = MemoryStore()
    j = Journal(store, snapshot_every=0)    # manual snapshots only
    j.snapshot_source = lambda: {"v": 1, "jobs": []}
    for i in range(3):
        j.record({"t": "x", "i": i})
    j.snapshot()
    assert store.read(JOURNAL_STREAM) == []     # compacted
    assert store.get(SNAPSHOT_KEY)["seq"] == 3
    j.record({"t": "x", "i": 99})
    snap, events = Journal(store).load()
    assert snap["seq"] == 3
    assert [e["n"] for e in events] == [4]


def test_journal_replay_skips_snapshotted_prefix(tmp_path):
    """Crash between snapshot-write and truncate: the journal still holds
    already-snapshotted records, and load() must skip them."""
    store = MemoryStore()
    j = Journal(store, snapshot_every=0)
    j.snapshot_source = lambda: {"v": 1}
    for i in range(4):
        j.record({"t": "x", "i": i})
    # snapshot WITHOUT truncation = the crash window
    doc = j.snapshot_source()
    doc["seq"] = 2
    store.put(SNAPSHOT_KEY, doc)
    snap, events = Journal(store).load()
    assert [e["i"] for e in events] == [2, 3]   # n=1,2 skipped


def test_journal_auto_snapshot_threshold():
    store = MemoryStore()
    j = Journal(store, snapshot_every=10)
    j.snapshot_source = lambda: {"v": 1}
    for i in range(25):
        j.record({"t": "x", "i": i})
    # two compactions happened; at most snapshot_every records remain
    assert len(store.read(JOURNAL_STREAM)) <= 10
    assert store.get(SNAPSHOT_KEY) is not None
    # nothing was lost: watermark + remaining journal cover all 25 records
    snap, events = Journal(store).load()
    assert snap["seq"] + len(events) == 25


def test_journal_paused_suppresses_recording():
    store = MemoryStore()
    j = Journal(store)
    with j.paused():
        j.record({"t": "x"})
        j.job_progress("job-1", 0.5)
    assert store.read(JOURNAL_STREAM) == []
    j.record({"t": "y"})
    assert [e["t"] for e in store.read(JOURNAL_STREAM)] == ["y"]


def test_journal_has_state(tmp_path):
    store = FileStore(tmp_path)
    j = Journal(store)
    assert not j.has_state()
    j.record({"t": "x"})
    assert j.has_state()


# -- codec round-trips: property-style over randomized shapes -------------
def _random_spec(rng: random.Random) -> JobSpec:
    gang = None
    if rng.random() < 0.4:
        n = rng.randint(2, 8)
        gang = GangSpec(
            n_pods=n,
            per_pod_resources={"vcpu": rng.choice([1.0, 2.0])}
            if rng.random() < 0.5 else None,
            topology=rng.choice(["any", "close"]),
            min_pods=rng.randint(0, n))
    return JobSpec(
        name=f"j{rng.randint(0, 999)}",
        project=rng.choice(["p1", "p2"]),
        user=rng.choice(["alice", "bob"]),
        fn=echo_job if rng.random() < 0.3 else None,
        argv=["run.py", "--x"] if rng.random() < 0.3 else None,
        input_fileset=rng.choice([None, "train@1"]),
        output_fileset=rng.choice([None, "out"]),
        resources={"vcpu": float(rng.randint(1, 8)),
                   "mem_mb": float(rng.choice([512, 2048]))},
        args={"lr": rng.random(), "tags": ["a", "b"],
              "nested": {"k": rng.randint(0, 5)}},
        duration=rng.choice([None, round(rng.uniform(1, 100), 3)]),
        priority=rng.randint(-2, 5),
        depends_on=[f"job-{rng.randint(1, 9)}"]
        if rng.random() < 0.3 else [],
        pool=rng.choice([None, "cpu", "tpu"]),
        pool_resources={"tpu": {"chips": 4.0}}
        if rng.random() < 0.3 else {},
        template=rng.choice([None, "resnet"]),
        gang=gang,
        input_bytes=rng.choice([0.0, 2.5e9]))


def test_spec_roundtrip_property():
    rng = random.Random(11)
    for _ in range(60):
        spec = _random_spec(rng)
        # the store boundary is real JSON text, not dict identity
        doc = json.loads(json.dumps(encode_spec(spec)))
        back = decode_spec(doc)
        for f in dataclasses.fields(JobSpec):
            if f.name == "fn":
                continue    # fn crosses as a ref, checked below
            assert getattr(back, f.name) == getattr(spec, f.name), f.name
        if spec.fn is not None:
            assert back.fn is spec.fn   # importable fn resolves itself


def test_job_roundtrip_property():
    rng = random.Random(23)
    for _ in range(60):
        job = Job(job_id=f"job-{rng.randint(1, 500)}",
                  spec=_random_spec(rng),
                  state=rng.choice(list(JobState)))
        job.started_at = rng.choice([None, 100.5])
        job.finished_at = rng.choice([None, 222.25])
        job.runtime = rng.choice([None, 12.125])
        job.cost = rng.choice([None, 0.75])
        job.pool = rng.choice([None, "cpu"])
        job.error = rng.choice([None, "boom"])
        job.outputs = {"log": "x" * rng.randint(0, 5),
                       "metrics": {"acc": 0.9}}
        job.epoch = rng.randint(0, 6)
        job.preemptions = rng.randint(0, 6)
        job.gang_pods = rng.choice([None, 4])
        doc = json.loads(json.dumps(encode_job(job)))
        back = decode_job(doc)
        for f in ("job_id", "state", "submitted_at", "started_at",
                  "finished_at", "runtime", "cost", "pool", "error",
                  "outputs", "epoch", "preemptions", "gang_pods"):
            assert getattr(back, f) == getattr(job, f), f


def test_transfer_costs_roundtrip_property():
    rng = random.Random(37)
    pools = ["cpu", "tpu", "gpu"]
    for _ in range(30):
        model = TransferCostModel(
            cost_per_gb=round(rng.uniform(0, 0.2), 6),
            pair_cost_per_gb={(s, d): round(rng.uniform(0, 0.5), 6)
                              for s in pools for d in pools
                              if s != d and rng.random() < 0.5},
            interconnect_weight=round(rng.uniform(0.1, 3.0), 6))
        doc = json.loads(json.dumps(encode_transfer_costs(model)))
        back = decode_transfer_costs(doc)
        assert back.cost_per_gb == model.cost_per_gb
        assert back.pair_cost_per_gb == model.pair_cost_per_gb
        assert back.interconnect_weight == model.interconnect_weight


def test_fn_codec_lambda_refuses_and_stub_fails_loudly(tmp_path):
    assert encode_fn(lambda w, j: {}) is None

    def local_fn(w, j):
        return {}
    assert encode_fn(local_fn) is None          # <locals> in qualname
    assert encode_fn(echo_job) == \
        "repro.core.engine.durable.jobs:echo_job"
    assert decode_fn(None) is None
    stub = decode_fn("no.such.module:missing")
    with pytest.raises(RuntimeError, match="not importable"):
        stub(tmp_path, None)


def test_json_safe_handles_nonfinite_and_objects():
    out = json_safe({"inf": float("inf"), "nan": float("nan"),
                     1: {"set": {1, 2}}, "obj": object()})
    json.dumps(out)     # must be representable
    assert out["inf"] == "inf"
    assert out["1"]["set"] == [1, 2] or sorted(out["1"]["set"]) == [1, 2]


def test_journal_event_shapes_roundtrip_through_filestore(tmp_path):
    """Every typed hook's record survives the real file boundary."""
    store = FileStore(tmp_path)
    j = Journal(store)
    job = Job(job_id="job-1", spec=_random_spec(random.Random(5)))
    j.job_submitted(job)
    job.state = JobState.QUEUED
    j.job_state(job)
    job.state = JobState.FAILED
    job.error = "boom"
    job.finished_at, job.runtime, job.cost = 9.0, 4.5, 0.01
    j.job_state(job)
    j.job_preempted(job)
    j.job_progress("job-1", 0.625)
    j.pool_resized("cpu", {"vcpu": 32.0})
    j.job_final(job)
    store.close()
    events = FileStore(tmp_path).read(JOURNAL_STREAM)
    assert [e["t"] for e in events] == \
        ["submit", "state", "state", "preempt", "progress", "resize",
         "final"]
    assert events[2]["error"] == "boom"
    assert events[2]["runtime"] == 4.5
    assert events[4]["done_frac"] == 0.625
    assert events[5]["capacity"] == {"vcpu": 32.0}
    assert events[6]["state"] == "FAILED"
    # the submitted spec decodes back into an equivalent JobSpec
    decode_spec(events[0]["spec"])
