"""Fault-tolerance layer: retry budgets with exponential backoff,
crash-loop quarantine, per-incarnation timeouts vs absolute deadlines,
node failure (gang-atomic), deterministic chaos injection, and the
durability of retry state across a crash-recovery restart."""
import pytest

from repro.core.acai import AcaiEngine
from repro.core.engine.cluster import Cluster
from repro.core.engine.events import EventBus
from repro.core.engine.faults import FaultInjector, FaultPlan
from repro.core.engine.lifecycle import (_TRANSITIONS, TERMINAL_STATES,
                                         IllegalTransition, JobState,
                                         TransientJobError,
                                         check_transition)
from repro.core.engine.launcher import VirtualRunner
from repro.core.engine.monitor import JobMonitor
from repro.core.engine.registry import (GangSpec, JobRegistry, JobSpec,
                                        RetryPolicy)
from repro.core.engine.scheduler import Scheduler, validate_spec
from repro.core.provision.pricing import CPU_PRICING


def _spec(name="j", duration=10.0, resources=None, user="u", **kw):
    return JobSpec(name=name, project="p", user=user, duration=duration,
                   resources=resources or {"vcpu": 4.0}, **kw)


def _engine(capacity=None, *, node_shape=None, **kw):
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus, pricing=CPU_PRICING)
    cl = Cluster(capacity or {"vcpu": 8.0}, {"vcpu": 0.0},
                 node_shape=node_shape)
    sched = Scheduler(registry, runner, bus, quota_k=100, cluster=cl,
                      **kw)
    monitor = JobMonitor(bus, registry=registry)  # after the scheduler
    return registry, bus, runner, sched, monitor


def _submit(registry, sched, spec):
    job = registry.submit(spec)
    sched.submit(job)
    return job


def _drain(runner, sched, until=None):
    """Drive completions + fault-tolerance timers on the virtual clock."""
    while True:
        cands = [t for t in (runner.next_completion(), sched.next_timer())
                 if t is not None]
        if not cands:
            return
        t = min(cands)
        if until is not None and t > until:
            return
        if runner.next_completion() == t:
            runner.step()
        else:
            runner.advance_to(t)
        sched.tick()


# -- property: the transition table is closed under retry/quarantine ----
def test_transition_table_closed():
    """Every state has a row; terminals have no exits except the one
    FAILED -> QUARANTINED refinement; QUARANTINED is a dead end."""
    assert set(_TRANSITIONS) == set(JobState)
    for s in TERMINAL_STATES:
        allowed = _TRANSITIONS[s]
        if s is JobState.FAILED:
            assert allowed == {JobState.QUARANTINED}
        else:
            assert allowed == set()
    # every declared target is a real state (no dangling edges)
    for targets in _TRANSITIONS.values():
        assert targets <= set(JobState)
    check_transition(JobState.FAILED, JobState.QUARANTINED)
    for target in JobState:
        with pytest.raises(IllegalTransition):
            check_transition(JobState.QUARANTINED, target)


def test_terminal_stays_terminal_across_epochs():
    """Epoch rebirth (mark_retrying) is privileged: only FAILED may be
    resurrected, and a stale incarnation's write can never resurrect a
    settled job."""
    registry = JobRegistry()
    job = registry.submit(_spec())
    registry.set_state(job.job_id, JobState.QUEUED)
    for bad in (JobState.QUEUED, JobState.RUNNING):
        job.state = bad
        with pytest.raises(IllegalTransition):
            registry.mark_retrying(job.job_id)
    job.state = JobState.FAILED
    reborn = registry.mark_retrying(job.job_id)
    assert reborn.state is JobState.QUEUED
    assert reborn.epoch == 1 and reborn.retries == 1
    # the dead incarnation's late terminal event is recognizably stale
    assert registry.set_state(job.job_id, JobState.FAILED,
                              expect_epoch=0) is None
    assert registry.get(job.job_id).state is JobState.QUEUED


def test_note_failure_streak_resets_on_transient():
    registry = JobRegistry()
    job = registry.submit(_spec())
    assert registry.note_failure(job.job_id, transient=False) == 1
    assert registry.note_failure(job.job_id, transient=False) == 2
    assert registry.note_failure(job.job_id, transient=True) == 0
    assert registry.note_failure(job.job_id, transient=False) == 1


def test_validate_spec_rejects_bad_fault_knobs():
    for bad in (dict(retry=RetryPolicy(max_retries=-1)),
                dict(retry=RetryPolicy(backoff_base=-1.0)),
                dict(retry=RetryPolicy(retry_on="sometimes")),
                dict(timeout_s=0.0), dict(deadline=-5.0)):
        with pytest.raises(ValueError):
            validate_spec(_spec(**bad))


# -- retry with backoff --------------------------------------------------
def test_transient_failure_retries_after_backoff():
    registry, bus, runner, sched, _ = _engine()
    job = _submit(registry, sched, _spec(
        duration=10.0, retry=RetryPolicy(max_retries=2, backoff_base=5.0)))
    assert job.state is JobState.RUNNING
    runner.advance_to(3.0)
    assert runner.fail_running(job, "nic reset", transient=True)
    # reborn QUEUED under a backoff hold: not dispatched yet
    assert job.state is JobState.QUEUED
    assert job.epoch == 1 and job.retries == 1
    assert sched.stats["retried"] == 1
    assert sched.stats["retry_wasted_s"] == pytest.approx(3.0)
    assert sched.next_timer() == pytest.approx(8.0)     # 3 + base*2^0
    runner.advance_to(7.0)
    sched.tick()
    assert job.state is JobState.QUEUED     # hold not due yet
    runner.advance_to(8.0)
    sched.tick()
    assert job.state is JobState.RUNNING    # released + dispatched
    _drain(runner, sched)
    assert job.state is JobState.FINISHED
    assert sched.next_timer() is None


def test_retry_budget_exhausts_to_failed():
    registry, bus, runner, sched, _ = _engine()
    job = _submit(registry, sched, _spec(
        retry=RetryPolicy(max_retries=1, backoff_base=0.0)))
    assert runner.fail_running(job, "flake", transient=True)
    assert job.state is JobState.RUNNING        # zero backoff: relaunched
    assert job.retries == 1
    assert runner.fail_running(job, "flake again", transient=True)
    assert job.state is JobState.FAILED         # budget spent: terminal
    assert sched.stats["retried"] == 1
    # transient failures never quarantine
    assert sched.stats["quarantined"] == 0


def test_fatal_failure_not_retried_under_transient_policy():
    registry, bus, runner, sched, _ = _engine()
    job = _submit(registry, sched, _spec(
        retry=RetryPolicy(max_retries=3, backoff_base=0.0)))
    assert runner.fail_running(job, "assertion error", transient=False)
    assert job.state is JobState.FAILED
    assert job.retries == 0 and sched.stats["retried"] == 0


# -- crash-loop quarantine ----------------------------------------------
def test_crash_loop_quarantines():
    registry, bus, runner, sched, monitor = _engine(
        quarantine_threshold=3)
    job = _submit(registry, sched, _spec(retry=RetryPolicy(
        max_retries=10, backoff_base=0.0, retry_on="any")))
    for i in range(2):
        assert runner.fail_running(job, f"segfault {i}", transient=False)
        assert job.state is JobState.RUNNING    # retried: budget remains
    assert runner.fail_running(job, "segfault 2", transient=False)
    assert job.state is JobState.QUARANTINED    # 3rd consecutive fatal
    assert job.retries == 2                     # budget NOT burned dry
    assert sched.stats["quarantined"] == 1
    assert "quarantined after 3 consecutive failures" in job.error
    assert monitor.is_terminal(job.job_id)
    # terminal means terminal: no further resurrection
    with pytest.raises(IllegalTransition):
        registry.mark_retrying(job.job_id)


def test_success_resets_quarantine_streak():
    registry, bus, runner, sched, _ = _engine(quarantine_threshold=2)
    job = _submit(registry, sched, _spec(retry=RetryPolicy(
        max_retries=10, backoff_base=0.0, retry_on="any")))
    assert runner.fail_running(job, "boom", transient=False)
    assert job.state is JobState.RUNNING
    _drain(runner, sched)
    assert job.state is JobState.FINISHED
    assert job.failures == 1        # streak intact until a success...
    # ...but the FINISHED reset the *user's* failure budget
    assert not sched._user_fails


def test_user_failure_budget_denies_retry():
    registry, bus, runner, sched, _ = _engine(
        quarantine_threshold=100, user_failure_budget=1)
    job = _submit(registry, sched, _spec(retry=RetryPolicy(
        max_retries=10, backoff_base=0.0, retry_on="any")))
    assert runner.fail_running(job, "bug", transient=False)
    assert job.state is JobState.RUNNING        # fail #1: within budget
    assert runner.fail_running(job, "bug", transient=False)
    assert job.state is JobState.FAILED         # fail #2 > budget: denied


# -- timeouts vs deadlines ----------------------------------------------
def test_timeout_is_transient_and_retries():
    registry, bus, runner, sched, _ = _engine()
    job = _submit(registry, sched, _spec(
        duration=100.0, timeout_s=10.0,
        retry=RetryPolicy(max_retries=1, backoff_base=0.0)))
    assert sched.next_timer() == pytest.approx(10.0)
    runner.advance_to(10.0)
    sched.tick()
    assert sched.stats["timeouts"] == 1
    assert job.state is JobState.RUNNING        # retried immediately
    assert job.epoch == 1
    runner.advance_to(20.0)                     # second incarnation's
    sched.tick()                                # timer: 10 + 10
    assert sched.stats["timeouts"] == 2
    assert job.state is JobState.FAILED         # budget spent
    assert "timeout" in job.error


def test_timeout_without_retry_kills():
    registry, bus, runner, sched, _ = _engine()
    job = _submit(registry, sched, _spec(duration=100.0, timeout_s=5.0))
    runner.advance_to(5.0)
    sched.tick()
    assert job.state is JobState.FAILED         # fail_running, no policy
    assert sched.stats["timeouts"] == 1


def test_deadline_kills_queued_job():
    registry, bus, runner, sched, _ = _engine()
    hog = _submit(registry, sched, _spec("hog", duration=100.0,
                                         resources={"vcpu": 8.0}))
    late = _submit(registry, sched, _spec(
        "late", duration=10.0, resources={"vcpu": 8.0}, deadline=20.0,
        retry=RetryPolicy(backoff_base=0.0)))
    assert hog.state is JobState.RUNNING
    assert late.state is JobState.QUEUED
    assert sched.next_timer() == pytest.approx(20.0)
    runner.advance_to(25.0)
    sched.tick()
    assert late.state is JobState.KILLED        # hard: no retry
    assert "deadline" in late.error
    assert sched.stats["deadline_kills"] == 1
    assert late.retries == 0


def test_deadline_infeasible_fails_at_admission():
    registry, bus, runner, sched, monitor = _engine()
    job = _submit(registry, sched, _spec(
        duration=100.0, deadline=50.0,
        retry=RetryPolicy(backoff_base=0.0, retry_on="any",
                          max_retries=5)))
    assert job.state is JobState.FAILED
    assert "infeasible" in job.error
    # the reason is readable as the job's log ("acai logs" answers why)
    assert "infeasible" in job.outputs.get("log", "")
    # never launched: retrying cannot change the outcome
    assert job.retries == 0 and sched.stats["retried"] == 0


def test_deadline_met_leaves_no_residue():
    registry, bus, runner, sched, _ = _engine()
    job = _submit(registry, sched, _spec(duration=10.0, deadline=50.0))
    _drain(runner, sched)
    assert job.state is JobState.FINISHED
    runner.advance_to(60.0)
    sched.tick()                                # stale timer pops inert
    assert job.state is JobState.FINISHED
    assert sched.stats["deadline_kills"] == 0


# -- node failure --------------------------------------------------------
def test_node_failure_fails_residents_and_excludes_node():
    registry, bus, runner, sched, _ = _engine(
        {"vcpu": 8.0}, node_shape={"vcpu": 4.0})
    a = _submit(registry, sched, _spec(
        "a", duration=50.0, retry=RetryPolicy(backoff_base=0.0)))
    b = _submit(registry, sched, _spec("b", duration=50.0))
    assert a.state is JobState.RUNNING and b.state is JobState.RUNNING
    cl = sched.pools["default"]
    victims = {jid for jid, holds in cl._node_holds.items()
               if any(n == 0 for n, _ in holds)}
    assert len(victims) == 1
    failed = sched.fail_node("default", 0)
    assert set(failed) == victims
    assert sched.stats["node_failures"] == 1
    assert cl.node_health() == {"nodes": 2, "up": 1, "failed": [0],
                                "drained": []}
    survivor = b if a.job_id in victims else a
    assert survivor.state is JobState.RUNNING   # other node untouched
    victim = a if a.job_id in victims else b
    if victim is a:
        # node loss is transient: the retry policy requeued it, and the
        # dead node is out of capacity so it waits for the survivor
        assert victim.state in (JobState.QUEUED, JobState.RUNNING)
        assert victim.retries == 1
    else:
        assert victim.state is JobState.FAILED  # no policy: terminal
    _drain(runner, sched)
    assert survivor.state is JobState.FINISHED


def test_node_failure_fails_gang_atomically():
    registry, bus, runner, sched, _ = _engine(
        {"vcpu": 8.0}, node_shape={"vcpu": 4.0})
    gang = _submit(registry, sched, _spec(
        "g", duration=50.0, resources={"vcpu": 4.0},
        gang=GangSpec(n_pods=2)))
    assert gang.state is JobState.RUNNING       # one pod per node
    failed = sched.fail_node("default", 0)
    assert failed == [gang.job_id]              # whole gang, one unit
    assert gang.state is JobState.FAILED
    cl = sched.pools["default"]
    assert cl.used["vcpu"] == 0.0               # both pods released
    assert cl.stats["release_underflow"] == 0


def test_drain_node_lets_residents_finish():
    registry, bus, runner, sched, _ = _engine(
        {"vcpu": 8.0}, node_shape={"vcpu": 4.0})
    a = _submit(registry, sched, _spec("a", duration=10.0))
    b = _submit(registry, sched, _spec("b", duration=10.0))
    residents = sched.drain_node("default", 0)
    assert len(residents) == 1
    assert registry.get(residents[0]).state is JobState.RUNNING
    # no new placements land on the cordoned node
    c = _submit(registry, sched, _spec("c", duration=10.0))
    assert c.state is JobState.QUEUED
    _drain(runner, sched)
    for j in (a, b, c):
        assert j.state is JobState.FINISHED


# -- deterministic chaos injection --------------------------------------
def test_fault_injector_is_deterministic():
    def run(seed):
        registry, bus, runner, sched, _ = _engine({"vcpu": 8.0})
        inj = FaultInjector(FaultPlan(seed=seed, transient_mtbf_s=7.0,
                                      straggler_mtbf_s=11.0),
                            sched, runner)
        for i in range(6):
            _submit(registry, sched, _spec(
                f"j{i}", duration=20.0, resources={"vcpu": 4.0},
                retry=RetryPolicy(max_retries=3, backoff_base=1.0)))
        for _ in range(400):
            cands = [t for t in (runner.next_completion(),
                                 sched.next_timer(), inj.next_event())
                     if t is not None]
            if not cands or runner.now > 500.0:
                break
            t = min(cands)
            if runner.next_completion() == t:
                runner.step()
            else:
                runner.advance_to(t)
            inj.advance_to(runner.now)
            sched.tick()
        return [(e["t"], e["kind"], e.get("job"), e.get("skipped"))
                for e in inj.events]
    a, b = run(42), run(42)
    assert a == b and len(a) > 0            # same seed: same schedule
    assert run(7) != a                      # different seed: different


def test_fault_injector_node_kill_cap():
    registry, bus, runner, sched, _ = _engine(
        {"vcpu": 8.0}, node_shape={"vcpu": 4.0})
    inj = FaultInjector(FaultPlan(seed=1, node_mtbf_s=5.0,
                                  max_node_failures=1), sched, runner)
    _submit(registry, sched, _spec(duration=500.0))
    for _ in range(50):
        t = inj.next_event()
        if t is None or runner.now > 200.0:
            break
        runner.advance_to(t)
        inj.advance_to(runner.now)
        sched.tick()
    assert inj.node_failures == 1           # cap held
    assert sched.pools["default"].node_health()["up"] == 1


# -- feature-off safety --------------------------------------------------
def test_no_policy_fleet_leaves_fault_state_untouched():
    """A fleet with no retry/timeout/deadline specs must not create any
    fault-tolerance state — the golden decision traces depend on it."""
    registry, bus, runner, sched, _ = _engine()
    jobs = [_submit(registry, sched, _spec(f"j{i}", duration=5.0 + i,
                                           resources={"vcpu": 4.0}))
            for i in range(4)]
    while runner.next_completion() is not None:
        runner.step()
    assert all(j.state is JobState.FINISHED for j in jobs)
    assert sched.next_timer() is None
    assert not sched._timers and not sched._backoff
    for k in ("retried", "quarantined", "timeouts", "deadline_kills",
              "node_failures"):
        assert sched.stats[k] == 0
    assert sched.stats["retry_wasted_s"] == 0.0


# -- monitor staleness ---------------------------------------------------
def test_monitor_drops_stale_terminal_of_retried_job():
    registry, bus, runner, sched, monitor = _engine()
    job = _submit(registry, sched, _spec(
        retry=RetryPolicy(max_retries=2, backoff_base=5.0)))
    runner.advance_to(2.0)
    assert runner.fail_running(job, "flake", transient=True)
    # the scheduler retried before the monitor saw the FAILED event:
    # the stale terminal must not be cached as the job's status
    assert job.state is JobState.QUEUED
    assert monitor.status.get(job.job_id) != "FAILED"
    assert not monitor.is_terminal(job.job_id)
    # the event itself stays visible for watch()/debugging
    assert any(e.get("status") == "FAILED"
               for e in monitor.watch(job.job_id))
    runner.advance_to(7.0)
    sched.tick()
    _drain(runner, sched)
    assert monitor.is_terminal(job.job_id)
    assert monitor.status[job.job_id] == "FINISHED"


# -- transient classification across runners -----------------------------
def test_thread_runner_classifies_transient_and_retries(tmp_path):
    flaky_calls = {"n": 0}

    def flaky(workdir, job):
        flaky_calls["n"] += 1
        if flaky_calls["n"] == 1:
            raise TransientJobError("shard unreachable")
        return {"ok": True}

    def fatal(workdir, job):
        raise ValueError("real bug")

    eng = AcaiEngine(runner="thread", workroot=str(tmp_path),
                     quota_k=100)
    h1 = eng.submit(JobSpec(name="flaky", project="p", user="u", fn=flaky,
                            retry=RetryPolicy(max_retries=2,
                                              backoff_base=0.0)))
    h2 = eng.submit(JobSpec(name="fatal", project="p", user="u", fn=fatal,
                            retry=RetryPolicy(max_retries=2,
                                              backoff_base=0.0)))
    assert h1.wait(timeout=30.0) is JobState.FINISHED
    assert h2.wait(timeout=30.0) is JobState.FAILED
    assert eng.registry.get(h1.job_id).retries == 1
    assert flaky_calls["n"] == 2
    assert eng.registry.get(h2.job_id).retries == 0     # fatal: no retry
    assert "real bug" in eng.registry.get(h2.job_id).error


def test_worker_marks_transient_by_class_name(tmp_path):
    """The subprocess worker classifies by MRO class name (it must not
    import the engine stack): a TransientJobError subclass raised by job
    code stamps ``transient`` on the durable result record."""
    from repro.core.engine.durable.worker import _Worker
    w = _Worker(tmp_path / "w")
    w._run_job({"job": "job-t", "epoch": 0,
                "fn": f"{__name__}:_raise_transient",
                "name": "t", "args": {},
                "workdir": str(tmp_path / "jobs" / "t")})
    w._run_job({"job": "job-f", "epoch": 0,
                "fn": f"{__name__}:_raise_fatal",
                "name": "f", "args": {},
                "workdir": str(tmp_path / "jobs" / "f")})
    assert w._done["job-t"]["status"] == "FAILED"
    assert w._done["job-t"].get("transient") is True
    assert w._done["job-f"]["status"] == "FAILED"
    assert "transient" not in w._done["job-f"]


def _raise_transient(workdir, job):
    raise TransientJobError("flaky shard")


def _raise_fatal(workdir, job):
    raise RuntimeError("deterministic bug")


# -- durability: retry state survives a restart --------------------------
def test_retry_counters_survive_recovery(tmp_path):
    eng = AcaiEngine(durable=tmp_path / "s", virtual=True,
                     pricing=CPU_PRICING, cluster_nodes=1, quota_k=100)
    h = eng.submit(JobSpec(name="r", project="p", user="u", duration=20.0,
                           resources={"vcpu": 4.0, "mem_mb": 512.0},
                           retry=RetryPolicy(max_retries=3,
                                             backoff_base=500.0)))
    job = eng.registry.get(h.job_id)
    assert job.state is JobState.RUNNING
    eng.scheduler.launcher.advance_to(5.0)
    assert eng.scheduler.launcher.fail_running(job, "node blip",
                                               transient=True)
    assert job.state is JobState.QUEUED and job.retries == 1
    eng.store.close()       # crash while held in backoff

    eng2 = AcaiEngine(durable=tmp_path / "s", virtual=True,
                      pricing=CPU_PRICING, cluster_nodes=1, quota_k=100)
    job2 = eng2.registry.get(h.job_id)
    # the journaled retry record survived: no fresh budget post-crash
    assert job2.retries == 1
    assert job2.spec.retry.max_retries == 3     # spec round-trips
    launcher = eng2.scheduler.launcher
    while launcher.pending():       # backoff holds are forgiven across
        launcher.step()             # restart: it re-queued immediately
    assert eng2.registry.get(h.job_id).state is JobState.FINISHED


def test_quarantine_survives_recovery(tmp_path):
    eng = AcaiEngine(durable=tmp_path / "s", virtual=True,
                     pricing=CPU_PRICING, cluster_nodes=1, quota_k=100,
                     quarantine_threshold=2)
    h = eng.submit(JobSpec(name="loop", project="p", user="u",
                           duration=20.0,
                           resources={"vcpu": 4.0, "mem_mb": 512.0},
                           retry=RetryPolicy(max_retries=10,
                                             backoff_base=0.0,
                                             retry_on="any")))
    job = eng.registry.get(h.job_id)
    assert eng.scheduler.launcher.fail_running(job, "bug", transient=False)
    assert job.state is JobState.RUNNING        # one retry granted
    assert eng.scheduler.launcher.fail_running(job, "bug", transient=False)
    assert job.state is JobState.QUARANTINED
    eng.store.close()

    eng2 = AcaiEngine(durable=tmp_path / "s", virtual=True,
                      pricing=CPU_PRICING, cluster_nodes=1, quota_k=100,
                      quarantine_threshold=2)
    job2 = eng2.registry.get(h.job_id)
    assert job2.state is JobState.QUARANTINED   # adopted as terminal,
    assert eng2.recovery.requeued == 0          # never re-run
    assert "quarantined" in job2.error
