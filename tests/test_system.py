"""End-to-end behaviour of the whole system: the paper's workflow (data ->
jobs -> provenance -> provisioning) wrapped around real JAX training, plus
the (arch x shape) applicability matrix the dry-run enforces."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_arch, list_archs
from repro.configs.shapes import SHAPES, applicable, cells
from repro.core.acai import AcaiPlatform
from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import JobSpec


def test_cell_matrix():
    archs = [get_arch(a) for a in list_archs()
             if not a.endswith("-fused")]           # hillclimb variants out
    all_cells = cells(archs)
    assert len(all_cells) == 40                      # 10 archs x 4 shapes
    runnable = [c for c in all_cells if c[2]]
    skipped = [c for c in all_cells if not c[2]]
    assert len(runnable) == 32
    assert len(skipped) == 8
    assert all(c[1].name == "long_500k" for c in skipped)
    assert all(not c[0].subquadratic for c in skipped)
    # sub-quadratic archs DO run long_500k
    for name in ("rwkv6-7b", "zamba2-7b"):
        assert applicable(get_arch(name), SHAPES["long_500k"])[0]


def test_full_acai_training_workflow(tmp_path):
    """The usability-study loop end to end with a real (tiny) LM train job:
    upload -> fileset -> job through the engine -> checkpoint fileset with
    provenance -> metadata query finds the best run."""
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.models import model as M
    from repro.train.checkpoints import CheckpointManager
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import (TrainConfig, make_opt_state,
                                        make_train_step)

    plat = AcaiPlatform(tmp_path)
    admin = plat.create_project(plat.admin_token, "e2e")
    proj = plat.project(admin)
    proj.upload("/data/dataset.json", b'{"seed": 7}', creator="e2e")
    proj.create_file_set("TrainData", ["/data/dataset.json"], creator="e2e")

    def train_job(workdir, job):
        lr = job.spec.args["lr"]
        cfg = get_arch("olmo-1b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        tcfg = TrainConfig()
        step = jax.jit(make_train_step(
            cfg, tcfg, OptimizerConfig(lr=lr, warmup_steps=2,
                                       weight_decay=0.0)))
        opt = make_opt_state(params, tcfg)
        pipe = TokenPipeline(DataConfig(vocab_size=32, seq_len=16,
                                        global_batch=8, markov_temp=2.5),
                             cfg)
        loss = None
        for i in range(8):
            batch = jax.tree.map(jnp.asarray, pipe.batch_at(i))
            params, opt, metrics = step(params, opt, batch)
            loss = float(metrics["loss"])
        ckpt = CheckpointManager(proj, f"run-lr{lr}")
        ckpt.save(8, params, extra={"final_loss": loss},
                  job_id=job.job_id, input_fileset="TrainData")
        print(f"[[acai:final_loss={loss}]]")

    jobs = [plat.submit_job(admin, JobSpec(
        name=f"train-lr{lr}", project="", user="", fn=train_job,
        input_fileset="TrainData", args={"lr": lr},
        resources={"vcpu": 2, "mem_mb": 2048})) for lr in (3e-3, 1e-4)]
    eng = plat.engine(admin)
    for j in jobs:
        assert eng.registry.get(j.job_id).state == JobState.FINISHED, \
            eng.registry.get(j.job_id).error

    # metadata: the higher-lr run should have learned more in 8 steps
    best = proj.metadata.find_min("final_loss", kind="job")
    assert eng.registry.get(best).spec.args["lr"] == pytest.approx(3e-3)

    # provenance: checkpoint filesets trace back to the dataset
    back = proj.provenance.backward("run-lr0.003-ckpt:1")
    assert any(src == "TrainData:1" for src, _ in back)
    # and the checkpoint is restorable
    cfg = get_arch("olmo-1b").reduced()
    template = M.init_params(cfg, jax.random.PRNGKey(0))
    state, step_no = CheckpointManager(proj, "run-lr0.003").restore(
        {"params": template})
    assert step_no == 8
    assert jax.tree.structure(state["params"]) == \
        jax.tree.structure(template)
