"""Execution-engine behaviour: lifecycle, FIFO+quota scheduling, agent
protocol, log parser, quorum straggler policy, auth."""
import pytest

from repro.core.acai import AcaiPlatform, AuthError
from repro.core.engine.lifecycle import IllegalTransition, JobState, \
    check_transition
from repro.core.engine.logparse import parse_line, parse_log
from repro.core.engine.registry import JobSpec
from repro.core.provision.pricing import CPU_PRICING


def test_lifecycle_transitions():
    check_transition(JobState.SUBMITTED, JobState.QUEUED)
    check_transition(JobState.QUEUED, JobState.LAUNCHING)
    check_transition(JobState.RUNNING, JobState.FINISHED)
    with pytest.raises(IllegalTransition):
        check_transition(JobState.FINISHED, JobState.RUNNING)
    with pytest.raises(IllegalTransition):
        check_transition(JobState.SUBMITTED, JobState.RUNNING)


def test_log_parser():
    assert parse_line("[[acai:precision=0.91]]") == {"precision": 0.91}
    assert parse_line("[[acai:model=BERT,epoch=5]]") == \
        {"model": "BERT", "epoch": 5}
    text = "step 1\n[[acai:loss=2.5]]\nstep 2\n[[acai:loss=1.5]]\n"
    assert parse_log(text) == {"loss": 1.5}   # latest wins


@pytest.fixture
def platform(tmp_path):
    plat = AcaiPlatform(tmp_path)
    admin = plat.create_project(plat.admin_token, "proj")
    return plat, admin


def test_auth(platform, tmp_path):
    plat, admin = platform
    with pytest.raises(AuthError):
        plat.authenticate("bogus")
    with pytest.raises(AuthError):
        plat.create_project("bogus", "p2")
    user_tok = plat.create_user(admin, "proj", "alice")
    assert plat.authenticate(user_tok).name == "alice"
    with pytest.raises(AuthError):
        plat.create_user(user_tok, "proj", "eve")   # non-admin


def test_agent_protocol_end_to_end(platform):
    plat, admin = platform
    proj = plat.project(admin)
    proj.upload("/data/in.txt", b"42", creator="admin")
    proj.create_file_set("inputs", ["/data/in.txt"], creator="admin")

    def fn(workdir, job):
        val = int((workdir / "data/in.txt").read_text())
        (workdir / "out/result.txt").write_text(str(val * 2))
        print(f"[[acai:answer={val * 2}]]")
        return {"answer": val * 2}

    job = plat.submit_job(admin, JobSpec(
        name="double", project="", user="", fn=fn,
        input_fileset="inputs", output_fileset="outputs",
        resources={"vcpu": 1, "mem_mb": 1024}))
    j = plat.engine(admin).registry.get(job.job_id)
    assert j.state == JobState.FINISHED
    assert j.outputs["answer"] == 84
    # output file set exists with the result file
    fsv = proj.filesets.resolve("outputs")
    assert "/outputs/result.txt" in fsv.files
    assert proj.storage.download("/outputs/result.txt") == b"84"
    # provenance edge input -> output with job id
    back = proj.provenance.backward("outputs:1")
    assert ("inputs:1", {"action": "job", "job_id": job.job_id,
                         "creator": "proj-admin"}) in back
    # log parser attached metadata; cost computed from the pricing model
    md = proj.metadata.get(job.job_id)
    assert md["answer"] == 84
    assert md["cost"] > 0
    # monitor saw the progress stages
    stages = [e.get("stage") for e in
              plat.engine(admin).monitor.watch(job.job_id) if "stage" in e]
    assert stages == ["downloading", "running", "uploading"]


def test_failed_job(platform):
    plat, admin = platform

    def boom(workdir, job):
        raise RuntimeError("user code crashed")

    job = plat.submit_job(admin, JobSpec(name="bad", project="", user="",
                                         fn=boom))
    j = plat.engine(admin).registry.get(job.job_id)
    assert j.state == JobState.FAILED
    assert "user code crashed" in j.error


def _virtual_platform(tmp_path, quota_k=2):
    plat = AcaiPlatform(tmp_path, virtual=True, quota_k=quota_k)
    admin = plat.create_project(plat.admin_token, "proj")
    return plat, admin


def test_fifo_quota_scheduling(tmp_path):
    plat, admin = _virtual_platform(tmp_path, quota_k=2)
    eng = plat.engine(admin)
    durations = [5.0, 5.0, 1.0, 1.0]
    jobs = [plat.submit_job(admin, JobSpec(
        name=f"j{i}", project="", user="", duration=d))
        for i, d in enumerate(durations)]
    # quota k=2: only two launched immediately, FIFO order preserved
    states = [eng.registry.get(j.job_id).state for j in jobs]
    assert states[:2] == [JobState.RUNNING, JobState.RUNNING]
    assert states[2:] == [JobState.QUEUED, JobState.QUEUED]
    eng.run_all()
    assert all(eng.registry.get(j.job_id).state == JobState.FINISHED
               for j in jobs)
    # FIFO: j2 starts only after one of j0/j1 finishes (virtual t=5)
    assert eng.launcher.now == pytest.approx(6.0)


def test_per_user_isolation(tmp_path):
    plat, admin = _virtual_platform(tmp_path, quota_k=1)
    alice = plat.create_user(admin, "proj", "alice")
    eng = plat.engine(admin)
    ja = [plat.submit_job(alice, JobSpec(name="a", project="", user="",
                                         duration=10.0)) for _ in range(3)]
    jb = plat.submit_job(admin, JobSpec(name="b", project="", user="",
                                        duration=1.0))
    # alice's queue cannot starve admin's queue: quota is per (project,user)
    assert eng.registry.get(jb.job_id).state == JobState.RUNNING
    eng.run_all()


def test_quorum_straggler_mitigation(tmp_path):
    plat, admin = _virtual_platform(tmp_path, quota_k=100)
    eng = plat.engine(admin)
    # 19 fast jobs + 1 extreme straggler
    jobs = [plat.submit_job(admin, JobSpec(
        name=f"p{i}", project="", user="",
        duration=1.0 if i < 19 else 10_000.0)) for i in range(20)]
    res = eng.scheduler.run_until_quorum([j.job_id for j in jobs],
                                         frac=0.95)
    assert len(res["finished"]) == 19
    assert len(res["stragglers"]) == 1
    assert res["virtual_time"] == pytest.approx(1.0)  # didn't wait 10000s
    straggler = eng.registry.get(res["stragglers"][0])
    assert straggler.state == JobState.KILLED


def test_job_kill(tmp_path):
    plat, admin = _virtual_platform(tmp_path, quota_k=1)
    eng = plat.engine(admin)
    j1 = plat.submit_job(admin, JobSpec(name="a", project="", user="",
                                        duration=100.0))
    j2 = plat.submit_job(admin, JobSpec(name="b", project="", user="",
                                        duration=1.0))
    eng.scheduler.kill(j1.job_id)
    assert eng.registry.get(j1.job_id).state == JobState.KILLED
    # queued job launches after the kill frees the quota slot
    assert eng.registry.get(j2.job_id).state == JobState.RUNNING


def test_pricing_model_shape():
    # unit price ramps 2/3 -> 4/3 of baseline (paper Fig. 11)
    dim = CPU_PRICING.dims["vcpu"]
    assert dim.unit_price(0.5) == pytest.approx(dim.base_unit_price * 2 / 3)
    assert dim.unit_price(8.0) == pytest.approx(dim.base_unit_price * 4 / 3)
    lo = CPU_PRICING.job_cost({"vcpu": 0.5, "mem_mb": 512}, 3600)
    hi = CPU_PRICING.job_cost({"vcpu": 8, "mem_mb": 8192}, 3600)
    assert hi > lo * 16   # superlinear in resources
