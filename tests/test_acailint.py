"""acailint fixture suite: every checker fires on its bad fixture and
passes its good one, the suppression/baseline mechanics behave, and the
real engine tree lints clean end-to-end (the CI hard gate)."""
import subprocess
import sys
from collections import Counter
from pathlib import Path

from tools.acailint import DEFAULT_BASELINE, run_files, run_paths
from tools.acailint.core import SourceFile, load_baseline
from tools.acailint.explain import EXPLANATIONS, explain

DATA = Path(__file__).parent / "data" / "acailint"
REPO = Path(__file__).resolve().parents[1]


def _codes(*names, baseline=None):
    files = [SourceFile.load(DATA / n) for n in names]
    return Counter(v.code for v in run_files(files, baseline))


def _dir_codes(dirname):
    return Counter(v.code for v in
                   run_paths([DATA / dirname], baseline_path=None,
                             scoped=False))


# -- per-checker: bad fires, good passes -------------------------------
def test_locks_bad_fixture_fires():
    codes = _codes("locks_bad.py")
    assert codes["ACAI101"] == 1      # unguarded read of a guarded field
    assert codes["ACAI102"] == 3      # publish + metadata + bare handler


def test_locks_good_fixture_passes():
    assert not _codes("locks_good.py")


def test_epochs_bad_fixture_fires():
    codes = _codes("epochs_bad.py")
    assert codes["ACAI201"] == 1      # terminal set_state, no expect_epoch
    assert codes["ACAI202"] == 3      # literal, local dict, .value member


def test_epochs_good_fixture_passes():
    assert not _codes("epochs_good.py")


def test_reserve_bad_fixture_fires():
    assert _codes("reserve_bad.py")["ACAI401"] == 2


def test_reserve_good_fixture_passes():
    # includes the unwind-helper indirection: a handler that releases
    # through a same-file helper counts as protected
    assert not _codes("reserve_good.py")


def test_codec_bad_fixture_fires():
    codes = _dir_codes("codec_bad")
    assert codes["ACAI301"] == 1      # epoch missing from encode_job
    assert codes["ACAI302"] == 1      # mutation without a journal hook


def test_codec_good_fixture_passes():
    assert not _dir_codes("codec_good")


def test_lifecycle_bad_fixture_fires():
    codes = _dir_codes("lifecycle_bad")
    # missing row, undeclared edge target, terminal escape, dead end
    assert codes["ACAI502"] == 4
    # direct .state assignment + set_state to an unreachable state
    assert codes["ACAI501"] == 2


def test_lifecycle_good_fixture_passes():
    assert not _dir_codes("lifecycle_good")


# -- suppression mechanics ---------------------------------------------
def test_justified_suppression_silences():
    assert not _codes("suppress_ok.py")


def test_unjustified_suppression_is_an_error_and_does_not_silence():
    codes = _codes("suppress_bad.py")
    assert codes["ACAI001"] == 1
    assert codes["ACAI201"] == 1


def test_baseline_suppresses_by_suffix_and_code():
    baseline = {("epochs_bad.py", "ACAI201"), ("epochs_bad.py", "ACAI202")}
    assert not _codes("epochs_bad.py", baseline=baseline)


def test_engine_baseline_ships_empty():
    # the checked-in core/engine baseline must stay empty: violations
    # get fixed, not recorded
    assert load_baseline(DEFAULT_BASELINE) == set()


# -- explain ------------------------------------------------------------
def test_every_code_has_an_explanation():
    emitted = {"ACAI001", "ACAI101", "ACAI102", "ACAI201", "ACAI202",
               "ACAI301", "ACAI302", "ACAI401", "ACAI501", "ACAI502"}
    assert emitted == set(EXPLANATIONS)
    for code in emitted:
        assert code in explain(code)
    assert "unknown code" in explain("ACAI999")


# -- end-to-end: the CI hard gate --------------------------------------
def test_engine_tree_lints_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.acailint", "src"],
        cwd=REPO, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_explain_exits_zero():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.acailint", "--explain", "ACAI401"],
        cwd=REPO, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0
    assert "phantom capacity" in proc.stdout


def test_cli_reports_violations_with_exit_one(tmp_path):
    target = tmp_path / "repro" / "core" / "engine"
    target.mkdir(parents=True)
    bad = (DATA / "epochs_bad.py").read_text()
    (target / "runner.py").write_text(bad)
    proc = subprocess.run(
        [sys.executable, "-m", "tools.acailint", str(tmp_path)],
        cwd=REPO, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 1
    assert "ACAI201" in proc.stdout
