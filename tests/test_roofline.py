"""HLO cost model: while-trip accounting, collective parsing, dot FLOPs —
validated against programs with known costs (and documenting the XLA
cost_analysis undercount that motivated the custom model)."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis as RA
from repro.roofline.hlo_cost import module_cost, parse_module


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    n, trips = 128, 10

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((trips, n, n), jnp.float32)
    c = _compile(f, x, w)
    cost = module_cost(c.as_text())
    expected = 2 * n ** 3 * trips
    assert expected <= cost.flops <= expected * 1.1
    # the motivating bug: XLA's own analysis counts the body ONCE
    ca = c.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] < expected / (trips - 1)


def test_plain_dot_flops():
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    c = _compile(lambda a, b: a @ b, a, b)
    cost = module_cost(c.as_text())
    want = 2 * 64 * 256 * 32
    assert want <= cost.flops <= want * 1.05
    # bytes: operands + result at minimum
    assert cost.bytes >= (64 * 256 + 256 * 32 + 64 * 32) * 4


def test_nested_scan_flops():
    n, inner, outer = 64, 3, 5

    def f(x, w):
        def outer_body(c, wo):
            def inner_body(ci, wi):
                return jnp.tanh(ci @ wi), None
            return jax.lax.scan(inner_body, c, wo)[0], None
        return jax.lax.scan(outer_body, x, w)[0]

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((outer, inner, n, n), jnp.float32)
    cost = module_cost(_compile(f, x, w).as_text())
    want = 2 * n ** 3 * inner * outer
    assert want <= cost.flops <= want * 1.2


def test_parse_module_structure():
    c = _compile(lambda x: jnp.sum(x * 2), jax.ShapeDtypeStruct((32,),
                                                                jnp.float32))
    comps = parse_module(c.as_text())
    assert any(len(comp.instrs) > 0 for comp in comps.values())


def test_roofline_terms_and_dominant():
    r = RA.Roofline(flops_per_device=197e12, bytes_per_device=819e9 * 2,
                    collective_bytes=50e9 * 0.5,
                    collectives=RA.CollectiveStats({}, {}),
                    model_flops=197e12 * 128, n_chips=256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.step_time_s == pytest.approx(2.0)
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(128 / (256 * 2.0))


def test_model_flops_kinds():
    from repro.configs.base import get_arch
    from repro.configs.shapes import SHAPES
    cfg = get_arch("qwen3-8b")
    t = RA.model_flops(cfg, SHAPES["train_4k"])
    p = RA.model_flops(cfg, SHAPES["prefill_32k"])
    d = RA.model_flops(cfg, SHAPES["decode_32k"])
    assert t == 6.0 * cfg.n_active_params() * 256 * 4096
    assert p == 2.0 * cfg.n_active_params() * 32 * 32768
    assert d < p  # one token vs a full prompt
    # MoE: active < total reflected in model flops
    moe = get_arch("olmoe-1b-7b")
    assert moe.n_active_params() < moe.n_params()


def test_collective_parse_sharded_program():
    # needs >1 device: use a 1-device mesh psum via shard_map (no comm) —
    # just assert the parser doesn't crash and reports zero collectives
    c = _compile(lambda x: x + 1, jax.ShapeDtypeStruct((8,), jnp.float32))
    cost = module_cost(c.as_text())
    assert cost.coll_bytes == 0
