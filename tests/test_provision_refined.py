"""Active-refinement provisioning (beyond-paper) + feature templates +
master-weights training path."""
import jax
import jax.numpy as jnp

from repro.core.provision.autoprovision import AutoProvisioner
from repro.core.provision.features import template_for
from repro.core.provision.pricing import TPU_PRICING
from repro.core.provision.profiler import CommandTemplate, Profiler


def wall_oracle(cfg):
    """Compute 1/chips scaling up to a hard collective wall at 2s/step —
    the regime where the paper's plain log-linear extrapolation fails."""
    per_step = max(600.0 / cfg["chips"], 2.0)
    return cfg["steps"] * per_step


TEMPLATE = CommandTemplate(
    name="walled",
    hints={"steps": [10, 20]},
    resource_hints={"chips": [8, 32, 128], "hbm_gb": [4, 16]})


def _profiler():
    prof = Profiler(engine=None)
    grid = TEMPLATE.grid()
    prof.fit_offline(TEMPLATE, grid, [wall_oracle(c) for c in grid])
    return prof


def test_refined_search_respects_budget_at_the_wall():
    """Against a hard collective wall the log-linear fit mispredicts
    beyond the profiled hull; refinement must end feasible-and-faster with
    its final measured prediction accurate. (The full overshoot-then-fix
    drama on the realistic oracle is exercised by bench_table23.)"""
    prof = _profiler()
    ap = AutoProvisioner(prof, TPU_PRICING)
    values = {"steps": 100}
    baseline = {"chips": 32, "hbm_gb": 16}
    t_base = wall_oracle({**values, **baseline})
    c_base = TPU_PRICING.job_cost(baseline, t_base)

    dec, hist = ap.refined_search(TEMPLATE.name, values,
                                  measure_fn=wall_oracle,
                                  objective="runtime", max_cost=c_base,
                                  rounds=4)
    assert dec.feasible and len(hist) >= 1
    t_true = wall_oracle({**values, **dec.resources})
    assert t_true < t_base                    # actually faster
    # final accepted round's prediction is accurate
    assert hist[-1]["rel_err"] <= 0.10
    # every refinement observation entered the training set
    cfgs, _ = prof.training_sets[TEMPLATE.name]
    assert len(cfgs) >= len(TEMPLATE.grid()) + len(hist) - 1


def test_refined_search_converges_when_model_is_right():
    prof = Profiler(engine=None)
    grid = TEMPLATE.grid()
    exact = lambda c: c["steps"] * 600.0 / c["chips"]   # pure power law
    prof.fit_offline(TEMPLATE, grid, [exact(c) for c in grid])
    ap = AutoProvisioner(prof, TPU_PRICING)
    dec, hist = ap.refined_search(TEMPLATE.name, {"steps": 50},
                                  measure_fn=exact, objective="runtime",
                                  max_cost=1e9)
    assert len(hist) == 1                     # first measurement confirms
    assert hist[0]["rel_err"] < 0.05


def test_template_for_families():
    from repro.configs.base import get_arch
    dense = template_for(get_arch("qwen3-8b"), "train_4k")
    assert set(dense.resource_hints) == {"chips", "hbm_gb"}
    moe = template_for(get_arch("olmoe-1b-7b"), "train_4k")
    assert "ep_width" in moe.resource_hints
    assert all(64 % w == 0 for w in moe.resource_hints["ep_width"])
    ssm = template_for(get_arch("rwkv6-7b"), "long_500k")
    assert "kv_shard" in ssm.resource_hints
    assert len(dense.grid()) == 27


def test_master_weights_training():
    from repro.configs.base import get_arch
    from repro.models import model as M
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import (TrainConfig, make_opt_state,
                                        make_train_step)
    from repro.data.pipeline import DataConfig, TokenPipeline
    cfg = get_arch("olmo-1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16)
                          if p.dtype == jnp.float32 else p, params)
    tcfg = TrainConfig(master_weights=True)
    opt = make_opt_state(params, tcfg)
    assert "master" in opt
    assert all(m.dtype == jnp.float32 for m in jax.tree.leaves(opt["master"]))
    step = jax.jit(make_train_step(
        cfg, tcfg, OptimizerConfig(lr=3e-3, warmup_steps=2,
                                   weight_decay=0.0)))
    pipe = TokenPipeline(DataConfig(vocab_size=32, seq_len=32,
                                    global_batch=16, markov_temp=2.5), cfg)
    losses = []
    for i in range(15):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    # params stay bf16; masters stay fp32; loss decreases
    assert all(p.dtype == jnp.bfloat16 for p in jax.tree.leaves(params)
               if jnp.issubdtype(p.dtype, jnp.floating))
    assert losses[-1] < losses[0] - 0.5, losses
