"""Checkpoint-aware preemption, elastic/spot pools, and the
reservation-lifecycle invariants they flush out: exactly-once
release/settle under kill-vs-LAUNCHING races, epoch-guarded stale
terminal events, checkpoint-bounded lost work, resize drains, and the
provisioning controller."""
import threading
import time

import pytest

from repro.core.engine.cluster import Cluster
from repro.core.engine.dashboard import scheduler_page
from repro.core.engine.events import EventBus, TOPIC_CONTAINER_STATUS
from repro.core.engine.launcher import ThreadPoolRunner, VirtualRunner
from repro.core.engine.lifecycle import (IllegalTransition, JobPreempted,
                                         JobState, check_transition)
from repro.core.engine.placement import Placement
from repro.core.engine.registry import JobRegistry, JobSpec
from repro.core.engine.scheduler import Scheduler
from repro.core.provision.elastic import ElasticController, PoolPolicy
from repro.core.provision.pricing import CPU_PRICING, spot_pricing
from repro.train.fault import preemption_hook


def _spec(name, duration=1.0, resources=None, user="u", priority=0,
          args=None):
    return JobSpec(name=name, project="p", user=user, duration=duration,
                   priority=priority, resources=resources or {},
                   args=args or {})


def _engine(capacity, *, quota_k=100, preemption=True,
            starvation_threshold=0.0, checkpoint_interval=None, **kw):
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus,
                           checkpoint_interval=checkpoint_interval)
    cl = Cluster(capacity, {k: 0.0 for k in capacity})
    sched = Scheduler(registry, runner, bus, quota_k=quota_k, cluster=cl,
                      preemption=preemption,
                      starvation_threshold=starvation_threshold, **kw)
    return registry, bus, runner, sched, cl


# -- lifecycle ------------------------------------------------------------
def test_preempted_state_transitions():
    check_transition(JobState.RUNNING, JobState.PREEMPTED)
    check_transition(JobState.PREEMPTED, JobState.QUEUED)
    check_transition(JobState.PREEMPTED, JobState.KILLED)
    for bad in [(JobState.PREEMPTED, JobState.RUNNING),
                (JobState.QUEUED, JobState.PREEMPTED),
                (JobState.LAUNCHING, JobState.PREEMPTED),
                (JobState.FINISHED, JobState.PREEMPTED)]:
        with pytest.raises(IllegalTransition):
            check_transition(*bad)


# -- starvation-triggered preemption -------------------------------------
def test_starved_high_priority_preempts_lowest_priority():
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 4.0}, starvation_threshold=30.0, checkpoint_interval=10.0)
    hog = registry.submit(_spec("hog", duration=1000.0,
                                resources={"vcpu": 4}))
    sched.submit(hog)
    assert registry.get(hog.job_id).state == JobState.RUNNING
    hi = registry.submit(_spec("hi", duration=50.0, resources={"vcpu": 4},
                               user="vip", priority=10))
    sched.submit(hi)
    # not yet starved: waited 0 < threshold
    assert registry.get(hi.job_id).state == JobState.QUEUED
    assert registry.get(hog.job_id).state == JobState.RUNNING
    runner.advance_to(40.0)
    sched._maybe_launch()           # poke dispatch past the threshold
    assert registry.get(hi.job_id).state == JobState.RUNNING
    hog_job = registry.get(hog.job_id)
    assert hog_job.state == JobState.QUEUED     # preempted -> requeued
    assert hog_job.preemptions == 1
    assert hog_job.epoch == 1
    assert sched.stats["preempted"] == 1
    # fair-share settled the actual partial runtime of the hog's segment
    assert sched._usage[("p", "u")] == pytest.approx(40.0)
    sched.run_to_completion()
    assert registry.get(hi.job_id).state == JobState.FINISHED
    assert registry.get(hog.job_id).state == JobState.FINISHED
    # resumed from the 40s checkpoint: 50 (hi) + 960 remaining, not 1000
    assert runner.now == pytest.approx(40.0 + 50.0 + 960.0)
    assert runner.preempt_stats["max_lost_s"] <= 10.0 + 1e-9


def test_starved_policy_head_found_behind_low_priority_same_queue():
    """A starved high-priority job parked *behind* an older low-priority
    job in the same queue is that queue's policy head — the starvation
    scan must find it in candidate sort order, not arrival order."""
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 4.0}, starvation_threshold=30.0, checkpoint_interval=10.0)
    mid = registry.submit(_spec("mid", duration=1000.0,
                                resources={"vcpu": 4}, user="other",
                                priority=5))
    sched.submit(mid)               # runs, holds the whole pool
    a = registry.submit(_spec("a", duration=1000.0, resources={"vcpu": 4}))
    sched.submit(a)                 # priority 0, arrives first
    b = registry.submit(_spec("b", duration=50.0, resources={"vcpu": 4},
                              priority=10))
    sched.submit(b)                 # policy head despite arriving second
    runner.advance_to(40.0)
    sched._maybe_launch()
    # b's priority 10 justifies preempting the priority-5 runner; a's
    # priority 0 would not — scanning arrival order would find a, bail
    assert registry.get(b.job_id).state == JobState.RUNNING
    assert registry.get(mid.job_id).preemptions == 1
    sched.run_to_completion()
    for j in (mid, a, b):
        assert registry.get(j.job_id).state == JobState.FINISHED


def test_killed_while_preempted_queued_frees_runner_state():
    """A job killed after a preemption (while re-queued, with no live
    run in the virtual runner) must not leak its checkpoint progress or
    duration draws for the life of the engine."""
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 4.0}, starvation_threshold=30.0, checkpoint_interval=10.0)
    hog = registry.submit(_spec("hog", duration=1000.0,
                                resources={"vcpu": 4}))
    sched.submit(hog)
    hi = registry.submit(_spec("hi", duration=500.0, resources={"vcpu": 4},
                               user="vip", priority=10))
    sched.submit(hi)
    runner.advance_to(40.0)
    sched._maybe_launch()           # hog preempted; hi occupies the pool
    assert registry.get(hog.job_id).state == JobState.QUEUED
    assert hog.job_id in runner._done_frac
    sched.kill(hog.job_id)
    assert hog.job_id not in runner._done_frac
    assert hog.job_id not in runner._dur_cache
    sched.run_to_completion()
    assert registry.get(hi.job_id).state == JobState.FINISHED


def test_equal_priority_never_preempted():
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 4.0}, starvation_threshold=0.0)
    a = registry.submit(_spec("a", duration=100.0, resources={"vcpu": 4}))
    sched.submit(a)
    b = registry.submit(_spec("b", duration=10.0, resources={"vcpu": 4},
                              user="other"))
    sched.submit(b)
    runner.advance_to(50.0)
    sched._maybe_launch()
    # same effective priority: b waits for a to finish, no preemption
    assert registry.get(a.job_id).state == JobState.RUNNING
    assert sched.stats["preempted"] == 0
    sched.run_to_completion()
    assert registry.get(b.job_id).state == JobState.FINISHED


def test_checkpoint_interval_bounds_lost_work():
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 1.0}, checkpoint_interval=10.0)
    j = registry.submit(_spec("train", duration=100.0,
                              resources={"vcpu": 1}))
    sched.submit(j)
    runner.advance_to(37.0)
    assert sched.preempt(j.job_id)
    assert runner.preempt_stats["lost_work_s"] == pytest.approx(7.0)
    # requeued and (capacity being free) immediately relaunched with only
    # the un-checkpointed remainder left
    job = registry.get(j.job_id)
    assert job.state == JobState.RUNNING
    assert runner.expected_duration(job) == pytest.approx(70.0)
    sched.run_to_completion()
    assert runner.now == pytest.approx(37.0 + 70.0)
    assert job.state == JobState.FINISHED


def test_no_checkpoint_interval_restarts_from_zero():
    registry, bus, runner, sched, cl = _engine({"vcpu": 1.0})
    j = registry.submit(_spec("nockpt", duration=100.0,
                              resources={"vcpu": 1}))
    sched.submit(j)
    runner.advance_to(37.0)
    assert sched.preempt(j.job_id)
    assert runner.preempt_stats["lost_work_s"] == pytest.approx(37.0)
    sched.run_to_completion()
    assert runner.now == pytest.approx(37.0 + 100.0)


def test_per_job_checkpoint_interval_override():
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 1.0}, checkpoint_interval=50.0)
    j = registry.submit(_spec("fine", duration=100.0,
                              resources={"vcpu": 1},
                              args={"checkpoint_interval": 5.0}))
    sched.submit(j)
    runner.advance_to(23.0)
    assert sched.preempt(j.job_id)
    assert runner.preempt_stats["lost_work_s"] == pytest.approx(3.0)


def test_preempt_refuses_non_running_and_kill_wins():
    registry, bus, runner, sched, cl = _engine({"vcpu": 1.0})
    a = registry.submit(_spec("a", duration=10.0, resources={"vcpu": 1}))
    sched.submit(a)
    b = registry.submit(_spec("b", duration=10.0, resources={"vcpu": 1}))
    sched.submit(b)                 # queued behind a
    assert not sched.preempt(b.job_id)      # QUEUED: nothing to preempt
    sched.kill(a.job_id)
    assert not sched.preempt(a.job_id)      # KILLED: terminal wins
    sched.run_to_completion()
    assert registry.get(b.job_id).state == JobState.FINISHED


def test_fair_share_charges_every_segment():
    """A job preempted twice charges usage for all three partial
    segments — the sum of actual runtimes, not the declared duration."""
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 1.0}, checkpoint_interval=10.0)
    j = registry.submit(_spec("seg", duration=100.0, resources={"vcpu": 1}))
    sched.submit(j)
    runner.advance_to(20.0)
    sched.preempt(j.job_id)         # segment 1: 20s, checkpointed 20
    runner.advance_to(50.0)
    sched.preempt(j.job_id)         # segment 2: 30s, progress 50
    sched.run_to_completion()       # segment 3: the remaining 50
    assert registry.get(j.job_id).state == JobState.FINISHED
    assert registry.get(j.job_id).preemptions == 2
    assert sched._usage[("p", "u")] == pytest.approx(20.0 + 30.0 + 50.0)


# -- epoch guard: stale terminal events ----------------------------------
def test_stale_terminal_event_cannot_settle_new_incarnation():
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 1.0}, checkpoint_interval=10.0)
    j = registry.submit(_spec("j", duration=100.0, resources={"vcpu": 1}))
    sched.submit(j)
    runner.advance_to(30.0)
    sched.preempt(j.job_id)         # epoch 0 -> 1; relaunches immediately
    job = registry.get(j.job_id)
    assert job.state == JobState.RUNNING and job.epoch == 1
    assert cl.used["vcpu"] == 1.0
    # a worker from the superseded incarnation reports FINISHED late
    bus.publish(TOPIC_CONTAINER_STATUS,
                {"job_id": j.job_id, "status": "FINISHED", "epoch": 0})
    assert job.state == JobState.RUNNING        # ignored
    assert cl.used["vcpu"] == 1.0               # reservation intact
    assert cl.stats["release_underflow"] == 0
    sched.run_to_completion()
    assert job.state == JobState.FINISHED
    assert cl.used["vcpu"] == 0.0


# -- satellite: kill racing LAUNCHING — exactly-once release + settle -----
class CountingCluster(Cluster):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.effective_releases = 0

    def release(self, job_id):
        req = super().release(job_id)
        if req is not None:
            self.effective_releases += 1
        return req


class GatedThreadRunner(ThreadPoolRunner):
    """launch() parks the job instead of handing it to a worker, so a
    test can interleave a kill while the job is still LAUNCHING — the
    exact race the scheduler's settle path must survive."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.parked = []

    def launch(self, job):
        job.preempt_flag = threading.Event()
        with self._cv:
            self._inflight[job.job_id] = \
                self._inflight.get(job.job_id, 0) + 1
        self.parked.append(job)

    def run_parked(self):
        for job in self.parked:
            self._run(job)
        del self.parked[:]


def test_kill_racing_launching_settles_exactly_once():
    registry = JobRegistry()
    bus = EventBus()
    runner = GatedThreadRunner(registry, bus, max_workers=1)
    cl = CountingCluster({"vcpu": 1.0}, {"vcpu": 0.0})
    sched = Scheduler(registry, runner, bus, quota_k=10, cluster=cl)
    usage_calls = []
    orig_charge = sched._charge_usage
    sched._charge_usage = lambda key, amt: (usage_calls.append(amt),
                                            orig_charge(key, amt))[1]
    j = registry.submit(_spec("victim", duration=None,
                              resources={"vcpu": 1}))
    j.spec.fn = lambda wd, job: {"ran": True}
    sched.submit(j)
    assert registry.get(j.job_id).state == JobState.LAUNCHING
    assert cl.used["vcpu"] == 1.0
    killed_events = []
    bus.subscribe(TOPIC_CONTAINER_STATUS,
                  lambda m: killed_events.append(m)
                  if m.get("status") == "KILLED" else None)
    sched.kill(j.job_id)            # races the worker pickup
    assert cl.used["vcpu"] == 0.0   # slot freed immediately
    runner.run_parked()             # worker finally picks the job up
    runner.shutdown()
    assert registry.get(j.job_id).state == JobState.KILLED
    # the invariants the audit pins: one effective release, one
    # fair-share settle, one terminal event, zero accounting drift
    assert cl.effective_releases == 1
    assert len(usage_calls) == 1
    assert len(killed_events) == 1
    assert cl.used["vcpu"] == 0.0
    assert cl.stats["release_underflow"] == 0


def test_threadpool_cooperative_preempt_resumes():
    registry = JobRegistry()
    bus = EventBus()
    runner = ThreadPoolRunner(registry, bus, max_workers=2)
    cl = Cluster({"vcpu": 1.0}, {"vcpu": 0.0})
    sched = Scheduler(registry, runner, bus, quota_k=10, cluster=cl,
                      preemption=True, starvation_threshold=1e9)
    calls = []

    def fn(workdir, job):
        calls.append(job.epoch)
        if len(calls) == 1:
            hook = preemption_hook(job)
            assert job.preempt_flag.wait(10.0), "preempt signal never came"
            hook(step=7)            # raises the external JobPreempted
            raise AssertionError("hook should have raised")
        return {"resumed": True}

    j = registry.submit(JobSpec(name="coop", project="p", user="u", fn=fn,
                                resources={"vcpu": 1}))
    sched.submit(j)
    deadline = time.monotonic() + 10.0
    while registry.get(j.job_id).state != JobState.RUNNING:
        assert time.monotonic() < deadline
        time.sleep(0.005)
    assert sched.preempt(j.job_id)
    deadline = time.monotonic() + 10.0
    while registry.get(j.job_id).state not in (JobState.FINISHED,
                                               JobState.FAILED):
        assert time.monotonic() < deadline, registry.get(j.job_id).state
        time.sleep(0.005)
    runner.shutdown()
    job = registry.get(j.job_id)
    assert job.state == JobState.FINISHED, job.error
    assert job.preemptions == 1 and job.epoch == 1
    # two incarnations ran; the second saw the bumped epoch (the first
    # may observe either 0 or 1 depending on when the signal lands)
    assert len(calls) == 2 and calls[-1] == 1
    assert job.outputs.get("resumed") is True
    assert cl.used["vcpu"] == 0.0
    assert cl.stats["release_underflow"] == 0


def test_spurious_jobpreempted_fails_the_job():
    registry = JobRegistry()
    bus = EventBus()
    runner = ThreadPoolRunner(registry, bus, max_workers=1)
    sched = Scheduler(registry, runner, bus, quota_k=10,
                      cluster=Cluster({"vcpu": 1.0}, {"vcpu": 0.0}))

    def fn(workdir, job):
        raise JobPreempted("nobody asked")

    j = registry.submit(JobSpec(name="spurious", project="p", user="u",
                                fn=fn, resources={"vcpu": 1}))
    sched.submit(j)
    sched.run_to_completion()
    runner.shutdown()
    assert registry.get(j.job_id).state == JobState.FAILED


# -- satellite: release-underflow drift counter ---------------------------
def test_release_underflow_is_counted_not_masked():
    cl = Cluster({"vcpu": 4.0}, {"vcpu": 0.0})
    cl.reserve("a", {"vcpu": 2.0})
    # simulate drifted books: a second holder appears without a reserve
    cl._held["ghost"] = {"vcpu": 3.0}
    cl.release("a")
    assert cl.stats["release_underflow"] == 0
    cl.release("ghost")             # would drive used to -3
    assert cl.used["vcpu"] == 0.0   # still clamped (pool stays usable)
    assert cl.stats["release_underflow"] == 1
    assert cl.stats["release_underflow_amount"] == pytest.approx(3.0)
    # idempotent double release of a normal job does NOT count as drift
    cl.reserve("b", {"vcpu": 1.0})
    cl.release("b")
    cl.release("b")
    assert cl.stats["release_underflow"] == 1


# -- satellite: zero-capacity utilization + dashboard ---------------------
def test_zero_capacity_dimension_reports_inf_not_zero():
    cl = Cluster({"vcpu": 2.0}, {"vcpu": 0.0})
    cl.reserve("a", {"vcpu": 2.0})
    cl.resize({"vcpu": 0.0})        # shrink below the live reservation
    util = cl.utilization()
    assert util["vcpu"] == float("inf")     # flagged, not 0%
    cl.release("a")
    assert cl.utilization()["vcpu"] == 0.0  # empty zero-cap dim is 0


def test_dashboard_renders_overcommit_without_zerodivision():
    registry, bus, runner, sched, cl = _engine({"vcpu": 2.0},
                                               preemption=False)
    j = registry.submit(_spec("j", duration=100.0, resources={"vcpu": 2}))
    sched.submit(j)
    cl.resize({"vcpu": 0.0})
    page = scheduler_page(sched)    # must not raise ZeroDivisionError
    assert "OVERCOMMIT" in page
    sched.run_to_completion()
    assert "OVERCOMMIT" not in scheduler_page(sched)


# -- elasticity: resize + drain ------------------------------------------
def test_resize_grow_admits_waiting_job():
    registry, bus, runner, sched, cl = _engine({"vcpu": 1.0},
                                               preemption=False)
    a = registry.submit(_spec("a", duration=100.0, resources={"vcpu": 1}))
    sched.submit(a)
    b = registry.submit(_spec("b", duration=10.0, resources={"vcpu": 1},
                              user="other"))
    sched.submit(b)
    assert registry.get(b.job_id).state == JobState.QUEUED
    sched.resize_pool(cl.name or "default", {"vcpu": 2.0})
    assert registry.get(b.job_id).state == JobState.RUNNING
    sched.run_to_completion()


def test_resize_shrink_drains_via_preemption():
    registry, bus, runner, sched, cl = _engine(
        {"vcpu": 2.0}, checkpoint_interval=5.0)
    a = registry.submit(_spec("a", duration=100.0, resources={"vcpu": 1}))
    sched.submit(a)
    runner.advance_to(1.0)
    b = registry.submit(_spec("b", duration=100.0, resources={"vcpu": 1},
                              user="other"))
    sched.submit(b)                 # b launched later than a
    overage = sched.resize_pool(cl.name or "default", {"vcpu": 1.0})
    assert overage == {"vcpu": pytest.approx(1.0)}
    # the latest-started reservation drained through the preemption path
    assert registry.get(b.job_id).preemptions == 1
    assert registry.get(a.job_id).state == JobState.RUNNING
    assert cl.used["vcpu"] <= 1.0 + 1e-9
    assert sched.stats["drained"] == 1
    sched.run_to_completion()
    assert registry.get(a.job_id).state == JobState.FINISHED
    assert registry.get(b.job_id).state == JobState.FINISHED


def test_spot_reclaim_preempts_and_requeues():
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus, checkpoint_interval=5.0)
    spot = Cluster({"vcpu": 2.0}, {"vcpu": 0.0}, name="spot", spot=True,
                   reclaim_rate=1e-4)
    sched = Scheduler(registry, runner, bus, quota_k=10,
                      placement=Placement({"spot": spot}), preemption=True,
                      starvation_threshold=1e9)
    jobs = [registry.submit(_spec(f"s{i}", duration=50.0,
                                  resources={"vcpu": 1})) for i in range(2)]
    for j in jobs:
        sched.submit(j)
    runner.advance_to(12.0)
    victims = sched.reclaim("spot")
    assert len(victims) == 2
    assert sched.stats["reclaimed"] == 2
    # capacity untouched (a transient reclaim): both relaunch and resume
    sched.run_to_completion()
    for j in jobs:
        job = registry.get(j.job_id)
        assert job.state == JobState.FINISHED
        assert job.preemptions == 1
    assert runner.preempt_stats["max_lost_s"] <= 5.0 + 1e-9


# -- elastic controller ---------------------------------------------------
def test_controller_grows_under_pressure_and_shrinks_idle():
    registry, bus, runner, sched, cl = _engine({"vcpu": 8.0},
                                               preemption=False)
    pool = cl.name or "default"
    ctl = ElasticController(sched, {pool: PoolPolicy(
        node_shape={"vcpu": 8.0}, min_nodes=1, max_nodes=3,
        grow_at=0.9, shrink_at=0.3, cooldown_s=10.0)})
    assert ctl.nodes(pool) == 1
    jobs = [registry.submit(_spec(f"j{i}", duration=100.0,
                                  resources={"vcpu": 8})) for i in range(2)]
    for j in jobs:
        sched.submit(j)             # one runs (util 1.0), one queues
    decs = ctl.step(now=0.0)
    assert [d.action for d in decs] == ["grow"]
    assert ctl.nodes(pool) == 2
    assert registry.get(jobs[1].job_id).state == JobState.RUNNING
    # cooldown: an immediate second round does nothing
    assert ctl.step(now=1.0) == []
    sched.run_to_completion()
    # idle now: shrink back down to min_nodes, then hold
    assert [d.action for d in ctl.step(now=200.0)] == ["shrink"]
    assert ctl.step(now=300.0) == []        # at min_nodes
    assert ctl.nodes(pool) == 1
    # node-hours integral: 2 nodes for [0, 200), 1 node for [200, 3600)
    hours = ctl.node_hours(until=3600.0)
    assert hours[pool] == pytest.approx(
        (2 * 200.0 + 1 * 3400.0) / 3600.0, rel=1e-6)


def test_controller_node_hours_integral():
    registry, bus, runner, sched, cl = _engine({"vcpu": 8.0},
                                               preemption=False)
    pool = cl.name or "default"
    ctl = ElasticController(sched, {pool: PoolPolicy(
        node_shape={"vcpu": 8.0}, min_nodes=1, max_nodes=4)})
    # no decisions: flat 1 node for an hour
    assert ctl.node_hours(until=3600.0)[pool] == pytest.approx(1.0)
    assert ctl.provisioned_cost(3600.0, {pool: 2.5}) == pytest.approx(2.5)


# -- spot-aware placement -------------------------------------------------
def test_placement_prices_spot_risk_by_runtime():
    ondemand = Cluster({"vcpu": 8.0}, name="ondemand")
    spot = Cluster({"vcpu": 8.0}, name="spot", spot=True,
                   reclaim_rate=1.0 / 1800.0)     # ~1 reclaim / 30 min
    catalog = {"ondemand": CPU_PRICING,
               "spot": spot_pricing(CPU_PRICING, discount=0.6)}
    pl = Placement({"ondemand": ondemand, "spot": spot}, pricing=catalog,
                   objective="cost", spot_risk_weight=1.0)
    short = _spec("short", duration=60.0, resources={"vcpu": 1})
    long = _spec("long", duration=6 * 3600.0, resources={"vcpu": 1})
    # short job: 60s of risk costs ~3% — the 60% discount wins easily
    assert pl.rank(short, pl.eligible(short))[0] == "spot"
    # long job: 12 expected reclamations inflate spot 13x — on-demand wins
    assert pl.rank(long, pl.eligible(long))[0] == "ondemand"


def test_spot_pricing_preserves_subclass_and_discount():
    from repro.core.provision.pricing import (ChipScaledPricing,
                                              TPU_PRICING)
    sp = spot_pricing(TPU_PRICING, discount=0.5)
    assert isinstance(sp, ChipScaledPricing)
    assert sp.family == "tpu-spot"
    res = {"chips": 8, "hbm_gb": 2}
    assert sp.job_cost(res, 3600.0) == \
        pytest.approx(0.5 * TPU_PRICING.job_cost(res, 3600.0))
    with pytest.raises(ValueError):
        spot_pricing(TPU_PRICING, discount=1.5)


# -- zombie incarnations: stale workers must not touch the live job ------
def test_stale_epoch_finalize_cannot_terminalize_live_incarnation():
    """A worker from a superseded incarnation that completes late must
    not write the registry, bill, or publish a terminal event — the
    relaunched incarnation owns the job now."""
    registry = JobRegistry()
    bus = EventBus()
    runner = ThreadPoolRunner(registry, bus, max_workers=1)
    j = registry.submit(_spec("zombie", duration=None,
                              resources={"vcpu": 1}))
    for s in (JobState.QUEUED, JobState.LAUNCHING, JobState.RUNNING):
        registry.set_state(j.job_id, s)
    j.epoch = 1                     # the job was preempted + relaunched
    terminal = []
    bus.subscribe(TOPIC_CONTAINER_STATUS, terminal.append)
    runner._finalize(j, "old log", JobState.FINISHED, epoch=0)
    runner.shutdown()
    assert registry.get(j.job_id).state == JobState.RUNNING
    assert terminal == []
    assert j.cost is None           # stale segment not billed
    # the live incarnation's own finalize still works
    runner2 = ThreadPoolRunner(registry, bus, max_workers=1)
    j.runtime = 1.0
    runner2._finalize(j, "new log", JobState.FINISHED, epoch=1)
    runner2.shutdown()
    assert registry.get(j.job_id).state == JobState.FINISHED
    assert [m["status"] for m in terminal] == ["FINISHED"]


# -- train/fault tie-in ---------------------------------------------------
def test_preemption_hook_is_silent_until_signalled():
    class FakeJob:
        job_id = "job-x"
        epoch = 0
        preempt_flag = threading.Event()
    hook = preemption_hook(FakeJob)
    hook(3)                         # no signal: no raise
    FakeJob.preempt_flag.set()
    with pytest.raises(JobPreempted) as ei:
        hook(4)
    assert getattr(ei.value, "external", False) is True


def test_preemption_hook_survives_flag_replacement():
    """The relaunch installs a fresh (unset) preempt_flag on the shared
    Job; a superseded worker's hook must still observe its preemption
    via the epoch it captured at creation — polling the live flag alone
    would lose the signal."""
    class FakeJob:
        job_id = "job-y"
        epoch = 0
        preempt_flag = threading.Event()
    hook = preemption_hook(FakeJob)
    hook(1)
    # scheduler preempts (epoch bump) and the relaunch replaces the flag
    # before this worker's next poll
    FakeJob.epoch = 1
    FakeJob.preempt_flag = threading.Event()    # fresh, unset
    with pytest.raises(JobPreempted):
        hook(2)
